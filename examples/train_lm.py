"""End-to-end driver: train a ~100M-param LM for a few hundred steps,
with checkpoints, restart, Kahan-compensated bf16 params, and compensated
grad-norm (the VRP training tie-ins) — the "standalone mode" of EPAC's
dual execution model.

The ~100M model is an olmo-family config (12L, d=768) — real vocab, real
depth, CPU-trainable in minutes at short seq.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import functools

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.train import TrainLoopConfig, train_loop
from repro.models.model import Model
from repro.models.transformer import RunCtx
from repro.optim import OptConfig
from repro.optim.schedule import warmup_cosine


def config_100m():
    return dataclasses.replace(
        get_config("olmo_1b"),
        name="olmo-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, head_dim=64, d_ff=3072, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m()
    model = Model(cfg)
    n_params = sum(
        int(x.size) for x in jax.tree.leaves(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"model: {cfg.name}  params={n_params / 1e6:.1f}M")

    opt_cfg = OptConfig(weight_decay=0.1, kahan=False, norm_tile="vrp")
    ctx = RunCtx(kernel_mode="ref")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    loop_cfg = TrainLoopConfig(steps=args.steps, ckpt_every=100,
                               ckpt_dir=args.ckpt_dir, log_every=20,
                               metrics_path=args.ckpt_dir + ".metrics.jsonl")
    lr_fn = functools.partial(warmup_cosine, peak_lr=3e-4, warmup_steps=30,
                              total_steps=args.steps)
    state, hist = train_loop(model, opt_cfg, ctx, data_cfg, loop_cfg,
                             lr_fn=lr_fn)
    first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    print(f"loss: first10={first:.3f} last10={last:.3f} "
          f"(checkpoints in {loop_cfg.ckpt_dir}; rerun to resume)")


if __name__ == "__main__":
    main()
