"""Quickstart: the three EPAC tiles in 60 seconds.

  1. VEC — vector-length-agnostic strip-mining (no scalar tails),
  2. STX — Pallas stencil/matmul kernels validated vs the jnp oracle,
  3. VRP — runtime-selectable extended precision rescuing an
     ill-conditioned CG solve,
then a tiny LM train step on the same substrate.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import functools

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import solvers
from repro.core.precision import F64, VP128, VP256
from repro.core.vec import strip_mine
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels import ops, ref
from repro.launch.train import init_state, make_train_step
from repro.models.model import Model
from repro.models.transformer import RunCtx
from repro.optim import OptConfig
from repro.optim.schedule import constant

print("== VEC: vector-length-agnostic strip-mining ==")
x = jnp.arange(1000003, dtype=jnp.float32)          # deliberately ragged
y = strip_mine(lambda v: 2.0 * v + 1.0, x, max_vl=8192)
assert float(jnp.max(jnp.abs(y - (2 * x + 1)))) == 0.0
print(f"   axpy over {x.shape[0]} elements (not a multiple of anything): ok")

print("== STX: Pallas stencil kernel vs oracle (interpret mode) ==")
rng = np.random.default_rng(0)
grid = jnp.asarray(rng.normal(size=(96, 96)), jnp.float32)
w = ref.five_point_weights()
out = ops.stencil2d(grid, w, block_m=32, block_n=32, mode="interpret")
err = float(jnp.max(jnp.abs(out - ref.stencil2d(grid, w))))
print(f"   5-point Laplacian, 96x96, kernel-vs-oracle max err: {err:.1e}")

print("== VRP: precision as a runtime knob (Hilbert system, cond~1.7e16) ==")
A = solvers.hilbert(12)
b = A @ jnp.ones(12)
for env, name in ((F64, "f64   (53 bits)"), (VP128, "vp128 (106 bits)"),
                  (VP256, "vp256 (265 bits)")):
    res = solvers.cg(A, b, env, tol=1e-13, maxiter=400)
    print(f"   CG @ {name}: iters={int(res.iterations):3d} "
          f"converged={bool(res.converged)} relres={float(res.residual):.1e}")

print("== LM train steps on the tile substrate (olmo-1b smoke config) ==")
cfg = get_config("olmo_1b").smoke()
model = Model(cfg)
opt_cfg = OptConfig(weight_decay=0.0)
state = init_state(model, opt_cfg)
step = jax.jit(make_train_step(model, opt_cfg, RunCtx(kernel_mode="ref"),
                               functools.partial(constant, peak_lr=3e-3)))
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                              global_batch=8))
for i in range(20):
    state, metrics = step(state, data.batch_at(i))
    if i % 5 == 0:
        print(f"   step {i:2d} loss {float(metrics['loss']):.3f}")
print("done — see examples/train_lm.py for the full driver.")
