"""STX end-to-end: 2-D heat diffusion via the stencil kernel (paper §3.2).

"Iterative time-stepping algorithms (e.g., diffusion or wave
propagation)" are the STX tile's stated use case. This drives the
halo-blocked Pallas stencil through a diffusion solve and cross-checks
against the analytic solution, plus a 3-D 7-point step.

Run: PYTHONPATH=src python examples/stencil_diffusion.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

N = 96
ALPHA = 0.20          # diffusion number (stable: <= 0.25 in 2-D)
STEPS = 200


def diffusion_step(u, weights, mode):
    return u + ALPHA * ops.stencil2d(u, weights, block_m=32, block_n=32,
                                     mode=mode)


if __name__ == "__main__":
    # hot square in the middle of a cold plate (zero boundary)
    u0 = jnp.zeros((N, N), jnp.float32).at[36:60, 36:60].set(1.0)
    w = ref.five_point_weights()

    # reference path (what the dry-run lowers), jitted end-to-end
    step_ref = jax.jit(lambda u: diffusion_step(u, w, "ref"))
    u = u0
    for t in range(STEPS):
        u = step_ref(u)
    total0 = float(jnp.sum(u0))

    # kernel path (interpret mode = kernel body semantics on CPU)
    u_k = u0
    for t in range(8):
        u_k = diffusion_step(u_k, w, "interpret")
    u_r = u0
    for t in range(8):
        u_r = step_ref(u_r)
    err = float(jnp.max(jnp.abs(u_k - u_r)))
    print(f"kernel-vs-ref after 8 steps: max err {err:.2e}")
    assert err < 1e-5

    # physics sanity: heat spreads, maximum decays, nothing blows up
    print(f"t=0    peak={float(u0.max()):.3f} total={total0:.1f}")
    print(f"t={STEPS}  peak={float(u.max()):.3f} total={float(jnp.sum(u)):.1f} "
          f"(mass leaks through the cold boundary, peak must decay)")
    assert float(u.max()) < 1.0 and float(u.max()) > 0.0
    assert bool(jnp.all(jnp.isfinite(u)))

    # 3-D: one 7-point step on a 64^3 grid through the 3-D kernel
    rng = np.random.default_rng(0)
    vol = jnp.asarray(rng.normal(size=(64, 64, 64)), jnp.float32)
    w7 = ref.seven_point_weights()
    out = ops.stencil3d(vol, w7, block_d=8, block_m=32, block_n=32,
                        mode="interpret")
    err3 = float(jnp.max(jnp.abs(out - ref.stencil3d(vol, w7))))
    print(f"3-D 7-point 64^3 kernel-vs-ref: max err {err3:.2e}")
    assert err3 < 1e-4
    print("diffusion demo ok")
