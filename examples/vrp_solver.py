"""VRP end-to-end: adaptive-precision Krylov solving (paper §3.3).

The silicon's usage model: the host configures precision via environment
registers, the VRP runs VBLAS-based solvers, precision can be *adapted at
runtime* to balance cost vs numerical stability. This example implements
that adaptive strategy: start cheap (f64), escalate K only if the solver
stalls — no recompilation of the solver, just a new PrecisionEnv.

Run: PYTHONPATH=src python examples/vrp_solver.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import time

import jax.numpy as jnp
import numpy as np

from repro.core import solvers, vrp
from repro.core.precision import PRESETS

LADDER = ["f64", "vp128", "vp256", "vp512"]


def adaptive_cg(A, b, tol=1e-13, maxiter=400):
    """Escalate precision until CG converges (paper's adaptive strategy)."""
    history = []
    for name in LADDER:
        env = PRESETS[name]
        t0 = time.time()
        res = solvers.cg(A, b, env, tol=tol, maxiter=maxiter)
        dt = time.time() - t0
        history.append((name, int(res.iterations), float(res.residual), dt))
        print(f"  {name:6s} ({env.significand_bits:3d} bits): "
              f"iters={int(res.iterations):3d} relres={float(res.residual):.2e} "
              f"({dt:.1f}s)")
        if bool(res.converged):
            return res, name, history
    return res, name, history


if __name__ == "__main__":
    print("== problem 1: moderately ill-conditioned (cond 1e8) ==")
    A = solvers.hilbert_like(64, cond=1e8, seed=0)
    b = A @ jnp.ones(64)
    res, used, _ = adaptive_cg(A, b, tol=1e-12)
    print(f"  -> solved at {used}; x_err={float(jnp.max(jnp.abs(res.x - 1))):.2e}")

    print("== problem 2: Hilbert n=12 (cond ~1.7e16) ==")
    A = solvers.hilbert(12)
    b = A @ jnp.ones(12)
    res, used, _ = adaptive_cg(A, b, tol=1e-13)
    print(f"  -> solved at {used}")

    print("== problem 3: extended-precision RHS (cond 1e6) ==")
    env = PRESETS["vp256"]
    m = 24
    Am = solvers.hilbert_like(m, cond=1e6, seed=1)
    xs = vrp.from_float(jnp.ones(m), env)
    bE = vrp.tree_sum(vrp.mul(vrp.from_float(Am, env), xs[None], env), env,
                      axis=1)
    r64 = solvers.cg(Am, vrp.to_float(bE), PRESETS["f64"], tol=1e-24,
                     maxiter=600)
    rvp = solvers.cg(Am, bE[:, :2], PRESETS["vp128"], tol=1e-24, maxiter=600)
    print(f"  f64   iters={int(r64.iterations)} "
          f"xerr={float(jnp.max(jnp.abs(r64.x - 1))):.2e}")
    print(f"  vp128 iters={int(rvp.iterations)} "
          f"xerr={float(jnp.max(jnp.abs(rvp.x - 1))):.2e}")
    print("  (the paper's claim: extended precision improves convergence; "
          "fewer iterations and lower error at the same tolerance)")
