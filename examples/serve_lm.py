"""Serving example: continuous batching over the paged KV cache.

Loads (or random-inits) a smoke model, submits a stream of ragged
requests with skewed output lengths, and drives the continuous-batching
``Scheduler`` (launch/serve.py): requests are admitted into decode slots
as earlier ones retire, KV cache blocks are recycled on the fly, and the
jit'd decode step never recompiles. With --arch recurrentgemma_2b the
decode path mixes constant-size RG-LRU state with windowed ring caches.

Compare with the legacy lockstep batcher via --engine static.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch olmo_1b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import (Scheduler, SchedulerConfig, ServeConfig,
                                Server)
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--n-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if args.engine == "static":
        server = Server(model, params, ServeConfig(batch_size=args.slots,
                                                   max_len=128))
        prompts = [list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(4, 16))))
                   for _ in range(args.slots)]
        print(f"arch={cfg.name}  {args.slots} ragged prompts "
              f"(lens {[len(p) for p in prompts]})")
        t0 = time.time()
        outs = server.generate(prompts, args.n_new)
        dt = time.time() - t0
        print(f"decoded {args.n_new} x {args.slots} tokens in {dt:.2f}s "
              f"({args.slots * args.n_new / dt:.1f} tok/s)")
        for i, o in enumerate(outs):
            print(f"  req{i}: {o[:10]}...")
        return

    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=args.slots, block_size=16,
                                      num_blocks=256, max_len=128))
    for _ in range(args.requests):
        prompt = list(rng.integers(0, cfg.vocab_size,
                                   int(rng.integers(4, 16))))
        # skewed output lengths: mostly short, a few long stragglers
        max_new = int(rng.choice([4, 6, 8, args.n_new],
                                 p=[0.4, 0.25, 0.2, 0.15]))
        sched.submit(prompt, max_new)
    print(f"arch={cfg.name}  {args.requests} requests into "
          f"{args.slots} slots")
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    st = sched.stats()
    print(f"decoded {total} tokens over {len(done)} reqs in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    print(f"  mean active slots {st['mean_active_slots']:.2f}/"
          f"{args.slots}, cache utilization "
          f"{st['cache_utilization']:.0%}, blocks leaked "
          f"{st['blocks_used']}")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        print(f"  req{r.uid}: {r.out[:10]}...")


if __name__ == "__main__":
    main()
