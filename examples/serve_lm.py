"""Serving example: batched prefill + decode (host-device mode).

Trains nothing — loads (or random-inits) a smoke model, packs a ragged
request batch VLA-style, prefases and decodes with the ring/linear KV
caches, prints tokens/s. With --arch recurrentgemma_2b the decode path
exercises the constant-size RG-LRU state instead of a growing KV cache.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch olmo_1b]
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import ServeConfig, Server
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--n-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, ServeConfig(batch_size=args.batch,
                                               max_len=128))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 16))))
               for _ in range(args.batch)]
    print(f"arch={cfg.name}  {args.batch} ragged prompts "
          f"(lens {[len(p) for p in prompts]})")
    import time
    t0 = time.time()
    outs = server.generate(prompts, args.n_new)
    dt = time.time() - t0
    print(f"decoded {args.n_new} x {args.batch} tokens in {dt:.2f}s "
          f"({args.batch * args.n_new / dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"  req{i}: {o[:10]}...")


if __name__ == "__main__":
    main()
