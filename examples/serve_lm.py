"""Serving example: the unified Engine API, streaming outputs.

Loads (or random-inits) a smoke model, submits a stream of ragged
requests with per-request SamplingParams (temperature / top-k / top-p /
seed / stop tokens), and drives ``Engine.step()`` by hand to show the
streaming interface: each step yields per-request token increments as
they are sampled. The paged backend admits optimistically, preempts LIFO
under cache pressure (watch the preemption counter with a tiny
--mem-tokens), and prefills through power-of-two buckets; the static
backend is the lockstep baseline behind the same API.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch olmo_1b]
     PYTHONPATH=src python examples/serve_lm.py --backend static
     PYTHONPATH=src python examples/serve_lm.py --smoke   # CI-sized
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.engine import (DisaggregatedEngine, Engine, EngineConfig,
                                 ReplicaSet, SamplingParams)
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--backend", choices=("paged", "static"),
                    default="paged")
    ap.add_argument("--n-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--mem-tokens", type=int, default=256,
                    help="paged KV pool capacity in tokens")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard the backend over "
                         "a (data = n/tp, model = tp) mesh of the local "
                         "devices (fake N CPU devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel engine replicas behind one "
                         "shared admission queue (ReplicaSet); splits "
                         "the mesh's data axis, each replica keeping "
                         "its own KV pool and TP subgrid")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative decoding: draft tokens per step "
                         "(paged backend; ngram self-drafting — outputs "
                         "are bit-identical, only faster on repetitive "
                         "text)")
    ap.add_argument("--roles", default=None,
                    help="prefill/decode disaggregation over the dp "
                         "replicas: comma-separated roles (e.g. "
                         "'prefill,decode') or 'auto'. Prefill replicas "
                         "export first-token slots as migration packets; "
                         "decode replicas import them — outputs stay "
                         "bit-identical, stats() grows a 'disagg' "
                         "section")
    ap.add_argument("--kv-dtype", choices=("bf16", "int8", "fp8"),
                    default="bf16",
                    help="paged KV pool storage precision: int8/fp8 "
                         "store quantized blocks with per-(token, head) "
                         "scales, dequant fused into the kernels "
                         "(several-fold cache capacity per byte; paged backend "
                         "only)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.n_new = 6, 8

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    from repro.launch.mesh import make_local_mesh, replica_cli_mesh

    if args.dp > 1:
        # dp x tp devices, each replica a (1, tp) TP subgrid
        mesh = replica_cli_mesh(args.dp, args.tp)
    elif args.tp > 1 or len(jax.devices()) > 1:
        mesh = make_local_mesh(args.tp)
    else:
        mesh = None
    if mesh is not None:
        print(f"mesh: {dict(zip(('data', 'model'), mesh.devices.shape))}")

    ecfg = EngineConfig(
        backend=args.backend, num_slots=args.slots, block_size=16,
        num_blocks=args.mem_tokens // 16 + 1, max_len=128,
        spec_tokens=args.spec_tokens, kv_dtype=args.kv_dtype)
    if args.roles is not None:
        roles = args.roles if args.roles == "auto" \
            else tuple(args.roles.split(","))
        engine = DisaggregatedEngine(model, params, ecfg, dp=args.dp,
                                     mesh=mesh, roles=roles)
        print(f"disaggregated: roles={list(engine.roles)}, "
              f"{engine.total_slots} total slots")
    elif args.dp > 1:
        engine = ReplicaSet(model, params, ecfg, dp=args.dp, mesh=mesh)
        print(f"replica set: dp={args.dp}, "
              f"{engine.total_slots} total slots")
    else:
        import dataclasses

        engine = Engine(model, params,
                        dataclasses.replace(ecfg, mesh=mesh))

    handles = []
    for i in range(args.requests):
        prompt = list(rng.integers(0, cfg.vocab_size,
                                   int(rng.integers(4, 16))))
        # skewed output lengths: mostly short, a few long stragglers
        max_new = int(rng.choice([4, 6, 8, args.n_new],
                                 p=[0.4, 0.25, 0.2, 0.15]))
        handles.append(engine.add_request(prompt, SamplingParams(
            max_tokens=max_new, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, seed=i)))
    print(f"arch={cfg.name}  backend={args.backend}  "
          f"{args.requests} requests into {args.slots} slots")

    t0 = time.time()
    total = 0
    while engine.has_work:
        for out in engine.step():                 # streaming increments
            total += len(out.new_tokens)
            if out.request_id < 2 and out.new_tokens:
                print(f"  stream req{out.request_id} += "
                      f"{list(out.new_tokens)}"
                      + (f"  [done: {out.finish_reason}]"
                         if out.finished else ""))
    dt = time.time() - t0

    st = engine.stats()
    print(f"decoded {total} tokens over {len(handles)} reqs in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    print(f"  stats: {st}")
    for h in handles[:3]:
        print(f"  req{h.uid}: {h.token_ids[:10]}... ({h.finish_reason})")
    assert all(h.finished for h in handles)
    if args.backend == "paged":
        assert st["blocks_used"] == 0, "block leak"


if __name__ == "__main__":
    main()
