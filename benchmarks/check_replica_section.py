"""CI asserts over the ``replicas`` section of BENCH_serve.json.

Validates the dp=2 acceptance bar: aggregate capacity (per-replica
clocks — fake CPU devices time-share the host cores, see
bench_serve.py) at least ``--min-speedup`` x one replica of the same
config, zero block leaks, the expected (data, model) mesh, and a
non-degenerate dispatch spread. Kept as a script so the workflow can
retry the whole bench+check once on a timing transient instead of
failing the job on host noise.
"""

from __future__ import annotations

import argparse
import json
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--min-speedup", type=float, default=1.5)
    ap.add_argument("--mesh", default=None,
                    help="expected 'data,model' sizes, e.g. '2,2'")
    args = ap.parse_args()
    with open(args.json) as f:
        r = json.load(f)["replicas"]
    errors = []
    if r["blocks_leaked"]:
        errors.append(f"{r['blocks_leaked']} blocks leaked")
    if args.mesh is not None:
        want = dict(zip(("data", "model"),
                        (int(x) for x in args.mesh.split(","))))
        if r["mesh"] is None or r["mesh"]["axes"] != want:
            errors.append(f"mesh {r['mesh']} != {want}")
    if r["speedup_vs_single"] < args.min_speedup:
        errors.append(
            f"aggregate {r['agg_tok_s']:.1f} tok/s is only "
            f"{r['speedup_vs_single']:.2f}x one replica "
            f"({r['single_tok_s']:.1f}); need {args.min_speedup}x")
    if not all(p["share"] > 0 for p in r["per_replica"]):
        errors.append(f"a replica was starved: {r['dispatched']}")
    if "queue_wait" not in r:
        errors.append("queue_wait telemetry missing")
    if errors:
        for e in errors:
            print(f"REPLICA SECTION FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"replicas ok: dp={r['dp']} agg {r['agg_tok_s']:.1f} tok/s = "
          f"{r['speedup_vs_single']:.2f}x single; dispatched "
          f"{r['dispatched']}; queue wait {r['queue_wait']}")


if __name__ == "__main__":
    main()
