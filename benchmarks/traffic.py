"""Deterministic open-loop traffic generation with per-request SLOs.

Every trace in bench_serve.py before this module was CLOSED-loop in
spirit: requests exist up front and the replay clock only gates when
they become visible. Production serving is OPEN-loop — arrivals do not
wait for completions — and the serving literature's headline metrics
(Orca/vLLM continuous batching, Sarathi chunked prefill) are TTFT/TPOT
percentiles and *goodput under SLO*: the token throughput attributable
to requests that met their latency budgets, not raw tok/s. This module
generates those arrival processes and scores those metrics.

Determinism contract: a trace is a pure function of its seed — the
generators never read a wall clock, so the same (seed, kind, rate, n)
produces the same arrivals, prompts, output lengths, and SLO
annotations on every machine. The REPLAY measures real time; the TRACE
never does. That is what lets the bench replay one identical trace
through two engine configs (``overlap=`` off/on) and raw-assert
bit-identical outputs, and what lets the chaos tests in
tests/test_open_loop.py re-inject a failing trace from nothing but its
seed.

Arrival kinds (all share the same long-run mean rate, so sections are
comparable across kinds):

* ``poisson`` — memoryless exponential gaps; the steady-traffic
  baseline.
* ``bursty``  — back-to-back arrival bursts separated by exponential
  quiet gaps (mean gap = burst/rate). Bursts are the adversarial case
  for admission control: a burst wider than the free-block pool lands
  entirely inside one watermark window.
* ``ramp``    — instantaneous rate ramps linearly from below to above
  the mean across the trace; exercises the transition from an idle
  engine (arrival-gated) to a saturated one (capacity-gated).

SLO model: each request carries its own ``SLO(ttft_s, tpot_s)`` budget
pair — time-to-first-token and time-per-output-token. ``slo_report``
scores a replay: a request *meets* its SLO when TTFT <= ttft_s and
(once it has >= 2 tokens, so TPOT is defined) its mean inter-token
gap <= tpot_s. Goodput is the emitted-token throughput of the meeting
subset over the same replay wall time — tokens from SLO-violating
requests are produced but worthless, which is exactly how this metric
punishes a scheduler that optimizes raw tok/s by starving the tail.

Budgets are machine-relative by construction: an absolute budget would
make goodput a CPU-speed lottery in CI, so ``annotate_slos`` derives
per-request budgets from a measured baseline (bench_serve calibrates
on the overlap=False replay) with generous multipliers, and
longer-prompt requests get proportionally more TTFT headroom (their
prefill is genuinely bigger).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.engine.api import latency_stats


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency budget: TTFT and TPOT in seconds."""

    ttft_s: float
    tpot_s: float


@dataclasses.dataclass
class OpenLoopItem:
    """One open-loop request: arrival offset (seconds since trace
    start), prompt token ids, output budget, and its SLO annotation.
    Field-compatible with bench_serve's ``TraceItem`` (arrival /
    prompt / max_new), so ``_replay`` and ``_warm`` take it as-is."""

    arrival: float
    prompt: list[int]
    max_new: int
    slo: SLO


def poisson_arrivals(n: int, rate: float, rng) -> np.ndarray:
    """Memoryless arrivals: exponential gaps at ``rate`` req/s."""
    return np.cumsum(rng.exponential(1.0 / rate, n))


def bursty_arrivals(n: int, rate: float, rng, *, burst: int = 8,
                    spread: float = 1e-4) -> np.ndarray:
    """Bursts of ``burst`` near-simultaneous arrivals (``spread``
    seconds apart, preserving a strict arrival order) separated by
    exponential quiet gaps with mean ``burst/rate`` — the same long-run
    rate as the Poisson kind, concentrated into admission spikes."""
    t, out = 0.0, []
    while len(out) < n:
        t += float(rng.exponential(burst / rate))
        for i in range(min(burst, n - len(out))):
            out.append(t + i * spread)
    return np.asarray(out)


def ramp_arrivals(n: int, rate: float, rng, *,
                  ramp_from: float = 0.25) -> np.ndarray:
    """Linearly ramping load: the instantaneous rate of request i runs
    from ``ramp_from * rate`` up to ``(2 - ramp_from) * rate`` across
    the trace (mean ``rate``), crossing the engine's capacity somewhere
    in the middle — the under-to-overload transition."""
    rates = np.linspace(ramp_from * rate, (2.0 - ramp_from) * rate, n)
    return np.cumsum(rng.exponential(1.0 / rates))


_KINDS = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
          "ramp": ramp_arrivals}


def make_open_loop_trace(cfg, *, kind: str, n_requests: int, rate: float,
                         seed: int, prompt_lens=(8, 12, 16),
                         max_new_choices=(16, 32, 64),
                         max_new_p=(0.35, 0.40, 0.25),
                         slo: SLO = SLO(10.0, 1.0),
                         **kind_kwargs) -> list[OpenLoopItem]:
    """Seeded open-loop trace of ``n_requests`` with ``kind`` arrivals.

    Output lengths lean LONG relative to the closed-loop serve trace
    (16/32/64 vs mostly 4–8): TPOT is undefined below two tokens and
    noisy below ten, and the decode loop is where the overlap toggle
    this trace prices actually lives. ``slo`` is a placeholder budget
    replaced by ``annotate_slos`` once a baseline has been measured.

    Parameters
    ----------
    cfg
        Model config (vocab_size bounds the random prompts).
    kind : {"poisson", "bursty", "ramp"}
        Arrival process; extra ``kind_kwargs`` (e.g. ``burst=``) are
        forwarded to the generator.
    n_requests, rate, seed
        Trace size, long-run mean arrival rate (req/s), RNG seed —
        the trace is a pure function of these (plus the shape kwargs).

    Returns
    -------
    list of OpenLoopItem
        Arrival-sorted; deterministic for fixed arguments.
    """
    if kind not in _KINDS:
        raise ValueError(f"unknown arrival kind {kind!r}; "
                         f"expected one of {sorted(_KINDS)}")
    rng = np.random.default_rng(seed)
    arrivals = _KINDS[kind](n_requests, rate, rng, **kind_kwargs)
    items = []
    for t in arrivals:
        plen = int(rng.choice(prompt_lens))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        max_new = int(rng.choice(max_new_choices, p=max_new_p))
        items.append(OpenLoopItem(float(t), prompt, max_new, slo))
    return items


def annotate_slos(trace: list[OpenLoopItem], *, ttft_s: float,
                  tpot_s: float):
    """Stamp per-request budgets onto ``trace`` in place: every request
    gets ``tpot_s``, and a TTFT budget scaled by its prompt length
    relative to the trace max (a 2x-longer prompt has genuinely more
    prefill to wait for, so it earns up to 2x the base budget — still
    deterministic, since prompt lengths are part of the trace)."""
    max_plen = max(len(it.prompt) for it in trace)
    for it in trace:
        scale = 1.0 + len(it.prompt) / max_plen
        it.slo = SLO(ttft_s=ttft_s * scale, tpot_s=tpot_s)


def slo_report(handles, trace: list[OpenLoopItem],
               wall_s: float) -> dict:
    """Score a finished open-loop replay against its SLO annotations.

    Parameters
    ----------
    handles
        Finished ``RequestHandle``s, index-aligned with ``trace`` (the
        order ``_replay`` collected them in — trace order).
    trace
        The items replayed, carrying the per-request budgets.
    wall_s
        Replay wall time; the goodput denominator.

    Returns
    -------
    dict
        ``ttft`` / ``tpot`` percentile summaries (p50/p95/p99 via
        ``api.latency_stats``), ``slo_met`` / ``slo_frac`` (requests
        meeting BOTH budgets), ``goodput_tok_s`` (tokens from meeting
        requests / wall) and ``goodput_frac`` (share of emitted tokens
        that were goodput).
    """
    good_tokens = total_tokens = met = 0
    for h, it in zip(handles, trace):
        total_tokens += len(h.token_ids)
        if h.t_first_token is None:
            continue
        ok = (h.t_first_token - h.t_submit) <= it.slo.ttft_s
        if len(h.t_tokens) >= 2:
            tpot = ((h.t_tokens[-1] - h.t_tokens[0])
                    / (len(h.t_tokens) - 1))
            ok = ok and tpot <= it.slo.tpot_s
        if ok:
            met += 1
            good_tokens += len(h.token_ids)
    out = latency_stats(handles)
    out["slo_met"] = met
    out["count"] = len(handles)
    out["slo_frac"] = met / max(len(handles), 1)
    out["goodput_tok_s"] = good_tokens / max(wall_s, 1e-9)
    out["goodput_frac"] = good_tokens / max(total_tokens, 1)
    return out
