"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (compiled, blocked)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
