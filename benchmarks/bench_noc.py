"""Paper §4 — uncore: NoC/C2C bandwidth table + collective cost model.

Reproduces the paper's fabric arithmetic (64 GB/s per NoC port per
direction at 1 GHz; C2C 8 lanes x 25 Gb/s = 25 GB/s per direction,
20 GB/s demonstrated at bring-up) and evaluates the analytical collective
model this repo uses to attribute the roofline collective term across
the ICI / pod tiers.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import noc


def run():
    # Paper table (§4): exact fabric numbers.
    port_bw = 512 / 8 * 1e9  # 512-bit channel per cycle @ 1 GHz
    emit("noc_port_bw", 0.0,
         f"GBps_per_dir={port_bw / 1e9:.0f};paper=64")
    assert port_bw / 1e9 == noc.EPAC_NOC["noc_port_bw_GBps_per_dir"]
    c2c = 8 * 25e9 / 8  # 8 lanes x 25 Gb/s
    emit("noc_c2c_bw", 0.0,
         f"GBps_per_dir={c2c / 1e9:.0f};aggregate={2 * c2c / 1e9:.0f};"
         f"demonstrated={noc.EPAC_NOC['c2c_bw_GBps_demonstrated'] if 'c2c_bw_GBps_demonstrated' in noc.EPAC_NOC else noc.EPAC_NOC['c2c_demonstrated_GBps']}")
    emit("noc_c2c_saturates_ddr4", 0.0,
         "ddr4_channel_GBps~25.6;c2c_per_dir=25;adequate=True")

    # Collective model across the two tiers (1 GiB per device).
    nbytes = 1 << 30
    for axis, size in (("data", 16), ("model", 16), ("pod", 2)):
        ar = noc.all_reduce_time(nbytes, size, axis)
        ag = noc.all_gather_time(nbytes // size, size, axis)
        rs = noc.reduce_scatter_time(nbytes, size, axis)
        emit(f"noc_collectives_{axis}{size}", 0.0,
             f"all_reduce_ms={ar * 1e3:.1f};all_gather_ms={ag * 1e3:.1f};"
             f"reduce_scatter_ms={rs * 1e3:.1f}")
    # pod tier vs ici tier asymmetry — why DP goes on the pod axis:
    ar_pod = noc.all_reduce_time(nbytes, 2, "pod")
    ar_ici = noc.all_reduce_time(nbytes, 2, "data")
    emit("noc_tier_asymmetry", 0.0,
         f"pod_over_ici={ar_pod / ar_ici:.2f}x;paper_c2c_vs_port="
         f"{64 / 25:.2f}x_slower")

    # L2 slice interleaving (line vs block modes)
    hits = [noc.interleave(a * 64, 8) for a in range(16)]
    emit("noc_l2_interleave_line", 0.0,
         f"slices_touched_16lines={len(set(hits))}/8")


if __name__ == "__main__":
    run()
