"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_vec  — §3.1 VPU cycle model + VLA strip-mining
  bench_stx  — §3.2 stencil/tensor kernels + TCDM/VMEM working sets
  bench_vrp  — §3.3 precision-vs-convergence + precision-vs-cost
  bench_noc  — §4   NoC/C2C bandwidth table + collective model
  bench_lm   — §5   bring-up workloads (DGEMM/STREAM) + LM steps
  bench_serve — serving engine smoke: static vs continuous vs sharded
                vs replicas vs speculative (also writes machine-readable
                BENCH_serve.json; see docs/benchmarks.md for the schema)

Run: PYTHONPATH=src python -m benchmarks.run [section ...]
"""

import argparse

SECTIONS = ("vec", "stx", "vrp", "noc", "lm", "serve")

_SERVE_FLAGS = """\
the `serve` section runs benchmarks/bench_serve.py with its smoke
defaults. Run that module directly for the full knob set:

  --arch ARCH          model config (default olmo_1b; --smoke shrinks it)
  --requests N         requests per trace          --rate R     req/s
  --mem-tokens N       KV cache budget (tokens, shared by all engines)
  --slots N            decode slots (continuous)   --block-size N
  --max-len N          per-sequence position cap   --watermark N
  --tp T               tensor-parallel degree for the `sharded` section
  --dp R               data-parallel replicas for the `replicas` section
  --spec-tokens K      draft tokens per step for the `speculative`
                       section (K+1 positions verified per step)
  --drafter NAME       ngram | draft_model (speculative proposal source)
  --json PATH          machine-readable results (default BENCH_serve.json)

field-by-field JSON schema and CI thresholds: docs/benchmarks.md
"""


def main() -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description=__doc__,
        epilog=_SERVE_FLAGS,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("sections", nargs="*", choices=[[], *SECTIONS],
                    metavar="section",
                    help=f"sections to run (default: all of "
                         f"{', '.join(SECTIONS)})")
    args = ap.parse_args()

    from benchmarks import (bench_lm, bench_noc, bench_serve, bench_stx,
                            bench_vec, bench_vrp)

    modules = {"vec": bench_vec, "stx": bench_stx, "vrp": bench_vrp,
               "noc": bench_noc, "lm": bench_lm, "serve": bench_serve}
    want = args.sections or list(SECTIONS)
    print("name,us_per_call,derived")
    for name in want:
        modules[name].run()


if __name__ == "__main__":
    main()
