"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_vec  — §3.1 VPU cycle model + VLA strip-mining
  bench_stx  — §3.2 stencil/tensor kernels + TCDM/VMEM working sets
  bench_vrp  — §3.3 precision-vs-convergence + precision-vs-cost
  bench_noc  — §4   NoC/C2C bandwidth table + collective model
  bench_lm   — §5   bring-up workloads (DGEMM/STREAM) + LM steps
  bench_serve — serving engine static-vs-continuous smoke (also writes
                machine-readable BENCH_serve.json)

Run: PYTHONPATH=src python -m benchmarks.run [section ...]
"""

import sys


def main() -> None:
    from benchmarks import (bench_lm, bench_noc, bench_serve, bench_stx,
                            bench_vec, bench_vrp)

    sections = {"vec": bench_vec, "stx": bench_stx, "vrp": bench_vrp,
                "noc": bench_noc, "lm": bench_lm, "serve": bench_serve}
    want = sys.argv[1:] or list(sections)
    print("name,us_per_call,derived")
    for name in want:
        sections[name].run()


if __name__ == "__main__":
    main()
