"""Paper §3.1 — VEC tile: VPU timing model + VLA strip-mining efficiency.

Validates the paper's cycle claims (8 FUs x 8 elem/cycle: a 256-element
DP vop retires in 32 + ~3 cycles) and measures the VLA strip-mining
machinery (arbitrary lengths, no scalar tail) on this host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.vec import VecTimingModel, strip_mine


def run():
    m = VecTimingModel()
    # Utilization curve vs vector length — the paper's headline behavior:
    # long vectors amortize the ~3-cycle decode overhead.
    for vl in (8, 32, 64, 128, 256):
        cyc = m.vop_cycles(vl)
        emit(f"vec_vpu_model_vl{vl}", 0.0,
             f"cycles={cyc};util={m.utilization(vl):.3f};"
             f"gflops_dp={m.gflops(vl):.1f}")
    # paper check: 256 elements = 32 compute cycles (+3 decode)
    assert m.vop_cycles(256) == 35
    emit("vec_vpu_model_paper_check", 0.0,
         "vop256=32+3cycles;peak_dp_gflops_per_fu_set="
         f"{m.gflops(256):.1f}")

    # VLA strip-mining on host: throughput vs strip length for an AXPY.
    n = 1 << 20
    x = jnp.arange(n, dtype=jnp.float32)
    for vl in (1024, 8192, 65536):
        fn = jax.jit(lambda v: strip_mine(lambda s: 2.0 * s + 1.0, v, vl))
        us = time_fn(fn, x)
        emit(f"vec_strip_mine_axpy_vl{vl}", us,
             f"n={n};GB/s={(2 * 4 * n) / (us * 1e-6) / 1e9:.2f}")
    # ragged tail correctness at full speed (no scalar fallback)
    odd = x[: n - 37]
    fn = jax.jit(lambda v: strip_mine(lambda s: 2.0 * s + 1.0, v, 8192))
    us = time_fn(fn, odd)
    emit("vec_strip_mine_ragged_tail", us, f"n={n - 37};masked_tail=ok")


if __name__ == "__main__":
    run()
