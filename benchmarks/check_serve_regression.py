"""CI bench-regression gate over BENCH_serve.json.

Compares a fresh serve-bench run against the committed baseline (the
repo-root ``BENCH_serve.json``, regenerated whenever a PR re-runs the
bench) and fails on:

  * continuous tok/s dropping more than ``--tolerance`` (default 20%)
    below baseline. Because CI runners and dev machines differ in raw
    speed, the default comparison is MACHINE-NORMALIZED: continuous
    tok/s divided by the same run's static tok/s — static is the
    lockstep baseline engine on identical hardware in the same process,
    so the ratio cancels host speed and isolates scheduler regressions.
    ``--absolute`` compares raw tok/s instead (same-machine runs).
  * any block leak (``blocks_leaked != 0``) in the continuous, sharded,
    replicas, speculative, shared_prefix or disagg sections (disagg also
    checks its symmetric-baseline run).
  * prefill compile-count growth in the continuous section (the jit
    cache is O(buckets x batch-buckets) by contract; a new trace per
    request length sneaking back in is a regression even when fast).
  * shared_prefix contract breaks: zero hit rate or zero prefill tokens
    saved on the >=75%-shared trace, cached outputs differing from the
    cache-off engine, or the cached-over-uncached speedup dropping more
    than ``--tolerance`` below baseline.
  * disagg contract breaks: disaggregated outputs differing from the
    symmetric ReplicaSet (bit-identity), a run that migrated nothing
    (zero packets or bytes — the subsystem silently off), or the
    TTFT-p95 ratio / wall-speedup vs symmetric drifting more than
    ``--tolerance`` past baseline (both ratios are machine-normalized by
    construction: the two engines run in the same process).
  * workloads contract breaks: MoE or encoder-decoder traffic whose
    co-batched outputs differ from the one-request-at-a-time run of
    the same config (bit-identity), a block or cross-KV-arena row
    leaked in either class, or an enc-dec run that shared no arena
    rows on the repeated-clip trace (identity sharing silently off).
  * quantized-KV contract breaks: the int8 pool converting an equal
    cache byte budget into fewer than 1.8x the bf16 usable blocks
    (the capacity win silently gone), the greedy token match rate vs
    the bf16 run dropping below 0.95, or a leak in either engine of
    the section.
  * open-loop contract breaks: overlap outputs differing from the
    no-overlap run (bit-identity — the RNG-stream contract), zero
    goodput-under-SLO (budgets are calibrated from the same run's
    baseline, so zero means the scheduler starved every request past
    generous budgets), unordered TTFT/TPOT percentiles, a leak in
    either engine, or — against baseline, with the same noise-robust
    clamps as the disagg section — the overlap speedup dropping below
    ``min((1 - tol) * base, 0.95)``, the goodput fraction below
    ``min((1 - tol) * base, 0.5)``, or the p99-TTFT ratio above
    ``max((1 + tol) * base, 1.25)`` (all three machine-normalized by
    construction: both engines run in the same process).

Usage:
  python benchmarks/check_serve_regression.py \
      --baseline BENCH_serve.baseline.json --fresh BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import sys


def check(baseline: dict, fresh: dict, *, tolerance: float,
          absolute: bool) -> list[str]:
    errors = []
    for section in ("continuous", "sharded", "replicas", "speculative",
                    "shared_prefix", "disagg", "quantized",
                    "open_loop"):
        leaked = fresh.get(section, {}).get("blocks_leaked", 0)
        if leaked:
            errors.append(f"{section}: {leaked} blocks leaked")
    if fresh.get("disagg", {}).get("sym_blocks_leaked", 0):
        errors.append("disagg: symmetric baseline run leaked blocks")
    if fresh.get("open_loop", {}).get("base_blocks_leaked", 0):
        errors.append("open_loop: no-overlap baseline run leaked blocks")
    if absolute:
        base_v = baseline["continuous"]["tok_s"]
        fresh_v = fresh["continuous"]["tok_s"]
        kind = "absolute"
    else:
        base_v = baseline["continuous"]["tok_s"] \
            / max(baseline["static"]["tok_s"], 1e-9)
        fresh_v = fresh["continuous"]["tok_s"] \
            / max(fresh["static"]["tok_s"], 1e-9)
        kind = "static-normalized"
    floor = (1.0 - tolerance) * base_v
    print(f"continuous tok_s ({kind}): baseline {base_v:.3f}, "
          f"fresh {fresh_v:.3f}, floor {floor:.3f}")
    if fresh_v < floor:
        errors.append(
            f"continuous tok_s regressed >{tolerance:.0%}: "
            f"{fresh_v:.3f} < {floor:.3f} ({kind} vs baseline "
            f"{base_v:.3f})")
    base_c = baseline["continuous"]["prefill_compiles"]
    fresh_c = fresh["continuous"]["prefill_compiles"]
    print(f"continuous prefill_compiles: baseline {base_c}, "
          f"fresh {fresh_c}")
    if fresh_c > base_c:
        errors.append(
            f"prefill compile count grew: {fresh_c} > baseline {base_c}")
    # speculative decode: the speedup over non-speculative paged decode
    # is already machine-normalized (both engines run in this process),
    # so it is compared directly. Skipped when the baseline predates
    # the section.
    if "speculative" in baseline and "speculative" in fresh:
        base_s = baseline["speculative"]["speedup_vs_paged"]
        fresh_s = fresh["speculative"]["speedup_vs_paged"]
        floor_s = (1.0 - tolerance) * base_s
        print(f"speculative speedup_vs_paged: baseline {base_s:.3f}, "
              f"fresh {fresh_s:.3f}, floor {floor_s:.3f}")
        if fresh_s < floor_s:
            errors.append(
                f"speculative speedup regressed >{tolerance:.0%}: "
                f"{fresh_s:.3f} < {floor_s:.3f} (baseline {base_s:.3f})")
        if fresh["speculative"]["accepted"] <= 0:
            errors.append("speculative section accepted no drafts — "
                          "the drafter or accept rule is broken")
    # prefix cache: the shared-prefix trace must actually HIT (rate,
    # saved prefill volume), must not change emitted tokens, and its
    # cached-over-uncached speedup (machine-normalized by construction:
    # both engines run in this process) must hold within tolerance.
    # Skipped when the baseline predates the section.
    if "shared_prefix" in fresh:
        px = fresh["shared_prefix"]
        print(f"shared_prefix: hit_rate {px['hit_rate']:.3f}, "
              f"prefill_tokens_saved {px['prefill_tokens_saved']}, "
              f"outputs_match {px['outputs_match']}")
        if px["hit_rate"] <= 0:
            errors.append("shared_prefix: hit rate is 0 — the prefix "
                          "index matched nothing on a >=75%-shared trace")
        if px["prefill_tokens_saved"] <= 0:
            errors.append("shared_prefix: no prefill tokens saved — "
                          "cache hits are not shrinking admission work")
        if not px["outputs_match"]:
            errors.append("shared_prefix: cached outputs differ from "
                          "the cache-off engine (bit-identity broken)")
        if "shared_prefix" in baseline:
            base_x = baseline["shared_prefix"]["speedup_vs_uncached"]
            fresh_x = px["speedup_vs_uncached"]
            floor_x = (1.0 - tolerance) * base_x
            print(f"shared_prefix speedup_vs_uncached: baseline "
                  f"{base_x:.3f}, fresh {fresh_x:.3f}, "
                  f"floor {floor_x:.3f}")
            if fresh_x < floor_x:
                errors.append(
                    f"shared_prefix speedup regressed >{tolerance:.0%}: "
                    f"{fresh_x:.3f} < {floor_x:.3f} "
                    f"(baseline {base_x:.3f})")
    # disaggregation: migration must be live and token-invisible, and
    # the two comparisons against the in-process symmetric ReplicaSet
    # (TTFT p95 ratio, wall speedup) must hold within tolerance of the
    # committed baseline. Skipped when the baseline predates the
    # section.
    if "disagg" in fresh:
        dg = fresh["disagg"]
        print(f"disagg: tok_s {dg['tok_s']:.1f} vs symmetric "
              f"{dg['sym_tok_s']:.1f} (x{dg['speedup_wall']:.3f}), "
              f"ttft_p95_ratio {dg['ttft_p95_ratio']:.3f}, "
              f"packets {dg['packets']}, "
              f"outputs_match {dg['outputs_match']}")
        if not dg["outputs_match"]:
            errors.append("disagg: outputs differ from the symmetric "
                          "ReplicaSet (bit-identity broken)")
        if dg["packets"] <= 0 or dg["bytes_moved"] <= 0:
            errors.append("disagg: no KV blocks migrated — the "
                          "prefill/decode split is silently inactive")
        if "disagg" in baseline:
            # TTFT percentiles on a time-shared CPU host are noisy run
            # to run, so a strong committed baseline must not make the
            # gate flaky: the ceiling never drops below 1.0 — only a
            # run where disagg is outright WORSE than symmetric (and
            # past tolerance) fails.
            base_r = baseline["disagg"]["ttft_p95_ratio"]
            ceil_r = max((1.0 + tolerance) * base_r, 1.0)
            print(f"disagg ttft_p95_ratio: baseline {base_r:.3f}, "
                  f"fresh {dg['ttft_p95_ratio']:.3f}, "
                  f"ceiling {ceil_r:.3f}")
            if dg["ttft_p95_ratio"] > ceil_r:
                errors.append(
                    f"disagg TTFT p95 vs symmetric worsened "
                    f">{tolerance:.0%}: {dg['ttft_p95_ratio']:.3f} > "
                    f"{ceil_r:.3f} (baseline {base_r:.3f})")
            # same noise argument, floor side: never demand more than
            # 0.9x symmetric wall throughput regardless of how good
            # the committed baseline run happened to be
            base_w = baseline["disagg"]["speedup_wall"]
            floor_w = min((1.0 - tolerance) * base_w, 0.9)
            print(f"disagg speedup_wall: baseline {base_w:.3f}, "
                  f"fresh {dg['speedup_wall']:.3f}, floor {floor_w:.3f}")
            if dg["speedup_wall"] < floor_w:
                errors.append(
                    f"disagg wall speedup vs symmetric regressed "
                    f">{tolerance:.0%}: {dg['speedup_wall']:.3f} < "
                    f"{floor_w:.3f} (baseline {base_w:.3f})")
    # workload classes: MoE and enc-dec must stay bit-identical to a
    # one-at-a-time replay (co-batching invariance), leak nothing from
    # the block pool or the cross-KV arena, and the repeated-clip
    # enc-dec trace must actually share arena rows. All in-process
    # invariants, no baseline ratio — skipped only when the fresh run
    # predates the section.
    if "workloads" in fresh:
        for cls in ("moe", "encdec"):
            w = fresh["workloads"][cls]
            print(f"workloads/{cls}: tok_s {w['tok_s']:.1f} "
                  f"(x{w['cobatch_speedup']:.2f} vs sequential), "
                  f"outputs_match {w['outputs_match']}")
            if not w["outputs_match"]:
                errors.append(
                    f"workloads/{cls}: co-batched outputs differ from "
                    "the sequential run (bit-identity broken)")
            if w["blocks_leaked"] or w["seq_blocks_leaked"]:
                errors.append(f"workloads/{cls}: blocks leaked")
        enc = fresh["workloads"]["encdec"]
        if enc["arena_rows_leaked"]:
            errors.append("workloads/encdec: cross-KV arena rows "
                          "leaked")
        if enc["arena_shared_hits"] <= 0:
            errors.append("workloads/encdec: no arena rows shared on "
                          "the repeated-clip trace — feature-identity "
                          "sharing is silently off")
    # quantized KV: the capacity claim and the quality floor are both
    # in-process invariants (the bf16 comparison engine runs alongside),
    # no baseline ratio needed. Skipped only when the fresh run
    # predates the section.
    if "quantized" in fresh:
        q = fresh["quantized"]
        print(f"quantized ({q['kv_dtype']}): capacity_ratio "
              f"{q['capacity_ratio']:.3f}, match_rate "
              f"{q['match_rate']:.4f}, tok_s {q['tok_s']:.1f} vs bf16 "
              f"{q['bf16_tok_s']:.1f}")
        if q["capacity_ratio"] < 1.8:
            errors.append(
                f"quantized: capacity ratio {q['capacity_ratio']:.3f} "
                "< 1.8 at equal cache bytes — the int8 pool is not "
                "converting the byte budget into blocks")
        if q["match_rate"] < 0.95:
            errors.append(
                f"quantized: greedy token match rate "
                f"{q['match_rate']:.4f} < 0.95 vs the bf16 engine — "
                "quantization error is changing outputs beyond the gate")
        if q["bf16_blocks_leaked"]:
            errors.append("quantized: bf16 comparison run leaked blocks")
    # open loop: bit-identity across the overlap toggle, live goodput,
    # ordered percentiles (raw invariants, both engines run in this
    # process), and three baseline-relative ratios with the same
    # noise-robustness discipline as the disagg clamps: a strong
    # committed baseline must never make the gate flaky, so the floors
    # and ceiling saturate at fixed "outright broken" thresholds.
    if "open_loop" in fresh:
        ol = fresh["open_loop"]
        print(f"open_loop ({ol['kind']}): overlap tok_s "
              f"{ol['tok_s']:.1f} vs base {ol['base_tok_s']:.1f} "
              f"(x{ol['overlap_speedup']:.3f}), goodput "
              f"{ol['goodput_tok_s']:.1f} tok/s "
              f"(frac {ol['goodput_frac']:.3f}), ttft_p99_ratio "
              f"{ol['ttft_p99_ratio']:.3f}, outputs_match "
              f"{ol['outputs_match']}")
        if not ol["outputs_match"]:
            errors.append("open_loop: overlap outputs differ from the "
                          "no-overlap run (bit-identity broken)")
        if ol["goodput_tok_s"] <= 0:
            errors.append("open_loop: zero goodput under SLO — every "
                          "request blew a budget calibrated from this "
                          "run's own baseline")
        for metric in ("ttft", "tpot"):
            p = ol["slo"][metric]
            if not (p["p50_s"] <= p["p95_s"] <= p["p99_s"]):
                errors.append(f"open_loop: {metric} percentiles are "
                              f"unordered ({p['p50_s']:.6f} / "
                              f"{p['p95_s']:.6f} / {p['p99_s']:.6f})")
        if "open_loop" in baseline:
            base_v = baseline["open_loop"]["overlap_speedup"]
            floor_v = min((1.0 - tolerance) * base_v, 0.95)
            print(f"open_loop overlap_speedup: baseline {base_v:.3f}, "
                  f"fresh {ol['overlap_speedup']:.3f}, "
                  f"floor {floor_v:.3f}")
            if ol["overlap_speedup"] < floor_v:
                errors.append(
                    f"open_loop overlap speedup regressed "
                    f">{tolerance:.0%}: {ol['overlap_speedup']:.3f} < "
                    f"{floor_v:.3f} (baseline {base_v:.3f})")
            base_g = baseline["open_loop"]["goodput_frac"]
            floor_g = min((1.0 - tolerance) * base_g, 0.5)
            print(f"open_loop goodput_frac: baseline {base_g:.3f}, "
                  f"fresh {ol['goodput_frac']:.3f}, "
                  f"floor {floor_g:.3f}")
            if ol["goodput_frac"] < floor_g:
                errors.append(
                    f"open_loop goodput fraction regressed "
                    f">{tolerance:.0%}: {ol['goodput_frac']:.3f} < "
                    f"{floor_g:.3f} (baseline {base_g:.3f})")
            base_t = baseline["open_loop"]["ttft_p99_ratio"]
            ceil_t = max((1.0 + tolerance) * base_t, 1.25)
            print(f"open_loop ttft_p99_ratio: baseline {base_t:.3f}, "
                  f"fresh {ol['ttft_p99_ratio']:.3f}, "
                  f"ceiling {ceil_t:.3f}")
            if ol["ttft_p99_ratio"] > ceil_t:
                errors.append(
                    f"open_loop p99 TTFT vs no-overlap worsened "
                    f">{tolerance:.0%}: {ol['ttft_p99_ratio']:.3f} > "
                    f"{ceil_t:.3f} (baseline {base_t:.3f})")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional tok/s drop (default 0.20)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw tok/s instead of the "
                         "static-normalized ratio (same-machine runs)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    errors = check(baseline, fresh, tolerance=args.tolerance,
                   absolute=args.absolute)
    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        sys.exit(1)
    print("serve bench regression gate: ok")


if __name__ == "__main__":
    main()
