"""Paper §3.2 — STX tile: stencil + tensor kernels.

The paper's numbers: 4 clusters x 8 cores x 2 DP FLOP/cycle @ 1 GHz =
64 GFLOPS per tile; high FPU utilization on ML workloads. Here: the
Pallas kernels' modeled MXU utilization (from BlockSpec working sets) +
host-measured interpret/ref timings for the same math, plus the
correctness gate vs the jnp oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run():
    # Paper tile model
    clusters, cores, flops_cyc, ghz = 4, 8, 2, 1.0
    emit("stx_paper_tile_model", 0.0,
         f"peak_dp_gflops={clusters * cores * flops_cyc * ghz}")

    rng = np.random.default_rng(0)
    # Tensor op: matmul through the VEC (XLA) path vs kernel working-set
    for size in (256, 512, 1024):
        x = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        fn = jax.jit(lambda a, b: ref.matmul(a, b))
        us = time_fn(fn, x, w)
        gflops = 2 * size**3 / (us * 1e-6) / 1e9
        emit(f"stx_matmul_xla_{size}", us, f"host_gflops={gflops:.1f}")
    # Kernel working set (the VMEM/TCDM budget claim):
    bm = bn = bk = 128
    ws_kb = (bm * bk + bk * bn + bm * bn) * 4 / 1024
    emit("stx_matmul_kernel_working_set", 0.0,
         f"block=128x128x128;vmem_kb={ws_kb:.0f};paper_tcdm_kb=64-256")

    # Stencil: 5-point 2-D and 7-point 3-D (diffusion step)
    x2 = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    w5 = ref.five_point_weights()
    us = time_fn(jax.jit(lambda a: ref.stencil2d(a, w5)), x2)
    pts = 512 * 512
    emit("stx_stencil2d_5pt_512", us,
         f"Mpts/s={pts / (us * 1e-6) / 1e6:.1f}")
    x3 = jnp.asarray(rng.normal(size=(64, 128, 128)), jnp.float32)
    w7 = ref.seven_point_weights()
    us = time_fn(jax.jit(lambda a: ref.stencil3d(a, w7)), x3)
    pts = 64 * 128 * 128
    emit("stx_stencil3d_7pt_64x128x128", us,
         f"Mpts/s={pts / (us * 1e-6) / 1e6:.1f};flops_per_pt=13")

    # Correctness gate (interpret kernel vs oracle) — small shape
    xs = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    out = ops.stencil2d(xs, w5, block_m=32, block_n=32, mode="interpret")
    err = float(jnp.max(jnp.abs(out - ref.stencil2d(xs, w5))))
    emit("stx_stencil_kernel_allclose", 0.0, f"max_err={err:.1e}")
    assert err < 1e-5


if __name__ == "__main__":
    run()
