"""Bring-up workloads (§5): DGEMM + STREAM analogues and an end-to-end LM
step through all three tiles — the EPAC validation sequence, on this
framework (the chip ran vectorized DGEMM/Stream, then booted Linux and
ran long HPC jobs; we run the LM train/serve steps that are this
framework's "long jobs")."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import Model
from repro.models.transformer import RunCtx
from repro.optim import OptConfig
from repro.launch.train import init_state, make_train_step
from repro.optim.schedule import constant
import functools


def run():
    rng = np.random.default_rng(0)
    # DGEMM (the bring-up benchmark) via the VEC/XLA tile, f64
    n = 512
    a = jnp.asarray(rng.normal(size=(n, n)))
    b = jnp.asarray(rng.normal(size=(n, n)))
    us = time_fn(jax.jit(lambda x, y: x @ y), a, b)
    emit("bringup_dgemm_512_f64", us,
         f"gflops={2 * n**3 / (us * 1e-6) / 1e9:.1f}")
    # STREAM triad
    m = 1 << 22
    x = jnp.asarray(rng.normal(size=m), jnp.float32)
    y = jnp.asarray(rng.normal(size=m), jnp.float32)
    us = time_fn(jax.jit(lambda xx, yy: xx + 3.0 * yy), x, y)
    emit("bringup_stream_triad", us,
         f"GB/s={3 * 4 * m / (us * 1e-6) / 1e9:.1f}")

    # End-to-end LM steps (smoke-scale olmo; full configs live in dry-run)
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    ctx = RunCtx(kernel_mode="ref")
    opt_cfg = OptConfig()
    state = init_state(model, opt_cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))
    step = jax.jit(make_train_step(model, opt_cfg, ctx,
                                   functools.partial(constant, peak_lr=1e-3)))
    batch = data.batch_at(0)
    us = time_fn(lambda s, bb: step(s, bb)[0], state, batch, iters=5)
    toks = 8 * 64
    emit("lm_train_step_olmo_smoke", us,
         f"tokens_per_s={toks / (us * 1e-6):.0f}")

    params = state["params"]
    B, S = 4, 32
    pbatch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    pre = jax.jit(lambda p, bb: model.prefill(p, bb, ctx, max_len=S + 16))
    us = time_fn(pre, params, pbatch, iters=5)
    emit("lm_prefill_olmo_smoke", us, f"tokens={B * S}")
    _, cache = pre(params, pbatch)
    dec = jax.jit(lambda p, c, t: model.decode_step(p, c, t, jnp.int32(S),
                                                    ctx))
    tok = pbatch["tokens"][:, :1]
    us = time_fn(dec, params, cache, tok, iters=10)
    emit("lm_decode_step_olmo_smoke", us,
         f"tokens_per_s={B / (us * 1e-6):.0f}")


if __name__ == "__main__":
    run()
