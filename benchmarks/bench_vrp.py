"""Paper §3.3 — VRP tile: precision-vs-convergence and precision-vs-cost.

Reproduces the central VRP claims (refs [19][20]): on ill-conditioned
systems, raising the working precision (a) reduces CG iterations and
(b) raises the attainable solution accuracy — selected at runtime via the
PrecisionEnv (environment-register analogue), no recompilation of the
solver call site. Also: op latency scaling with the chunk count K (the
paper's "latency and throughput scale with the selected precision").
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import solvers, vrp
from repro.core.precision import F64, VP128, VP256, PRESETS


def run():
    # (a) iterations-to-converge vs precision (Hilbert matrix, cond~1.7e16)
    n = 12
    A = solvers.hilbert(n)
    b = A @ jnp.ones(n)
    for name in ("f64", "vp128", "vp256"):
        env = PRESETS[name]
        res = solvers.cg(A, b, env, tol=1e-13, maxiter=400)
        emit(f"vrp_cg_hilbert{n}_{name}", 0.0,
             f"iters={int(res.iterations)};converged={bool(res.converged)};"
             f"relres={float(res.residual):.2e};"
             f"significand_bits={env.significand_bits}")

    # (b) attainable accuracy/iterations with an extended-precision RHS
    m = 24
    Am = solvers.hilbert_like(m, cond=1e6, seed=1)
    env = VP256
    xs = vrp.from_float(jnp.ones(m), env)
    bE = vrp.tree_sum(vrp.mul(vrp.from_float(Am, env), xs[None], env), env,
                      axis=1)
    r64 = solvers.cg(Am, vrp.to_float(bE), F64, tol=1e-24, maxiter=600)
    rvp = solvers.cg(Am, bE[:, :2], PRESETS["vp128"], tol=1e-24, maxiter=600)
    emit("vrp_cg_cond1e6_f64", 0.0,
         f"iters={int(r64.iterations)};"
         f"xerr={float(jnp.max(jnp.abs(r64.x - 1.0))):.2e}")
    emit("vrp_cg_cond1e6_vp128", 0.0,
         f"iters={int(rvp.iterations)};"
         f"xerr={float(jnp.max(jnp.abs(rvp.x - 1.0))):.2e}")

    # (c) op cost vs chunk count K (paper: latency scales with precision)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=4096))
    y = jnp.asarray(rng.normal(size=4096))
    base = None
    for name in ("f64", "vp128", "vp256", "vp512"):
        env = PRESETS[name]
        fn = jax.jit(lambda a, bb, e=env: vrp.dot(a, bb, e))
        us = time_fn(fn, x, y)
        base = base or us
        emit(f"vrp_dot4096_{name}", us,
             f"K={env.K};slowdown_vs_f64={us / base:.2f}x")

    # (d) BiCGStab stabilization (ref [20])
    rng = np.random.default_rng(4)
    m = 24
    M = jnp.asarray(np.eye(m) * 4 + rng.normal(size=(m, m)) * 0.3)
    xstar = jnp.asarray(rng.normal(size=m))
    res = solvers.bicgstab(M, M @ xstar, VP128, tol=1e-11, maxiter=200)
    emit("vrp_bicgstab_vp128", 0.0,
         f"iters={int(res.iterations)};converged={bool(res.converged)}")


if __name__ == "__main__":
    run()
