"""Serving benchmark: static vs continuous batching under Poisson arrivals.

Replays one request trace — Poisson inter-arrival times, ragged prompts,
skewed output lengths (many short responses, a few long stragglers) —
through both engines in launch/serve.py:

* static  — lockstep batcher: wait for a full batch (or queue drain),
  prefill, decode every sequence to the batch's max target length, keep
  only each request's first ``max_new`` tokens. Cache is a dense
  (B, max_len) slab per batch regardless of actual lengths.
* continuous — the paged-cache Scheduler: per-slot retirement + admission
  mid-flight, block-granular cache occupancy.

The comparison is at EQUAL CACHE MEMORY (--mem-tokens of KV capacity):
the static engine must preallocate max_len per lane, so its batch is
``mem // max_len``; the paged engine spends the same tokens of pool on
whatever mix of live sequences fits, so it runs more lanes concurrently
(vLLM's core claim, and the tensor-level version of EPAC's interleaved
L2 slices vs per-core private allocation).

Reported per engine: useful tokens/s (only requested tokens count — the
static engine's overshoot decode steps are pure waste) and cache memory
utilization (live tokens / allocated token capacity, averaged over decode
steps). On a skewed trace continuous batching wins both: retired slots
stop burning decode steps, and freed blocks admit queued requests early.

Run: PYTHONPATH=src python benchmarks/bench_serve.py --smoke
CSV:  name,us_per_call,derived  (via benchmarks/common.py emit discipline)
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import (Scheduler, SchedulerConfig, ServeConfig,
                                Server)
from repro.models.model import Model


@dataclasses.dataclass
class TraceItem:
    arrival: float              # seconds since trace start
    prompt: list[int]
    max_new: int


def make_trace(cfg, *, n_requests: int, rate: float, seed: int,
               prompt_lens=(8, 12, 16), n_new_max: int = 64):
    """Poisson arrivals; skewed (mostly-short) output-length distribution.

    The skew is the point: a lockstep batch decodes every member to the
    batch max, so one straggler holds ~B-1 finished lanes hostage."""
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.choice(prompt_lens))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        max_new = int(rng.choice([4, 6, 8, n_new_max],
                                 p=[0.45, 0.25, 0.2, 0.1]))
        trace.append(TraceItem(t, prompt, max_new))
    return trace


def _wait_until(t0: float, arrival: float):
    dt = t0 + arrival - time.time()
    if dt > 0:
        time.sleep(dt)


def run_static(model, params, trace, *, batch: int, max_len: int):
    """Lockstep batching: group arrivals into fixed batches; every batch
    decodes to its max target length."""
    server = Server(model, params, ServeConfig(batch_size=batch,
                                               max_len=max_len))
    # warmup compiles outside the timed region (both engines get this):
    # one prefill per distinct padded prompt length in the trace
    for plen in sorted({max(len(r.prompt) for r in trace[i:i + batch])
                        for i in range(0, len(trace), batch)}):
        server.generate([trace[0].prompt[:1] * plen], 1)
    t0 = time.time()
    useful = 0
    live_token_steps = 0
    cap_token_steps = 0
    i = 0
    while i < len(trace):
        group = trace[i:i + batch]
        _wait_until(t0, group[-1].arrival)       # batch forms on last arrival
        n_new = max(r.max_new for r in group)
        outs = server.generate([r.prompt for r in group], n_new)
        useful += sum(min(len(o), r.max_new) for o, r in zip(outs, group))
        # dense cache slab: batch x max_len capacity for n_new steps
        cap_token_steps += batch * max_len * n_new
        for t in range(n_new):
            live_token_steps += sum(min(len(r.prompt) + t + 1,
                                        len(r.prompt) + r.max_new)
                                    for r in group)
        i += batch
    dt = time.time() - t0
    return {"tok_s": useful / dt, "useful": useful, "wall_s": dt,
            "cache_util": live_token_steps / max(cap_token_steps, 1)}


def run_continuous(model, params, trace, *, slots: int, block_size: int,
                   num_blocks: int, max_len: int):
    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=slots, block_size=block_size,
                                      num_blocks=num_blocks,
                                      max_len=max_len))
    # warmup: compile decode + the trace's prefill lengths on the engine
    # itself (a second Scheduler would double the pool memory the
    # benchmark claims to budget), then reset telemetry
    seen = set()
    for r in trace:
        if len(r.prompt) not in seen:
            seen.add(len(r.prompt))
            sched.submit(list(r.prompt), 1)
    sched.run()
    sched.finished.clear()
    sched.steps = sched.slot_steps = 0
    sched.block_token_steps = sched.live_token_steps = 0
    t0 = time.time()
    pending = list(trace)
    while pending or sched.has_work:
        now = time.time() - t0
        while pending and pending[0].arrival <= now:
            r = pending.pop(0)
            sched.submit(r.prompt, r.max_new)
        if sched.has_work:
            sched.step()
        elif pending:
            _wait_until(t0, pending[0].arrival)
    dt = time.time() - t0
    useful = sum(len(r.out) for r in sched.finished)
    st = sched.stats()
    return {"tok_s": useful / dt, "useful": useful, "wall_s": dt,
            "cache_util": st["cache_utilization"],
            "mean_active": st["mean_active_slots"],
            "blocks_leaked": st["blocks_used"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--mem-tokens", type=int, default=512,
                    help="KV cache capacity in tokens, shared budget")
    ap.add_argument("--slots", type=int, default=16,
                    help="decode slots for the continuous engine")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = make_trace(cfg, n_requests=args.requests, rate=args.rate,
                       seed=args.seed)

    static_batch = max(args.mem_tokens // args.max_len, 1)
    res_s = run_static(model, params, trace, batch=static_batch,
                       max_len=args.max_len)
    res_c = run_continuous(model, params, trace, slots=args.slots,
                           block_size=args.block_size,
                           num_blocks=args.mem_tokens // args.block_size + 1,
                           max_len=args.max_len)

    print("name,tok_s,cache_util,useful_tokens,wall_s")
    print(f"serve_static,{res_s['tok_s']:.2f},{res_s['cache_util']:.3f},"
          f"{res_s['useful']},{res_s['wall_s']:.2f}")
    print(f"serve_continuous,{res_c['tok_s']:.2f},"
          f"{res_c['cache_util']:.3f},{res_c['useful']},"
          f"{res_c['wall_s']:.2f}")
    speedup = res_c["tok_s"] / max(res_s["tok_s"], 1e-9)
    print(f"# equal cache budget {args.mem_tokens} tokens: static "
          f"batch {static_batch}, continuous {args.slots} slots; "
          f"continuous/static tokens/s: {speedup:.2f}x; "
          f"mean active slots {res_c['mean_active']:.2f}/{args.slots}; "
          f"blocks leaked {res_c['blocks_leaked']}")
    if res_c["blocks_leaked"]:
        raise SystemExit("block leak detected")


if __name__ == "__main__":
    main()
