"""Serving benchmark: static vs continuous batching under Poisson arrivals.

Replays one request trace — Poisson inter-arrival times, ragged prompts,
skewed output lengths (many short responses, a few long stragglers) —
through both backends of the unified serving ``Engine``
(repro.launch.engine):

* static     — lockstep batcher: right-padded batched prefill, decode
  every batch until its last member finishes. Dense (B, max_len) cache
  slab regardless of actual lengths.
* continuous — the paged backend: per-slot retirement + optimistic
  admission mid-flight, LIFO preemption under pool pressure, bucketed
  prefill, block-granular cache occupancy.
* sharded    — the same paged backend over a (data, model) mesh of the
  local devices (``--tp`` picks the model-axis degree): params sharded
  by the 2-D FSDP x TP rules, the block pool head-sharded (each device
  owns its kv-head shard of every block). Emits mesh shape, whether the
  head-shard shard_map path was active, and per-device resident cache
  bytes + utilization. Run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise a
  real multi-device mesh on CPU (the CI multi-device job does).
* replicas   — ``--dp`` data-parallel paged replicas behind ONE shared
  admission queue (ReplicaSet, least-loaded-blocks dispatch), each
  replica on its own data-axis submesh with its own KV pool, against a
  single replica of the identical per-replica config on the same
  (heavier, dp-scaled) trace. Emits aggregate tok/s, the speedup over
  one replica, per-replica utilization/dispatch share, and shared-queue
  wait — the fixed-per-replica scale-out story (EPAC: more tiles behind
  the same hub).

A sixth section, ``shared_prefix``, replays a saturated trace of
prompts sharing one long system prefix through the paged backend with
the COW prefix cache off and on: hit rate, prefill tokens saved, COW
copies, and a bit-identity check between the two runs (outputs_match).

A seventh section, ``disagg``, replays a mixed-prompt-length trace
through a symmetric ``ReplicaSet`` and a ``DisaggregatedEngine`` of the
same ``--dp`` at identical per-replica config (equal total cache
memory): prefill/decode role specialization vs everyone-does-both.
Reports wall tok/s and TTFT p50/p95 for both (long co-resident prefills
are exactly the interference TTFT p95 measures), migration volume
(packets, bytes, estimated fabric seconds via ``core.noc.p2p_time``)
and a bit-identity check (outputs_match). Every section now carries a
``ttft`` sub-dict computed from per-request submit/first-token stamps.

An eighth section, ``workloads``, prices the two NON-dense request
classes the one Engine serves: dropless MoE (qwen3-moe smoke) and
encoder-decoder traffic (whisper smoke — ``Request.encoder_features``
through the cross-KV arena), each at the same ``--mem-tokens`` budget
as the dense sections, each replayed co-batched and again
one-request-at-a-time at identical cache config. Reports per-class
tok/s, the co-batching speedup, arena sharing/leak telemetry, and the
bit-identity check (outputs_match) between the two replays — the
workload-generalization contract tests/test_workload_serve.py pins.

A ninth section, ``quantized``, prices the int8 paged KV pool
(per-(token, kv-head) scales, dequant fused into the kernels —
``EngineConfig(kv_dtype="int8")``) against the bf16 pool at equal cache
BYTE budget on a head_dim=64 smoke variant: usable-block capacity
ratio, tok/s for both, and the greedy token match rate vs the bf16 run
(floor-gated by benchmarks/check_serve_regression.py).

A tenth section, ``open_loop``, replays a deterministic open-loop
arrival trace (benchmarks/traffic.py — seeded Poisson arrivals,
decode-heavy output lengths, per-request TTFT/TPOT budgets) through
the paged backend with ``EngineConfig(overlap=)`` OFF and ON at equal
config: the overlap run dispatches step N+1's fused device call before
fetching step N's sampled tokens. Reports TTFT/TPOT p50/p95/p99,
goodput-under-SLO (token throughput of budget-meeting requests, with
budgets calibrated from the measured baseline so CI machine speed
cannot zero it), the overlap speedup, the p99-TTFT ratio, and the
bit-identity check (outputs_match — raw-asserted at JSON write: the
RNG-stream contract says overlap may change WHEN tokens are fetched
but never WHICH tokens come out).

The comparison is at EQUAL CACHE MEMORY (--mem-tokens of KV capacity):
the static engine must preallocate max_len per lane, so its batch is
``mem // max_len``; the paged engine spends the same tokens of pool on
whatever mix of live sequences fits (vLLM's core claim, and the
tensor-level version of EPAC's interleaved L2 slices vs per-core
private allocation).

Reported per engine: useful tokens/s, cache memory utilization (live
tokens / allocated token capacity, averaged over decode steps), lane
efficiency (useful tokens per slot-step — the scheduling win, hardware
independent), plus the paged engine's preemption count and prefill
compile count. Results go to stdout as CSV (benchmarks/common.py
discipline) AND to a machine-readable ``BENCH_serve.json`` so the perf
trajectory is trackable across PRs.

Warmup matters: the first token of a request is sampled at prefill, so
warmup requests use max_tokens=2 — with 1, the decode step would first
compile inside the timed region and dominate the wall times.

Run: PYTHONPATH=src python benchmarks/bench_serve.py --smoke
CSV:  name,us_per_call,derived  (via benchmarks/common.py emit discipline)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.launch.engine.api import latency_stats
from repro.models.model import Model


@dataclasses.dataclass
class TraceItem:
    arrival: float              # seconds since trace start
    prompt: list[int]
    max_new: int


def make_trace(cfg, *, n_requests: int, rate: float, seed: int,
               prompt_lens=(8, 12, 16), n_new_max: int = 64):
    """Poisson arrivals; skewed (mostly-short) output-length distribution.

    The skew is the point: a lockstep batch decodes every member to the
    batch max, so one straggler holds ~B-1 finished lanes hostage."""
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.choice(prompt_lens))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        max_new = int(rng.choice([4, 6, 8, n_new_max],
                                 p=[0.45, 0.25, 0.2, 0.1]))
        trace.append(TraceItem(t, prompt, max_new))
    return trace


def make_repetitive_trace(cfg, *, n_requests: int, seed: int,
                          n_new: int = 64):
    """Decode-heavy OFFLINE trace of REPETITIVE prompts (short patterns
    tiled) for the speculative section: prompt-lookup drafting keys on
    exactly this structure (templated prose / code), long fixed outputs
    put the weight on the decode loop speculation accelerates, and
    arrival=0 for every request keeps the engine saturated — the
    decode-throughput regime the K-token window is a lever for (the
    Poisson traces above measure admission behavior instead)."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        pat = list(rng.integers(0, cfg.vocab_size, int(rng.integers(2, 5))))
        plen = int(rng.integers(9, 17))
        trace.append(TraceItem(0.0, (pat * plen)[:plen], n_new))
    return trace


def make_shared_prefix_trace(cfg, *, n_requests: int, seed: int,
                             shared: int = 48, unique: int = 8,
                             n_new: int = 8):
    """Offline trace of prompts sharing one long system prefix (~86%
    of prompt tokens shared at the default 48+8 split) — the regime
    COW prefix caching targets: thousands of requests re-prefilling the
    same system prompt. Arrival 0 for every request keeps the queue
    saturated so admission cost (the thing caching removes) dominates
    the prefill side of the measurement."""
    rng = np.random.default_rng(seed)
    common = list(rng.integers(0, cfg.vocab_size, shared))
    return [TraceItem(0.0,
                      common + list(rng.integers(0, cfg.vocab_size,
                                                 unique)),
                      n_new)
            for _ in range(n_requests)]


def _wait_until(t0: float, arrival: float):
    dt = t0 + arrival - time.time()
    if dt > 0:
        time.sleep(dt)


def _warm(engine, trace):
    """Warm the jit caches on the engine itself (a second engine would
    double the pool memory the benchmark claims to budget)."""
    # max_tokens=2, not 1: the first token is sampled at prefill, so a
    # 1-token request retires without ever compiling the decode step.
    # Beyond the trace's prompt lengths, also warm every power-of-two
    # bucket up to max_len: preemption-resume re-prefills land at
    # prompt+emitted-1 tokens, which can hit buckets no prompt started
    # in — those compiles must not fall inside the timed region.
    warm = {len(r.prompt) for r in trace}
    b = 2
    while b < engine.cfg.max_len * 2:     # include the TOP bucket
        warm.add(min(b, engine.cfg.max_len - 2))
        b *= 2
    # the paged backend traces one prefill jit per (bucket, batch-
    # bucket) pair — warm every power-of-two batch width per bucket
    # (splits under pool pressure just warm the smaller widths, which
    # the replay is equally limited to); the static backend keys on
    # bucket alone, so extra widths would warm nothing
    widths = [1]
    if hasattr(engine.backend, "alloc"):
        while widths[-1] * 2 <= engine.cfg.num_slots:
            widths.append(widths[-1] * 2)
    vocab = engine.backend.model.cfg.vocab_size
    c = 1
    for plen in sorted(warm):
        for nb in widths:
            # DISTINCT rows: identical probe rows would prefix-hit each
            # other on cache-on engines and the (bucket, width) FULL-
            # prefill trace this pass exists to compile would first
            # trace inside the timed region
            batch = []
            for _ in range(nb):
                pat = [c % vocab, (c // vocab) % vocab]
                batch.append((pat * plen)[:plen])
                c += 1
            try:
                engine.generate(batch, SamplingParams(max_tokens=2))
            except ValueError:
                # tiny pools reject the top-bucket probe's worst case at
                # admission — a length no real request can use either,
                # so there is nothing to warm there
                break
    if getattr(engine.backend, "prefix", None) is None:
        return
    # prefix-cache engines take two more admission paths the replay
    # must not compile mid-measurement: full-hit installs (the COW jit
    # on the first decode) and suffix-only prefills (one trace per
    # power-of-two suffix bucket). ONE shared probe prompt across all
    # lengths produces exactly those: the first call per length misses
    # (already-warm full prefill) and registers, repeats full-hit, and
    # each longer length suffix-prefills from the previous one.
    base = trace[0].prompt[:1] * (engine.cfg.max_len - 2)
    for plen in sorted(warm):
        for nb in widths:
            try:
                engine.generate([base[:plen]] * nb,
                                SamplingParams(max_tokens=2))
            except ValueError:
                break


def _replay(engine, trace, handles_out=None) -> dict:
    """Warm (on the engine itself), reset telemetry, then replay the
    trace against the arrival clock. ``engine`` is an Engine or a
    ReplicaSet — both speak add_request/step/stats. ``handles_out``
    (optional list) receives the finished request handles in trace
    order, for sections that compare emitted tokens across configs."""
    if hasattr(engine, "replicas"):       # warm each replica's jit caches
        pre = list(getattr(engine, "prefill_ids", ()))
        for r in pre:                     # let prefill-only replicas
            engine.replicas[r].backend.prefill_only = False   # finish _warm
        for rep in engine.replicas:
            _warm(rep, trace)
        for r in pre:
            engine.replicas[r].backend.prefill_only = True
        if pre:                           # trace the migration jits too
            engine.generate([t.prompt for t in trace[:2]],
                            SamplingParams(max_tokens=2))
        engine.reset_telemetry()
    else:
        _warm(engine, trace)
        engine.backend.reset_telemetry()
    t0 = time.time()
    pending = list(trace)
    handles = []
    while pending or engine.has_work:
        now = time.time() - t0
        while pending and pending[0].arrival <= now:
            r = pending.pop(0)
            handles.append(engine.add_request(
                r.prompt, SamplingParams(max_tokens=r.max_new)))
        if engine.has_work:
            engine.step()
        elif pending:
            _wait_until(t0, pending[0].arrival)
    dt = time.time() - t0
    if handles_out is not None:
        handles_out.extend(handles)
    return _result_row(engine, handles, dt)


def _result_row(engine, handles, dt) -> dict:
    """Per-section telemetry row from finished handles + engine stats
    (shared by ``_replay`` and the workload-class replays so every
    section reports the same columns)."""
    useful = sum(len(h.token_ids) for h in handles)
    st = engine.stats()
    slots = getattr(engine, "total_slots", engine.cfg.num_slots)
    lane_eff = useful / max(st["steps"] * slots, 1)
    lat = latency_stats(handles)
    return {"tok_s": useful / dt, "useful": useful, "wall_s": dt,
            "ttft": lat["ttft"], "tpot": lat["tpot"],
            "lane_eff": lane_eff,
            "cache_util": st["cache_utilization"],
            "mean_active": st["mean_active_slots"],
            "preemptions": st.get("preemptions", 0),
            "prefill_compiles": st["prefill_compiles"],
            "prefill_calls": st.get("prefill_calls", 0),
            "blocks_leaked": st.get("blocks_used", 0)}


def _per_device_cache_bytes(engine: Engine) -> dict:
    """Resident paged-cache bytes per device (the head-sharded pool puts
    1/|tp| of every block on each TP device; per-slot state follows the
    cache rules)."""
    import collections

    per = collections.defaultdict(int)
    for leaf in jax.tree.leaves(engine.backend.pools):
        for sh in leaf.addressable_shards:
            per[sh.device.id] += sh.data.nbytes
    return {str(k): int(v) for k, v in sorted(per.items())}


def _replay_sharded(model, params, trace, args) -> dict:
    """Replay the trace through the paged backend sharded over a
    (data = n/tp, model = tp) mesh of the local devices. With one local
    device this degenerates to a (1, 1) mesh — the sharded code path
    still runs, which is what the single-device CI smoke checks."""
    from repro.launch.mesh import make_local_mesh, mesh_summary

    # fail loudly on a bad --tp rather than silently benchmarking an
    # unsharded mesh under the "sharded" label (make_local_mesh raises)
    mesh = make_local_mesh(args.tp)
    eng = Engine(model, params, EngineConfig(
        backend="paged", num_slots=args.slots,
        block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark,
        mesh=mesh))
    res = _replay(eng, trace)
    res["mesh"] = mesh_summary(mesh)
    res["head_sharded"] = bool(eng.backend.ctx.decode_head_shard)
    per_dev = _per_device_cache_bytes(eng)
    # symmetric layout: every device sees the same live/allocated ratio,
    # so per-device utilization is the global one over its resident share
    res["per_device_cache"] = {
        dev: {"bytes": b, "util": round(res["cache_util"], 4)}
        for dev, b in per_dev.items()}
    return res


def _capacity(rset) -> float:
    """Aggregate tokens/s over per-replica CLOCKS: each replica's
    emitted tokens over the wall time spent inside ITS step calls. On
    parallel hardware replicas overlap and this equals wall-clock
    throughput; on a CPU host simulating devices they time-share the
    cores, and this is the rate the set would sustain if they did not —
    the quantity data-parallel replication actually adds. A replica the
    dispatch policy starves (or overloads into a long straggler tail)
    drags the sum down, so this number also scores placement quality."""
    st = rset.stats()
    return sum(t / b for t, b in zip(st["tokens_out"], st["busy_s"])
               if b > 0)


def _replay_replicas(model, params, trace, args) -> dict:
    """The ``"replicas"`` section: the same (dp-scaled) trace through a
    ReplicaSet of ``--dp`` data-parallel paged replicas behind one
    shared admission queue, against a SINGLE replica of the identical
    per-replica config (same slots, same pool, same submesh shape) —
    the fixed-per-replica scale-out claim. Reports wall-clock AND
    per-replica-clock (capacity) aggregate tok/s, per-replica
    utilization / dispatch share, and shared-queue wait."""
    from repro.launch.engine import ReplicaSet
    from repro.launch.mesh import make_mesh, mesh_summary

    cfg = EngineConfig(
        backend="paged", num_slots=args.slots,
        block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark)
    mesh = sub0 = None
    if len(jax.devices()) >= args.dp * args.tp and \
            args.dp * args.tp > 1:
        # exactly dp x tp devices: each replica owns a (1, tp) subgrid
        mesh = make_mesh((args.dp, args.tp), ("data", "model"))
        # the dp=1 baseline runs on ONE replica-shaped submesh so both
        # sides get identical per-replica resources
        sub0 = make_mesh((1, args.tp), ("data", "model"))
    single = ReplicaSet(model, params, cfg, dp=1, mesh=sub0)
    res_1 = _replay(single, trace)
    cap_1 = _capacity(single)
    # drop the baseline's pool before the replica replay so resident
    # cache memory stays at the dp x pool the section claims to budget
    del single
    rset = ReplicaSet(model, params, cfg, dp=args.dp, mesh=mesh)
    res = _replay(rset, trace)
    st = rset.stats()
    res["dp"] = args.dp
    res["mesh"] = mesh_summary(mesh) if mesh is not None else None
    res["single_tok_s"] = cap_1
    res["single_wall_tok_s"] = res_1["tok_s"]
    res["agg_tok_s"] = _capacity(rset)
    res["speedup_vs_single"] = res["agg_tok_s"] / max(cap_1, 1e-9)
    res["speedup_wall"] = res["tok_s"] / max(res_1["tok_s"], 1e-9)
    res["dispatched"] = st["dispatched"]
    res["per_replica"] = [
        {"util": round(p["cache_utilization"], 4),
         "mean_active": round(p["mean_active_slots"], 3),
         "steps": p["steps"],
         "busy_s": round(b, 4),
         "tok_s": round(t / b, 2) if b > 0 else 0.0,
         "preemptions": p.get("preemptions", 0),
         "share": round(d / max(sum(st["dispatched"]), 1), 4)}
        for p, d, b, t in zip(st["per_replica"], st["dispatched"],
                              st["busy_s"], st["tokens_out"])]
    res["queue_wait"] = {
        "steps_mean": round(st["queue_wait_steps_mean"], 3),
        "steps_max": st["queue_wait_steps_max"],
        "s_mean": round(st["queue_wait_s_mean"], 6)}
    return res


def _replay_speculative(model, params, args) -> dict:
    """The ``"speculative"`` section: a decode-heavy repetitive-prompt
    trace through the paged backend WITHOUT speculation and through the
    same config with ``spec_tokens`` n-gram self-drafting, at equal
    cache memory. Reports both tok/s, the speedup, and the acceptance
    telemetry from ``Engine.stats()['spec']`` (the same per-request
    counters the docs cite). Speculation changes WHAT the step computes
    but not WHAT tokens come out — equivalence is pinned by
    tests/test_spec_decode.py; this section only prices it."""
    trace = make_repetitive_trace(model.cfg, n_requests=2 * args.requests,
                                  seed=args.seed + 2)
    base_cfg = EngineConfig(
        backend="paged", num_slots=args.slots, block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark)
    eng = Engine(model, params, base_cfg)
    res_b = _replay(eng, trace)
    del eng
    spec = Engine(model, params, dataclasses.replace(
        base_cfg, spec_tokens=args.spec_tokens, drafter=args.drafter))
    res = _replay(spec, trace)
    st = spec.stats()["spec"]
    res["spec_tokens"] = args.spec_tokens
    res["drafter"] = args.drafter
    res["base_tok_s"] = res_b["tok_s"]
    res["speedup_vs_paged"] = res["tok_s"] / max(res_b["tok_s"], 1e-9)
    res["accept_rate"] = round(st["accept_rate"], 4)
    res["accepted_per_step"] = round(
        st["accepted"] / max(st["steps"], 1), 4)
    res["emitted_per_step"] = round(st["emitted_per_step"], 4)
    res["proposed"] = st["proposed"]
    res["accepted"] = st["accepted"]
    return res


def _replay_shared_prefix(model, params, args) -> dict:
    """The ``"shared_prefix"`` section: a saturated trace of prompts
    sharing one long system prefix, through the paged backend with the
    COW prefix cache OFF and ON at equal cache memory. Reports both
    tok/s, the hit rate, prefill tokens computed under each config (the
    saved volume is the caching win), COW copy and LRU eviction counts,
    and whether the two runs emitted bit-identical tokens (the
    correctness contract tests/test_prefix_cache.py pins; the bench
    re-checks it on every run because BENCH_serve.json is CI-gated)."""
    trace = make_shared_prefix_trace(model.cfg,
                                     n_requests=2 * args.requests,
                                     seed=args.seed + 3)
    base_cfg = EngineConfig(
        backend="paged", num_slots=args.slots, block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark,
        prefix_cache=False)
    off = Engine(model, params, base_cfg)
    h_off: list = []
    res_off = _replay(off, trace, h_off)
    st_off = off.stats()
    del off
    on = Engine(model, params,
                dataclasses.replace(base_cfg, prefix_cache=True))
    h_on: list = []
    res = _replay(on, trace, h_on)
    st = on.stats()
    pc = st["prefix_cache"]
    res["base_tok_s"] = res_off["tok_s"]
    res["speedup_vs_uncached"] = res["tok_s"] / max(res_off["tok_s"],
                                                    1e-9)
    res["hit_rate"] = round(pc["hits"] / max(pc["lookups"], 1), 4)
    res["hits"] = pc["hits"]
    res["lookups"] = pc["lookups"]
    res["hit_tokens"] = pc["hit_tokens"]
    res["prefill_tokens"] = st["prefill_tokens"]
    res["prefill_tokens_uncached"] = st_off["prefill_tokens"]
    res["prefill_tokens_saved"] = (st_off["prefill_tokens"]
                                   - st["prefill_tokens"])
    res["prefill_reduction"] = (st_off["prefill_tokens"]
                                / max(st["prefill_tokens"], 1))
    res["cow_copies"] = pc["cow_copies"]
    res["evictions"] = pc["evictions"]
    res["suffix_compiles"] = pc["suffix_compiles"]
    res["outputs_match"] = ([h.token_ids for h in h_on]
                            == [h.token_ids for h in h_off])
    return res


def _replay_disagg(model, params, args) -> dict:
    """The ``"disagg"`` section: prefill/decode disaggregation
    (DisaggregatedEngine, roles="auto") against a symmetric ReplicaSet
    of the same ``--dp`` at IDENTICAL per-replica config — equal total
    cache memory — on a mixed-prompt-length trace whose long prefills
    are the TTFT interference role specialization removes. Reports wall
    tok/s and TTFT p50/p95 for both, migration volume (packets / bytes /
    estimated fabric seconds from ``core.noc.p2p_time``), steal count,
    and the bit-identity check (outputs_match) the CI gate enforces."""
    from repro.launch.engine import DisaggregatedEngine, ReplicaSet
    from repro.launch.mesh import make_mesh, mesh_summary

    # 2x requests per replica (like the replicas section): percentile
    # TTFT stats need the sample count, and the win lives in the
    # saturated regime where symmetric slots are decode-occupied
    trace = make_trace(model.cfg, n_requests=2 * args.requests * args.dp,
                       rate=args.rate, seed=args.seed + 4,
                       prompt_lens=(6, 12, 24, 40))
    cfg = EngineConfig(
        backend="paged", num_slots=args.slots,
        block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark)
    mesh = None
    if len(jax.devices()) >= args.dp * args.tp and \
            args.dp * args.tp > 1:
        mesh = make_mesh((args.dp, args.tp), ("data", "model"))
    sym = ReplicaSet(model, params, cfg, dp=args.dp, mesh=mesh)
    h_s: list = []
    res_sym = _replay(sym, trace, h_s)
    # drop the symmetric set's pools before the disagg replay so
    # resident cache stays at the dp x pool the section budgets
    del sym
    dis = DisaggregatedEngine(model, params, cfg, dp=args.dp,
                              mesh=mesh, roles="auto")
    h_d: list = []
    res = _replay(dis, trace, h_d)
    st = dis.stats()["disagg"]
    res["dp"] = args.dp
    res["roles"] = list(dis.roles)
    res["mesh"] = mesh_summary(mesh) if mesh is not None else None
    res["sym_tok_s"] = res_sym["tok_s"]
    res["sym_ttft"] = res_sym["ttft"]
    res["speedup_wall"] = res["tok_s"] / max(res_sym["tok_s"], 1e-9)
    res["ttft_p95_ratio"] = (res["ttft"]["p95_s"]
                             / max(res_sym["ttft"]["p95_s"], 1e-9))
    res["packets"] = st["imported"]
    res["stolen"] = st["stolen"]
    res["bytes_moved"] = st["bytes_moved"]
    res["bytes_per_packet"] = round(st["bytes_per_packet"], 1)
    res["fabric_s"] = st["fabric_s"]
    res["outputs_match"] = ([h.token_ids for h in h_d]
                            == [h.token_ids for h in h_s])
    res["sym_blocks_leaked"] = res_sym["blocks_leaked"]
    return res


def _replay_encdec(engine, items, handles_out=None) -> dict:
    """Offline (arrival-0) replay of encoder-decoder requests — a list
    of ``(prompt, frames, max_new)`` triples — with ``_replay``'s warm /
    reset / time discipline. Needs its own warm pass because the
    generic ``_warm`` probes carry no encoder features, which
    ``check_request`` rejects on an enc-dec config; probe clips are all
    one encoder length, so the enc bucket axis contributes exactly one
    bucket of compiles."""
    cfg = engine.backend.model.cfg
    flen = max(f.shape[0] for _, f, _ in items)
    widths = [1]
    while widths[-1] * 2 <= engine.cfg.num_slots:
        widths.append(widths[-1] * 2)
    probe_rng = np.random.default_rng(0)
    c = 1
    for plen in sorted({len(p) for p, _, _ in items}):
        for nb in widths:
            prompts, feats = [], []
            for _ in range(nb):
                pat = [c % cfg.vocab_size, (c // cfg.vocab_size)
                       % cfg.vocab_size]
                prompts.append((pat * plen)[:plen])
                feats.append(probe_rng.standard_normal(
                    (flen, cfg.d_model)).astype(np.float32))
                c += 1
            engine.generate(prompts, SamplingParams(max_tokens=2),
                            encoder_features=feats)
    engine.backend.reset_telemetry()
    t0 = time.time()
    handles = [engine.add_request(p, SamplingParams(max_tokens=n),
                                  encoder_features=f)
               for p, f, n in items]
    while engine.has_work:
        engine.step()
    dt = time.time() - t0
    if handles_out is not None:
        handles_out.extend(handles)
    res = _result_row(engine, handles, dt)
    arena = engine.stats()["cross_arena"]
    res["arena_rows_leaked"] = arena["rows_used"]
    res["arena_shared_hits"] = arena["shared_hits"]
    return res


def _replay_workloads(args) -> dict:
    """The ``"workloads"`` section: the OTHER two request classes —
    dropless MoE (qwen3-moe smoke) and encoder-decoder (whisper smoke,
    cross-KV arena) — through the same paged ``Engine`` at the same
    ``--mem-tokens`` cache budget the dense sections spend. Each class
    replays its trace co-batched across ``--slots`` lanes and again
    one-request-at-a-time on an identically-budgeted single-slot
    engine: tokens must be bit-identical (the co-batching-invariance
    contract tests/test_workload_serve.py pins, re-checked every run
    because BENCH_serve.json is CI-gated), nothing may leak from the
    block pool or the cross-KV arena, and the repeated-clip enc-dec
    trace must actually share arena rows by feature identity."""
    out = {}
    base = dict(backend="paged", block_size=args.block_size,
                num_blocks=args.mem_tokens // args.block_size + 1,
                max_len=args.max_len, watermark_blocks=args.watermark)

    cfg = get_config("qwen3_moe_30b_a3b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = make_trace(cfg, n_requests=args.requests, rate=args.rate,
                       seed=args.seed + 5)
    eng = Engine(model, params,
                 EngineConfig(num_slots=args.slots, **base))
    h: list = []
    res = _replay(eng, trace, h)
    del eng
    seq = Engine(model, params, EngineConfig(num_slots=1, **base))
    h_seq: list = []
    res_seq = _replay(seq, trace, h_seq)
    del seq, model, params
    res["arch"] = cfg.name
    res["seq_tok_s"] = res_seq["tok_s"]
    res["cobatch_speedup"] = res["tok_s"] / max(res_seq["tok_s"], 1e-9)
    res["seq_blocks_leaked"] = res_seq["blocks_leaked"]
    res["outputs_match"] = ([x.token_ids for x in h]
                            == [x.token_ids for x in h_seq])
    out["moe"] = res

    cfg = get_config("whisper_base").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed + 6)
    flen = cfg.encoder_len
    shared_clip = rng.standard_normal(
        (flen, cfg.d_model)).astype(np.float32)
    items = []
    for i in range(args.requests):
        plen = int(rng.choice((6, 10)))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        # every third request decodes the SAME clip object — the
        # several-transcripts-per-audio pattern identity sharing
        # detects, so co-resident repeats hold one arena row
        clip = shared_clip if i % 3 == 0 else rng.standard_normal(
            (flen, cfg.d_model)).astype(np.float32)
        items.append((prompt, clip, 12))
    eng = Engine(model, params,
                 EngineConfig(num_slots=args.slots, **base))
    h = []
    res = _replay_encdec(eng, items, h)
    del eng
    seq = Engine(model, params, EngineConfig(num_slots=1, **base))
    h_seq = []
    res_seq = _replay_encdec(seq, items, h_seq)
    del seq, model, params
    res["arch"] = cfg.name
    res["seq_tok_s"] = res_seq["tok_s"]
    res["cobatch_speedup"] = res["tok_s"] / max(res_seq["tok_s"], 1e-9)
    res["seq_blocks_leaked"] = res_seq["blocks_leaked"]
    res["outputs_match"] = ([x.token_ids for x in h]
                            == [x.token_ids for x in h_seq])
    out["encdec"] = res
    return out


def _pool_bytes_per_block(model, layout, spec) -> int:
    """Bytes one physical block occupies across every full-attention
    pool leaf (payload + scale leaves under a quantized ``spec``),
    computed from abstract shapes — no allocation. Per-slot state
    (rings, SSM carries) is excluded: it does not scale with the
    block budget this section trades."""
    shapes = jax.eval_shape(lambda: model.init_paged_cache(layout, spec))
    mask = model.paged_pool_mask(layout, spec)
    total = 0
    for leaf, kind in zip(jax.tree.leaves(shapes), jax.tree.leaves(mask)):
        if kind == "pool":
            total += (leaf.size // leaf.shape[1]
                      * np.dtype(leaf.dtype).itemsize)
    return int(total)


def _replay_quantized(args) -> dict:
    """The ``"quantized"`` section: int8 paged KV (per-(token, kv-head)
    scale leaves, dequant fused into the decode/verify kernels) against
    the bf16 pool at EQUAL cache byte budget, on a head_dim=64 smoke
    variant (the TPU lane-width-representative geometry; tiny smoke
    head dims understate the payload ratio because the fixed 4-byte
    scale amortizes over the head dim). The int8 engine converts the
    byte budget into several-fold the usable blocks (3.75x vs the
    f32-stored default pool) — the serving win is
    CAPACITY: more concurrent tokens resident per byte. Reports the
    usable-block capacity ratio, tok/s for both engines, the greedy
    token-level match rate vs the bf16 run (the quality gate
    benchmarks/check_serve_regression.py enforces a floor on), and
    both leak counters."""
    from repro.models import paged_kv

    cfg = dataclasses.replace(get_config(args.arch).smoke(), head_dim=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    trace = make_trace(cfg, n_requests=args.requests, rate=args.rate,
                       seed=args.seed + 7)
    bs = args.block_size
    nb_bf16 = args.mem_tokens // bs + 1
    layout = paged_kv.PagedLayout(
        num_slots=args.slots, num_blocks=nb_bf16, block_size=bs,
        max_len=args.max_len)
    spec = paged_kv.make_pool_spec(cfg, layout, kv_dtype="int8")
    b_bf16 = _pool_bytes_per_block(model, layout, None)
    b_int8 = _pool_bytes_per_block(model, layout, spec)
    budget = (nb_bf16 - 1) * b_bf16        # null block excluded
    nb_int8 = budget // b_int8 + 1
    base = EngineConfig(
        backend="paged", num_slots=args.slots, block_size=bs,
        num_blocks=nb_bf16, max_len=args.max_len,
        watermark_blocks=args.watermark)
    eng = Engine(model, params, base)
    h_fp: list = []
    res_fp = _replay(eng, trace, h_fp)
    del eng
    qeng = Engine(model, params, dataclasses.replace(
        base, num_blocks=int(nb_int8), kv_dtype="int8"))
    h_q: list = []
    res = _replay(qeng, trace, h_q)
    matched = total = 0
    for a, b in zip(h_fp, h_q):
        total += max(len(a.token_ids), len(b.token_ids))
        matched += sum(x == y for x, y in zip(a.token_ids, b.token_ids))
    res["kv_dtype"] = "int8"
    res["head_dim"] = cfg.head_dim
    res["bf16_tok_s"] = res_fp["tok_s"]
    res["bf16_blocks_leaked"] = res_fp["blocks_leaked"]
    res["bf16_preemptions"] = res_fp["preemptions"]
    res["bytes_per_block_bf16"] = b_bf16
    res["bytes_per_block_int8"] = b_int8
    res["cache_bytes_budget"] = int(budget)
    res["usable_blocks_bf16"] = nb_bf16 - 1
    res["usable_blocks_int8"] = int(nb_int8) - 1
    res["capacity_ratio"] = round((nb_int8 - 1) / (nb_bf16 - 1), 4)
    res["match_rate"] = round(matched / max(total, 1), 4)
    return res


def _replay_open_loop(model, params, args) -> dict:
    """The ``"open_loop"`` section: one deterministic Poisson arrival
    trace (benchmarks/traffic.py; decode-heavy output lengths, since
    TPOT and the overlap toggle both live in the decode loop) through
    the paged backend with ``overlap=`` OFF then ON at equal config.

    SLO budgets are calibrated from the MEASURED baseline replay
    (generous multiples of its median TTFT/TPOT, longer prompts earning
    proportionally more TTFT headroom) so goodput-under-SLO is a
    scheduling metric, not a CPU-speed lottery; the same budgets then
    score both runs, and ``ttft_p99_ratio`` (overlap p99 over baseline
    p99) is machine-normalized by construction. Outputs must be
    bit-identical across the toggle — the RNG-stream contract — and
    ``_write_json`` raw-asserts it."""
    try:                          # package import (python -m benchmarks.run)
        from benchmarks import traffic
    except ImportError:           # script import (python benchmarks/bench_serve.py)
        import traffic

    trace = traffic.make_open_loop_trace(
        model.cfg, kind="poisson", n_requests=2 * args.requests,
        rate=args.rate, seed=args.seed + 8)
    base_cfg = EngineConfig(
        backend="paged", num_slots=args.slots,
        block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark)
    off = Engine(model, params, base_cfg)
    h_off: list = []
    res_off = _replay(off, trace, h_off)
    del off
    # budgets from the measured baseline: 4x its median TTFT, 3x its
    # median TPOT (floored so an all-zero-latency degenerate run can't
    # produce zero budgets) — applied identically to both runs
    lat = latency_stats(h_off)
    budget = traffic.SLO(ttft_s=max(4.0 * lat["ttft"]["p50_s"], 1e-3),
                         tpot_s=max(3.0 * lat["tpot"]["p50_s"], 1e-4))
    traffic.annotate_slos(trace, ttft_s=budget.ttft_s,
                          tpot_s=budget.tpot_s)
    slo_off = traffic.slo_report(h_off, trace, res_off["wall_s"])
    on = Engine(model, params,
                dataclasses.replace(base_cfg, overlap=True))
    h_on: list = []
    res = _replay(on, trace, h_on)
    slo_on = traffic.slo_report(h_on, trace, res["wall_s"])
    res["kind"] = "poisson"
    res["rate"] = args.rate
    res["requests"] = len(trace)
    res["overlap"] = True
    res["slo_budget"] = dataclasses.asdict(budget)
    res["slo"] = slo_on
    res["base_slo"] = slo_off
    res["base_tok_s"] = res_off["tok_s"]
    res["base_blocks_leaked"] = res_off["blocks_leaked"]
    res["overlap_speedup"] = res["tok_s"] / max(res_off["tok_s"], 1e-9)
    res["ttft_p99_ratio"] = (slo_on["ttft"]["p99_s"]
                             / max(slo_off["ttft"]["p99_s"], 1e-9))
    res["goodput_tok_s"] = slo_on["goodput_tok_s"]
    res["goodput_frac"] = slo_on["goodput_frac"]
    res["outputs_match"] = ([h.token_ids for h in h_on]
                            == [h.token_ids for h in h_off])
    return res


def run_bench(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = make_trace(cfg, n_requests=args.requests, rate=args.rate,
                       seed=args.seed)

    static_batch = max(args.mem_tokens // args.max_len, 1)
    eng_s = Engine(model, params,
                   EngineConfig(backend="static", num_slots=static_batch,
                                max_len=args.max_len,
                                block_size=args.block_size))
    res_s = _replay(eng_s, trace)
    eng_c = Engine(model, params, EngineConfig(
        backend="paged", num_slots=args.slots,
        block_size=args.block_size,
        num_blocks=args.mem_tokens // args.block_size + 1,
        max_len=args.max_len, watermark_blocks=args.watermark))
    res_c = _replay(eng_c, trace)
    res_sh = _replay_sharded(model, params, trace, args)
    # the replica section uses its own heavier trace: 2x requests per
    # replica so the scale-out claim is measured in the saturated
    # regime, where straggler tails amortize over a long bulk phase
    rep_trace = make_trace(cfg, n_requests=2 * args.requests * args.dp,
                           rate=args.rate, seed=args.seed + 1)
    res_r = _replay_replicas(model, params, rep_trace, args)
    res_sp = _replay_speculative(model, params, args)
    res_px = _replay_shared_prefix(model, params, args)
    res_dg = _replay_disagg(model, params, args)
    res_w = _replay_workloads(args)
    res_q = _replay_quantized(args)
    res_ol = _replay_open_loop(model, params, args)
    return {
        "arch": cfg.name,
        "mem_tokens": args.mem_tokens,
        "static": res_s,
        "continuous": res_c,
        "sharded": res_sh,
        "replicas": res_r,
        "speculative": res_sp,
        "shared_prefix": res_px,
        "disagg": res_dg,
        "workloads": res_w,
        "quantized": res_q,
        "open_loop": res_ol,
        "speedup": res_c["tok_s"] / max(res_s["tok_s"], 1e-9),
    }


def _write_json(result: dict, json_path: str):
    """Persist machine-readable results; fail loudly on a block leak
    from EITHER entry point (CLI main or benchmarks/run.py)."""
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    if result["continuous"]["blocks_leaked"] \
            or result["sharded"]["blocks_leaked"] \
            or result["replicas"]["blocks_leaked"] \
            or result["speculative"]["blocks_leaked"] \
            or result["shared_prefix"]["blocks_leaked"] \
            or result["disagg"]["blocks_leaked"] \
            or result["disagg"]["sym_blocks_leaked"]:
        raise SystemExit("block leak detected")
    if not result["shared_prefix"]["outputs_match"]:
        raise SystemExit("prefix cache changed emitted tokens")
    if not result["disagg"]["outputs_match"]:
        raise SystemExit("disaggregation changed emitted tokens")
    for cls in ("moe", "encdec"):
        w = result["workloads"][cls]
        if w["blocks_leaked"] or w["seq_blocks_leaked"]:
            raise SystemExit(f"{cls} workload leaked blocks")
        if not w["outputs_match"]:
            raise SystemExit(f"co-batching changed {cls} emitted tokens")
    if result["workloads"]["encdec"]["arena_rows_leaked"]:
        raise SystemExit("cross-KV arena leaked rows")
    q = result["quantized"]
    if q["blocks_leaked"] or q["bf16_blocks_leaked"]:
        raise SystemExit("quantized section leaked blocks")
    ol = result["open_loop"]
    if ol["blocks_leaked"] or ol["base_blocks_leaked"]:
        raise SystemExit("open_loop section leaked blocks")
    if not ol["outputs_match"]:
        raise SystemExit("overlap changed emitted tokens")


def _emit(result: dict, json_path: str):
    res_s, res_c = result["static"], result["continuous"]
    res_m = result["sharded"]
    print("name,tok_s,cache_util,lane_eff,useful_tokens,wall_s")
    print(f"serve_static,{res_s['tok_s']:.2f},{res_s['cache_util']:.3f},"
          f"{res_s['lane_eff']:.3f},{res_s['useful']},"
          f"{res_s['wall_s']:.2f}")
    print(f"serve_continuous,{res_c['tok_s']:.2f},"
          f"{res_c['cache_util']:.3f},{res_c['lane_eff']:.3f},"
          f"{res_c['useful']},{res_c['wall_s']:.2f}")
    print(f"serve_sharded,{res_m['tok_s']:.2f},"
          f"{res_m['cache_util']:.3f},{res_m['lane_eff']:.3f},"
          f"{res_m['useful']},{res_m['wall_s']:.2f}")
    res_r = result["replicas"]
    print(f"serve_replicas,{res_r['tok_s']:.2f},"
          f"{res_r['cache_util']:.3f},{res_r['lane_eff']:.3f},"
          f"{res_r['useful']},{res_r['wall_s']:.2f}")
    res_p = result["speculative"]
    print(f"serve_speculative,{res_p['tok_s']:.2f},"
          f"{res_p['cache_util']:.3f},{res_p['lane_eff']:.3f},"
          f"{res_p['useful']},{res_p['wall_s']:.2f}")
    res_x = result["shared_prefix"]
    print(f"serve_shared_prefix,{res_x['tok_s']:.2f},"
          f"{res_x['cache_util']:.3f},{res_x['lane_eff']:.3f},"
          f"{res_x['useful']},{res_x['wall_s']:.2f}")
    res_d = result["disagg"]
    print(f"serve_disagg,{res_d['tok_s']:.2f},"
          f"{res_d['cache_util']:.3f},{res_d['lane_eff']:.3f},"
          f"{res_d['useful']},{res_d['wall_s']:.2f}")
    res_w = result["workloads"]
    for nm, r in (("serve_moe", res_w["moe"]),
                  ("serve_encdec", res_w["encdec"])):
        print(f"{nm},{r['tok_s']:.2f},{r['cache_util']:.3f},"
              f"{r['lane_eff']:.3f},{r['useful']},{r['wall_s']:.2f}")
    res_q = result["quantized"]
    print(f"serve_quantized,{res_q['tok_s']:.2f},"
          f"{res_q['cache_util']:.3f},{res_q['lane_eff']:.3f},"
          f"{res_q['useful']},{res_q['wall_s']:.2f}")
    res_o = result["open_loop"]
    print(f"serve_open_loop,{res_o['tok_s']:.2f},"
          f"{res_o['cache_util']:.3f},{res_o['lane_eff']:.3f},"
          f"{res_o['useful']},{res_o['wall_s']:.2f}")
    print(f"# sharded mesh {res_m['mesh']['axes']}; "
          f"head_sharded={res_m['head_sharded']}; "
          f"per-device cache {res_m['per_device_cache']}")
    print(f"# replicas dp={res_r['dp']}: aggregate capacity "
          f"{res_r['agg_tok_s']:.1f} tok/s = "
          f"{res_r['speedup_vs_single']:.2f}x one replica "
          f"({res_r['single_tok_s']:.1f}); wall {res_r['tok_s']:.1f} "
          f"({res_r['speedup_wall']:.2f}x, replicas time-share CPU "
          f"cores); dispatched {res_r['dispatched']}; "
          f"queue wait {res_r['queue_wait']}")
    print(f"# speculative K={res_p['spec_tokens']} "
          f"({res_p['drafter']}): {res_p['tok_s']:.1f} tok/s = "
          f"{res_p['speedup_vs_paged']:.2f}x non-speculative paged "
          f"({res_p['base_tok_s']:.1f}) on the repetitive trace; "
          f"accept rate {res_p['accept_rate']:.2f}, "
          f"{res_p['accepted_per_step']:.2f} accepted drafts/step")
    print(f"# shared prefix: hit rate {res_x['hit_rate']:.2f} "
          f"({res_x['hits']}/{res_x['lookups']}), prefill tokens "
          f"{res_x['prefill_tokens']} vs "
          f"{res_x['prefill_tokens_uncached']} uncached "
          f"({res_x['prefill_reduction']:.2f}x fewer, "
          f"{res_x['prefill_tokens_saved']} saved); "
          f"{res_x['tok_s']:.1f} tok/s = "
          f"{res_x['speedup_vs_uncached']:.2f}x uncached "
          f"({res_x['base_tok_s']:.1f}); cow copies "
          f"{res_x['cow_copies']}; outputs_match "
          f"{res_x['outputs_match']}")
    print(f"# disagg dp={res_d['dp']} roles={res_d['roles']}: "
          f"{res_d['tok_s']:.1f} tok/s vs symmetric "
          f"{res_d['sym_tok_s']:.1f} ({res_d['speedup_wall']:.2f}x); "
          f"ttft p50/p95 {res_d['ttft']['p50_s'] * 1e3:.1f}/"
          f"{res_d['ttft']['p95_s'] * 1e3:.1f} ms vs "
          f"{res_d['sym_ttft']['p50_s'] * 1e3:.1f}/"
          f"{res_d['sym_ttft']['p95_s'] * 1e3:.1f} ms "
          f"(p95 ratio {res_d['ttft_p95_ratio']:.2f}); "
          f"{res_d['packets']} packets ({res_d['stolen']} stolen), "
          f"{res_d['bytes_moved']} bytes, "
          f"fabric {res_d['fabric_s']:.2e} s; "
          f"outputs_match {res_d['outputs_match']}")
    print(f"# workloads at the same {result['mem_tokens']}-token "
          f"budget: moe ({res_w['moe']['arch']}) "
          f"{res_w['moe']['tok_s']:.1f} tok/s = "
          f"{res_w['moe']['cobatch_speedup']:.2f}x one-at-a-time "
          f"({res_w['moe']['seq_tok_s']:.1f}), outputs_match "
          f"{res_w['moe']['outputs_match']}; encdec "
          f"({res_w['encdec']['arch']}) "
          f"{res_w['encdec']['tok_s']:.1f} tok/s = "
          f"{res_w['encdec']['cobatch_speedup']:.2f}x one-at-a-time "
          f"({res_w['encdec']['seq_tok_s']:.1f}), arena shared hits "
          f"{res_w['encdec']['arena_shared_hits']}, rows leaked "
          f"{res_w['encdec']['arena_rows_leaked']}, outputs_match "
          f"{res_w['encdec']['outputs_match']}")
    print(f"# quantized kv ({res_q['kv_dtype']}, head_dim "
          f"{res_q['head_dim']}): {res_q['usable_blocks_int8']} usable "
          f"blocks vs {res_q['usable_blocks_bf16']} bf16 at the same "
          f"{res_q['cache_bytes_budget']} cache bytes "
          f"({res_q['capacity_ratio']:.2f}x capacity); "
          f"{res_q['tok_s']:.1f} tok/s vs bf16 "
          f"{res_q['bf16_tok_s']:.1f}; greedy match rate "
          f"{res_q['match_rate']:.4f}")
    print(f"# open loop ({res_o['kind']}, {res_o['rate']:.0f} req/s, "
          f"{res_o['requests']} reqs): overlap {res_o['tok_s']:.1f} "
          f"tok/s = {res_o['overlap_speedup']:.2f}x no-overlap "
          f"({res_o['base_tok_s']:.1f}); ttft p50/p99 "
          f"{res_o['slo']['ttft']['p50_s'] * 1e3:.1f}/"
          f"{res_o['slo']['ttft']['p99_s'] * 1e3:.1f} ms "
          f"(p99 ratio {res_o['ttft_p99_ratio']:.2f}); tpot p50/p99 "
          f"{res_o['slo']['tpot']['p50_s'] * 1e3:.2f}/"
          f"{res_o['slo']['tpot']['p99_s'] * 1e3:.2f} ms; goodput "
          f"{res_o['goodput_tok_s']:.1f} tok/s "
          f"({res_o['goodput_frac']:.2f} of emitted, "
          f"{res_o['slo']['slo_frac']:.2f} of requests in SLO); "
          f"outputs_match {res_o['outputs_match']}")
    print(f"# equal cache budget {result['mem_tokens']} tokens; "
          f"continuous/static tokens/s: {result['speedup']:.2f}x; "
          f"mean active slots {res_c['mean_active']:.2f}; "
          f"preemptions {res_c['preemptions']}; "
          f"prefill compiles {res_c['prefill_compiles']}; "
          f"blocks leaked {res_c['blocks_leaked']}")
    print(f"# wrote {json_path}")
    _write_json(result, json_path)


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--mem-tokens", type=int, default=512,
                    help="KV cache capacity in tokens, shared budget")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots for the continuous engine")
    ap.add_argument("--watermark", type=int, default=2,
                    help="free-block admission watermark (paged)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree for the sharded section "
                         "(mesh over local devices; run under "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N to fake devices on CPU)")
    ap.add_argument("--dp", type=int, default=2,
                    help="data-parallel replicas for the replicas "
                         "section (ReplicaSet over the mesh's data "
                         "axis; dp*tp must divide the device count)")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="draft tokens per step for the speculative "
                         "section (K; the verify step scores K+1 "
                         "positions in one pass)")
    ap.add_argument("--drafter", default="ngram",
                    choices=["ngram", "draft_model"],
                    help="draft source for the speculative section "
                         "(the bench builds no draft model, so "
                         "'ngram' is the meaningful choice here)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable results path")
    return ap


def run():
    """benchmarks/run.py entry: smoke trace, common-CSV rows + JSON."""
    from benchmarks.common import emit

    args = _parser().parse_args(["--smoke"])
    result = run_bench(args)
    for name, r in (("serve_static", result["static"]),
                    ("serve_continuous", result["continuous"]),
                    ("serve_sharded", result["sharded"]),
                    ("serve_replicas", result["replicas"]),
                    ("serve_speculative", result["speculative"]),
                    ("serve_shared_prefix", result["shared_prefix"]),
                    ("serve_disagg", result["disagg"]),
                    ("serve_moe", result["workloads"]["moe"]),
                    ("serve_encdec", result["workloads"]["encdec"]),
                    ("serve_quantized", result["quantized"]),
                    ("serve_open_loop", result["open_loop"])):
        emit(name, 1e6 / max(r["tok_s"], 1e-9),
             f"tok_s={r['tok_s']:.2f} util={r['cache_util']:.3f} "
             f"preemptions={r['preemptions']} "
             f"prefill_compiles={r['prefill_compiles']}")
    _write_json(result, args.json)


def main():
    args = _parser().parse_args()
    _emit(run_bench(args), args.json)


if __name__ == "__main__":
    main()
