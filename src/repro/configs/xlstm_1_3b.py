"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks at the xLSTM[7:1] ratio (one sLSTM per 8-layer
period). d_ff=0: xLSTM blocks carry their own up/down projections, no
separate FFN. [arXiv:2405.04517; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    norm="layernorm",
    rope_style="none",
    tie_embeddings=True,
)
