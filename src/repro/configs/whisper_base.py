"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865.

Encoder-decoder; the mel/conv frontend is a STUB (input_specs provides
1500 precomputed frame embeddings). Sinusoidal positions on both stacks
(deviation: whisper uses learned decoder positions; sinusoidal keeps the
32k decode cell parameter-free — noted in DESIGN.md). Plain (non-gated)
GELU MLP, LayerNorm. [arXiv:2212.04356; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    rope_style="none",
    pos_embed="sinusoidal",
    enc_dec=True,
    n_encoder_layers=6,
    encoder_len=1500,
    tie_embeddings=True,
)
