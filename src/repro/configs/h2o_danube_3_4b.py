"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000. Llama+mistral mix with sliding-window attention (window 4096)
— SWA makes this arch sub-quadratic, so it RUNS the long_500k cell.
[arXiv:2401.16818; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=4096,
    tie_embeddings=False,
)
