"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm (no scale/bias — OLMo's signature choice).
[arXiv:2402.00838; hf]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric",
    gated_mlp=True,
    tie_embeddings=True,
)
