"""Model/config schema shared by all 10 assigned architectures.

A ModelConfig is hashable (jit-static) and fully describes the network;
shape profiles (seq_len x batch cells) live in ``shapes.py``. Reduced
("smoke") variants are derived with ``cfg.smoke()`` for CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparametric
    activation: str = "silu"         # silu (SwiGLU) | gelu (GeGLU)
    gated_mlp: bool = True

    rope_style: str = "rope"         # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    pos_embed: str = "none"          # none | sinusoidal (whisper)

    attn_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA on 'attn' layers (danube)
    local_window: int = 2048               # window for 'local' layers (griffin)

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_coef: float = 0.001

    # Encoder-decoder (whisper)
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500          # precomputed frame embeddings (stub)

    # VLM (qwen2-vl): first `visual_prefix` positions are patch embeddings
    visual_prefix: int = 0

    rnn_width: Optional[int] = None  # RG-LRU width (default d_model)
    tie_embeddings: bool = True
    embed_scale: bool = False        # multiply embeddings by sqrt(d) (gemma)
    dtype: str = "bfloat16"          # params + activations
    mlstm_chunk: int = 256

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def attention_free(self) -> bool:
        return all(k in ("mlstm", "slstm", "rglru") for k in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM/hybrid/windowed only.)"""
        full_attn = any(
            k == "attn" and self.sliding_window is None
            for k in self.layer_kinds)
        return not full_attn and not self.enc_dec

    def smoke(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        period = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, min(2 * period, 2 * period)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            n_experts=8 if self.is_moe else 0,
            moe_top_k=2 if self.is_moe else 0,
            moe_d_ff=32 if self.is_moe else 0,
            n_encoder_layers=2 if self.enc_dec else 0,
            encoder_len=16 if self.enc_dec else self.encoder_len,
            visual_prefix=4 if self.visual_prefix else 0,
            mrope_sections=(4, 2, 2) if self.mrope_sections else (),
            sliding_window=16 if self.sliding_window else None,
            local_window=16,
            rnn_width=64 if self.rnn_width else None,
            dtype="float32",
            mlstm_chunk=8,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""

    name: str                        # train_4k | prefill_32k | ...
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)
