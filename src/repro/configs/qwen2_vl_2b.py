"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 v=151936.

M-RoPE (3-D multimodal rotary, sections 16/24/24 over head_dim=128) and
dynamic resolution. The ViT frontend is a STUB: input_specs provides
precomputed patch embeddings for the first ``visual_prefix`` positions.
QKV biases per the HF config. [arXiv:2409.12191; hf]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    rope_style="mrope",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    attn_bias=True,
    visual_prefix=64,
    tie_embeddings=True,
)
