"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert_ff=2048
vocab=163840, MoE 384 experts top-8. Trillion-parameter MoE (paper-table
config): the FSDP/EP stress case of the dry-run matrix.
[arXiv:2501.kimi2; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=128,
    n_experts=384,
    moe_top_k=8,
    moe_d_ff=2048,
    tie_embeddings=False,
)
