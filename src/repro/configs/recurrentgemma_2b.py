"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000. RG-LRU + local attention at 1:2 (pattern rglru, rglru,
local-attn; 26 = 8 full periods + 2 remainder). Window 2048, GeGLU,
embeddings scaled. [arXiv:2402.19427; hf]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    activation="gelu",
    embed_scale=True,
    rnn_width=2560,
    tie_embeddings=True,
)
