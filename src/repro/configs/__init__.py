"""Architecture registry: the 10 assigned configs + EPAC paper testbenches."""

from __future__ import annotations

import importlib

from .base import LM_SHAPES, ModelConfig, ShapeCell

ARCH_IDS = (
    "xlstm_1_3b",
    "qwen2_vl_2b",
    "whisper_base",
    "yi_6b",
    "h2o_danube_3_4b",
    "gemma_7b",
    "olmo_1b",
    "kimi_k2_1t_a32b",
    "qwen3_moe_30b_a3b",
    "recurrentgemma_2b",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_cell(name: str) -> ShapeCell:
    for c in LM_SHAPES:
        if c.name == name:
            return c
    raise KeyError(name)
