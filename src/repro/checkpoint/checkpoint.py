"""Checkpointing: atomic, async, keep-k, elastic-reshardable.

Design for 1000+ nodes:
  * atomicity — write to ``<dir>/tmp.<step>``, fsync, rename to
    ``step_<k>``; a crash mid-write never corrupts the latest checkpoint.
  * async — a writer thread drains a depth-1 queue; training never blocks
    on storage (the step's arrays are snapshotted to host first).
  * elastic restore — leaves are stored as *full logical arrays* plus a
    JSON manifest; ``restore(..., shardings=...)`` device_puts onto ANY
    mesh, so restarts may change pod count/topology freely (the
    elastic-scaling contract, see launch/elastic.py).
  * keep-k — bounded disk usage; latest-k retained, best-metric optional.

Storage format: one ``.npy`` per leaf (names = flattened tree paths) — no
pickle, language-neutral, partially restorable.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # -- public ----------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[dict] = None,
             block: bool = False):
        """Snapshot to host and enqueue (or write synchronously)."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._thread is None or block:
            self._write(step, host_tree, metadata or {})
        else:
            self.wait()  # keep at most one in flight
            self._q.put((step, host_tree, metadata or {}))

    def wait(self):
        """Block until pending async writes complete; re-raise errors."""
        if self._thread is not None:
            self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self):
        if not os.path.isdir(self.dir):
            return []
        return sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                      if d.startswith("step_"))

    def restore(self, step: Optional[int] = None, template=None,
                shardings=None):
        """Load a checkpoint; optionally device_put onto new shardings.

        ``template`` (a pytree of like-structured values or
        ShapeDtypeStructs) rebuilds the tree structure; without it a flat
        {path: array} dict is returned.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {k: np.load(os.path.join(d, f"{i}.npy"))
                for i, k in enumerate(manifest["keys"])}
        meta = manifest.get("metadata", {})
        if template is None:
            return flat, meta
        tflat, treedef = _flatten(template)
        missing = [k for k in tflat if k not in flat]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
        leaves = [flat[k] for k in tflat]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta

    # -- internals ---------------------------------------------------------

    def _worker(self):
        while True:
            step, tree, meta = self._q.get()
            try:
                self._write(step, tree, meta)
            except BaseException as e:  # surfaced on next wait()
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host_tree, metadata: dict):
        flat, _ = _flatten(host_tree)
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        keys = list(flat.keys())
        for i, k in enumerate(keys):
            np.save(os.path.join(tmp, f"{i}.npy"), np.asarray(flat[k]))
        manifest = {"keys": keys, "step": step, "metadata": metadata,
                    "time": time.time()}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
