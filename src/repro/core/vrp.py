"""VRP tile — variable-precision arithmetic via floating-point expansions.

EPAC's VRP tile implements a chunk-based variable-precision FPU: wide
significands (up to 512 bits) are processed by iterating narrow hardware
units over chunks, with the active precision selected at runtime through
environment registers. TPUs have no wide FPU, so we adapt the *insight*
(precision as a runtime-tunable resource, latency scaling with precision)
using **floating-point expansions** (Priest/Shewchuk/Dekker):

  a value is an unevaluated sum  x = t_0 + t_1 + ... + t_{K-1}
  of K machine floats of decreasing magnitude.

All building blocks are *error-free transformations* (EFT): ``two_sum`` and
``two_prod`` return (result, error) pairs whose exact sum equals the exact
mathematical result — so precision is lost only when the expansion is
truncated back to K terms. K plays precisely the role of the VRP chunk
count: arithmetic cost scales ~O(K^2), matching the paper's "latency and
throughput scale with the selected precision".

Expansions are plain ``jnp`` arrays with a trailing axis of length K
(term 0 = highest magnitude), so every op here is shape-polymorphic and
vmappable — the long-vector (VEC) discipline applied to the VRP datapath.

``two_prod`` uses Dekker's algorithm with Veltkamp splitting, which is
exact without requiring an FMA primitive (XLA:CPU) and remains exact when
XLA fuses to FMA (XLA:TPU).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .precision import PrecisionEnv, get_env

# ---------------------------------------------------------------------------
# Error-free transformations
# ---------------------------------------------------------------------------


def two_sum(a, b):
    """Knuth's branch-free TwoSum: s + e == a + b exactly."""
    s = a + b
    a1 = s - b
    b1 = s - a1
    da = a - a1
    db = b - b1
    return s, da + db


def fast_two_sum(a, b):
    """Dekker's FastTwoSum; exact when |a| >= |b|."""
    s = a + b
    return s, b - (s - a)


def _split(a, splitter):
    """Veltkamp split: a == hi + lo with hi, lo half-width."""
    c = splitter * a
    hi = c - (c - a)
    return hi, a - hi


def two_prod(a, b, *, splitter=float(2**27 + 1)):
    """Dekker's TwoProd: p + e == a * b exactly (no FMA required)."""
    p = a * b
    ah, al = _split(a, splitter)
    bh, bl = _split(b, splitter)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


# ---------------------------------------------------------------------------
# Expansion construction / destruction
# ---------------------------------------------------------------------------


def from_float(x, env: PrecisionEnv):
    """Promote a plain array to a K-term expansion (value in term 0)."""
    env = get_env(env)
    x = jnp.asarray(x, env.dtype)
    pad = [(0, 0)] * x.ndim + [(0, env.K - 1)]
    return jnp.pad(x[..., None], pad)


def to_float(e):
    """Collapse an expansion to its base dtype (sum low terms first)."""
    acc = e[..., -1]
    for i in range(e.shape[-1] - 2, -1, -1):
        acc = acc + e[..., i]
    return acc


def zeros(shape, env: PrecisionEnv):
    env = get_env(env)
    return jnp.zeros(tuple(shape) + (env.K,), env.dtype)


# ---------------------------------------------------------------------------
# Renormalization (the VRP "normalization at full width" stage)
# ---------------------------------------------------------------------------


def _vecsum_pass(terms):
    """One VecSum distillation pass over the trailing axis, via lax.scan.

    Sequentially applies (t[i], t[i+1]) <- two_sum(t[i], t[i+1]) for
    i = M-2 .. 0, pushing dominant mass to index 0 and errors downward.
    Expressed as a scan so HLO size is O(1) in M (the unrolled form blew
    compile time up inside solver while-loops at high K).
    """
    M = terms.shape[-1]
    t = jnp.moveaxis(terms, -1, 0)  # (M, ...)

    def step(carry, ti):
        s, e = two_sum(ti, carry)
        return s, e

    carry, errs = jax.lax.scan(step, t[M - 1], t[: M - 1], reverse=True)
    # errs[i] is the error emitted when t[i] absorbed the running sum; it
    # belongs at slot i+1. Slot 0 is the final running sum.
    out = jnp.concatenate([carry[None], errs], axis=0)
    return jnp.moveaxis(out, 0, -1)


def renormalize(terms, K: int, passes: int | None = None):
    """Compress an (..., M)-term sum into a (..., K)-term expansion.

    Uses repeated VecSum distillation passes (Ogita–Rump–Oishi). Every
    two_sum is exact, so the *exact* value of the sum is invariant; only
    the final truncation to K terms rounds. ``passes`` trades accuracy
    against latency — the analogue of the VPFPU's full-width
    normalization pipeline stage.
    """
    M = terms.shape[-1]
    if M <= K:
        pad = [(0, 0)] * (terms.ndim - 1) + [(0, K - M)]
        terms = jnp.pad(terms, pad)
        M = K
    if passes is None:
        passes = 2 if K <= 2 else 3
    if M <= 6:
        # Small merges: unrolled bubble passes (cheaper at runtime).
        cols = [terms[..., i] for i in range(M)]
        for _ in range(passes):
            for i in range(M - 2, -1, -1):
                cols[i], cols[i + 1] = two_sum(cols[i], cols[i + 1])
        return jnp.stack(cols[:K], axis=-1)
    for _ in range(passes):
        terms = _vecsum_pass(terms)
    return terms[..., :K]


# ---------------------------------------------------------------------------
# Arithmetic
# ---------------------------------------------------------------------------


def add(x, y, env: PrecisionEnv):
    env = get_env(env)
    merged = jnp.concatenate(jnp.broadcast_arrays(x, y), axis=-1)
    return renormalize(merged, env.K)


def sub(x, y, env: PrecisionEnv):
    return add(x, -y, env)


def add_float(x, s, env: PrecisionEnv):
    """Expansion + plain float (Shewchuk grow-expansion, vectorized)."""
    env = get_env(env)
    s = jnp.broadcast_to(jnp.asarray(s, env.dtype), x.shape[:-1])
    merged = jnp.concatenate([x, s[..., None]], axis=-1)
    return renormalize(merged, env.K)


def scale(x, s, env: PrecisionEnv):
    """Expansion times plain float — exact partial products, then renorm."""
    env = get_env(env)
    s = jnp.asarray(s, env.dtype)
    p, e = two_prod(x, s[..., None], splitter=env.splitter)
    return renormalize(jnp.concatenate([p, e], axis=-1), env.K)


def mul(x, y, env: PrecisionEnv):
    """Expansion times expansion.

    Keeps partial products t_i * u_j with i + j <= K (magnitude-ordered
    truncation — precisely the chunk-iteration schedule of the VPFPU
    multiplier, which skips chunk products below the selected precision).
    """
    env = get_env(env)
    K = env.K
    x, y = jnp.broadcast_arrays(x, y)
    Kx, Ky = x.shape[-1], y.shape[-1]
    # All partial products at once (vectorized TwoProd over the K x K
    # outer grid), magnitude-truncated at order K: keep p where i+j <= K
    # and e where i+j < K. Zeroed-out entries are exact no-ops in renorm.
    p, e = two_prod(x[..., :, None], y[..., None, :], splitter=env.splitter)
    order = jnp.arange(Kx)[:, None] + jnp.arange(Ky)[None, :]
    p = jnp.where(order <= K, p, 0.0)
    e = jnp.where(order < K, e, 0.0)
    parts = jnp.concatenate(
        [p.reshape(p.shape[:-2] + (Kx * Ky,)),
         e.reshape(e.shape[:-2] + (Kx * Ky,))], axis=-1)
    return renormalize(parts, env.K)


def _const(val, like, env):
    return from_float(jnp.full(like.shape[:-1], val, env.dtype), env)


def reciprocal(y, env: PrecisionEnv):
    """Newton–Raphson reciprocal: r <- r * (2 - y*r); quadratic/iteration."""
    env = get_env(env)
    iters = env.newton_iters or max(1, (env.K - 1).bit_length() + 1)
    r = from_float(1.0 / to_float(y), env)
    two = _const(2.0, y, env)
    for _ in range(iters):
        r = mul(r, sub(two, mul(y, r, env), env), env)
    return r


def div(x, y, env: PrecisionEnv):
    return mul(x, reciprocal(y, env), env)


def sqrt(x, env: PrecisionEnv):
    """sqrt via Newton on r ~ 1/sqrt(x): r <- r*(3 - x*r^2)/2, then x*r."""
    env = get_env(env)
    iters = env.newton_iters or max(1, (env.K - 1).bit_length() + 1)
    r = from_float(1.0 / jnp.sqrt(to_float(x)), env)
    three = _const(3.0, x, env)
    for _ in range(iters):
        xr2 = mul(x, mul(r, r, env), env)
        r = scale(mul(r, sub(three, xr2, env), env), jnp.asarray(0.5, env.dtype), env)
    return mul(x, r, env)


# ---------------------------------------------------------------------------
# Reductions (tree-structured, vectorized — the long-vector discipline)
# ---------------------------------------------------------------------------


def tree_sum(x, env: PrecisionEnv, axis: int = 0):
    """Sum an array of expansions along ``axis`` by pairwise vp-adds.

    log2(n) vectorized levels; each level is an exact merge + renormalize,
    so worst-case error is ~log2(n) truncations instead of n.
    """
    env = get_env(env)
    x = jnp.moveaxis(x, axis, 0)
    n = x.shape[0]
    while n > 1:
        half = n // 2
        lo, hi = x[: 2 * half : 2], x[1 : 2 * half : 2]
        merged = add(lo, hi, env)
        if n % 2:
            merged = jnp.concatenate([merged, x[2 * half :]], axis=0)
        x = merged
        n = x.shape[0]
    return x[0]


def sum_floats(x, env: PrecisionEnv, axis: int = 0):
    """Extended-precision sum of a *plain* float array (cascaded)."""
    env = get_env(env)
    return tree_sum(from_float(jnp.moveaxis(jnp.asarray(x, env.dtype), axis, 0), env), env)


def dot(x, y, env: PrecisionEnv):
    """Extended-precision dot of two plain vectors (Ogita–Rump–Oishi DotK).

    Elementwise TwoProd (exact), then a compensated tree sum of the 2n
    partials. This is the VBLAS ``dot`` of the paper — the reduction that
    makes Krylov methods on ill-conditioned systems converge.
    """
    env = get_env(env)
    x = jnp.asarray(x, env.dtype)
    y = jnp.asarray(y, env.dtype)
    p, e = two_prod(x, y, splitter=env.splitter)
    partials = jnp.stack([p, e], axis=-1)  # (n, 2) exact products
    partials = renormalize(partials, env.K)
    return tree_sum(partials, env)


def dot_vp(x, y, env: PrecisionEnv):
    """Dot of two expansion vectors (n, K) x (n, K)."""
    env = get_env(env)
    return tree_sum(mul(x, y, env), env)


def matvec(A, x, env: PrecisionEnv):
    """Plain matrix (m, n) times expansion vector (n, K) -> (m, K).

    Exact per-element products against every expansion term, then a
    compensated tree reduction along n.
    """
    env = get_env(env)
    A = jnp.asarray(A, env.dtype)
    p, e = two_prod(A[..., None], x[None, ...], splitter=env.splitter)
    merged = renormalize(jnp.concatenate([p, e], axis=-1), env.K)  # (m, n, K)
    return tree_sum(merged, env, axis=1)
