"""Precision environment — software analogue of VRP's environment registers.

EPAC's VRP tile exposes runtime-configurable precision through *environment
registers*: the number of significand bits used in computation, and a
separately configurable *memory format* (how values are stored). We mirror
that split exactly:

  * ``compute_terms`` — how many expansion terms arithmetic carries
    (the chunk count the VPFPU iterates over); K terms of a base dtype with
    ``m`` mantissa bits give roughly ``K * (m+1)`` significand bits.
  * ``store_terms``  — how many terms are kept when a value is written back
    (the paper's extendable IEEE-754 memory format: 128/256/512-bit reprs).

Like the silicon, changing the environment does not require "recompiling"
user code — solvers take a ``PrecisionEnv`` and thread it through jit as a
static argument.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax.numpy as jnp
import numpy as np

# Mantissa bits (excluding the implicit leading 1) per base dtype.
_MANT_BITS = {"float32": 23, "float64": 52}
# Veltkamp splitting constants (2^ceil(m/2) + 1) for Dekker's two_prod.
_SPLITTERS = {"float32": float(2**12 + 1), "float64": float(2**27 + 1)}


@dataclasses.dataclass(frozen=True)
class PrecisionEnv:
    """Runtime precision configuration (analogue of VRP env registers)."""

    compute_terms: int = 2
    store_terms: int | None = None  # defaults to compute_terms
    base_dtype: str = "float64"
    # Newton refinement steps used by div/sqrt (latency knob, like the
    # VPFPU's iterative chunk pipelines).
    newton_iters: int | None = None

    def __post_init__(self):
        if self.base_dtype not in _MANT_BITS:
            raise ValueError(f"unsupported base dtype {self.base_dtype}")
        if self.compute_terms < 1:
            raise ValueError("compute_terms must be >= 1")
        if self.store_terms is not None and self.store_terms > self.compute_terms:
            raise ValueError("store_terms cannot exceed compute_terms")

    @property
    def K(self) -> int:
        return self.compute_terms

    @property
    def dtype(self):
        return jnp.dtype(self.base_dtype)

    @property
    def significand_bits(self) -> int:
        """Effective significand width — the paper's headline number.

        K=10 with float64 gives ~530 bits, matching VRP's 512-bit ceiling.
        """
        return self.compute_terms * (_MANT_BITS[self.base_dtype] + 1)

    @property
    def splitter(self) -> float:
        return _SPLITTERS[self.base_dtype]

    @property
    def eps(self) -> float:
        return float(np.finfo(self.base_dtype).eps)

    def storage(self) -> "PrecisionEnv":
        """Environment describing the memory format (store_terms wide)."""
        st = self.store_terms or self.compute_terms
        return dataclasses.replace(self, compute_terms=st, store_terms=st)


# Named presets mirroring the paper's memory formats (significand widths).
F64 = PrecisionEnv(compute_terms=1)            # plain double (53 bits)
VP128 = PrecisionEnv(compute_terms=2)          # ~106 bits  ("double-double")
VP256 = PrecisionEnv(compute_terms=5)          # ~265 bits
VP512 = PrecisionEnv(compute_terms=10)         # ~530 bits  (VRP ceiling)

PRESETS = {"f64": F64, "vp128": VP128, "vp256": VP256, "vp512": VP512}


def get_env(name_or_env) -> PrecisionEnv:
    if isinstance(name_or_env, PrecisionEnv):
        return name_or_env
    return PRESETS[str(name_or_env)]
