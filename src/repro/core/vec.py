"""VEC tile — vector-length-agnostic (VLA) execution discipline.

The VEC tile's defining software property (RVV 0.7.1): code sets a desired
vector length, hardware grants up to its maximum, and loops of *arbitrary*
size run with no scalar tail handling. The VPU retires a 256-element
double-precision vop in 32 cycles through 8 parallel FAUST lanes.

TPU has no scalable vector registers, so the *discipline* is what we port:

  * ``strip_mine``    — apply a lane-width kernel over an arbitrary-length
    array with masked tails (vsetvl semantics), as a lax.scan over strips.
  * ``VecTimingModel`` — the paper's cycle model (8 lanes x 8 elem/cycle,
    ~3-cycle decode overhead) used by benchmarks/bench_vec.py to validate
    utilization curves against §3.1's numbers.

The data pipeline and serving batcher use strip_mine for ragged batches;
elementwise model math is left to XLA (the "compiler-driven" path, like
LLVM-EPI auto-vectorization).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def strip_mine(fn: Callable, x: jnp.ndarray, max_vl: int, *, out_dtype=None):
    """Apply ``fn`` (vector -> vector, same length) VLA-style.

    Processes ``x`` (n, ...) in strips of ``max_vl`` with a masked final
    strip — no scalar tail, no recompilation per length (vsetvl analogue:
    the grant is min(max_vl, remaining)).
    """
    n = x.shape[0]
    n_strips = -(-n // max_vl)
    pad = n_strips * max_vl - n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    xs = xp.reshape((n_strips, max_vl) + x.shape[1:])
    base = jnp.arange(n_strips) * max_vl

    def body(carry, inp):
        strip, start = inp
        vl = jnp.minimum(max_vl, n - start)  # granted vector length
        mask = jnp.arange(max_vl) < vl
        out = fn(strip)
        out = jnp.where(mask.reshape((max_vl,) + (1,) * (out.ndim - 1)), out, 0)
        return carry, out

    _, ys = jax.lax.scan(body, None, (xs, base))
    ys = ys.reshape((n_strips * max_vl,) + ys.shape[2:])
    return ys[:n].astype(out_dtype or ys.dtype)


def strip_reduce(fn: Callable, x: jnp.ndarray, max_vl: int, init):
    """VLA-style reduction: fold strips through ``fn(acc, strip, mask)``."""
    n = x.shape[0]
    n_strips = -(-n // max_vl)
    pad = n_strips * max_vl - n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    xs = xp.reshape((n_strips, max_vl) + x.shape[1:])
    base = jnp.arange(n_strips) * max_vl

    def body(acc, inp):
        strip, start = inp
        mask = jnp.arange(max_vl) < (n - start)
        return fn(acc, strip, mask), None

    acc, _ = jax.lax.scan(body, init, (xs, base))
    return acc


@dataclasses.dataclass(frozen=True)
class VecTimingModel:
    """Cycle model of the EPAC VPU (§3.1): used to validate bench_vec.

    A vector arithmetic instruction on VL elements takes
    ``ceil(VL / (lanes * elems_per_lane)) + decode_overhead`` cycles; a full
    256-element vop = 32 + ~3 cycles.
    """

    lanes: int = 8
    elems_per_lane_cycle: int = 1
    max_vl_elems: int = 256          # 2048 B / 8 B per f64
    decode_overhead_cycles: int = 3
    freq_ghz: float = 1.0

    def vop_cycles(self, vl: int) -> int:
        per_cycle = self.lanes * self.elems_per_lane_cycle
        return -(-vl // per_cycle) + self.decode_overhead_cycles

    def utilization(self, vl: int) -> float:
        """Fraction of lane-cycles doing useful work at vector length vl."""
        per_cycle = self.lanes * self.elems_per_lane_cycle
        return vl / (self.vop_cycles(vl) * per_cycle)

    def gflops(self, vl: int, flops_per_elem: int = 2) -> float:
        return (vl * flops_per_elem * self.freq_ghz) / self.vop_cycles(vl)
