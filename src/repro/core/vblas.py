"""VBLAS — extended-precision BLAS-1/2 on expansion vectors.

The paper: "The VRP runs a RISC-V binary using specialized libraries
(e.g., VBLAS) to operate on extended-precision data types." This module is
that library for the JAX port. Vectors are expansions of shape (n, K);
scalars are expansions of shape (K,). All routines take a PrecisionEnv and
are jit-compatible with the env static.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import vrp
from .precision import PrecisionEnv, get_env


def vcopy(x):
    return x


def vneg(x):
    return -x


def vaxpy(alpha, x, y, env: PrecisionEnv):
    """y + alpha * x with alpha an expansion scalar, x/y expansion vectors."""
    env = get_env(env)
    return vrp.add(vrp.mul(x, alpha[None, :], env), y, env)


def vscal(alpha, x, env: PrecisionEnv):
    env = get_env(env)
    return vrp.mul(x, alpha[None, :], env)


def vdot(x, y, env: PrecisionEnv):
    """Expansion-vector dot product -> expansion scalar."""
    return vrp.dot_vp(x, y, env)


def vnrm2(x, env: PrecisionEnv):
    env = get_env(env)
    return vrp.sqrt(vrp.dot_vp(x, x, env), env)


def vgemv(A, x, env: PrecisionEnv):
    """Plain (m, n) matrix times expansion vector."""
    return vrp.matvec(A, x, env)


def from_plain(x, env: PrecisionEnv):
    return vrp.from_float(x, env)


def to_plain(x):
    return vrp.to_float(x)
