"""Uncore model — CHI NoC / distributed-L2 / C2C, mapped to the TPU fabric.

EPAC's uncore (§4): a 2-D-mesh CHI NoC (64 GB/s per port per direction at
1 GHz), distributed 256 kB L2 slices with programmable address
interleaving, a directory Home Node, and a 25 GB/s-per-direction C2C
SerDes link extending the NoC off-chip.

The TPU analogue we target (v5e):
  * on-pod ICI links  <-> NoC ports        (~50 GB/s per link)
  * pod-to-pod axis   <-> C2C SerDes       (slow tier; DP-only traffic)
  * sharded layouts   <-> L2 address interleaving
  * XLA SPMD          <-> Home-Node coherence (by construction)

This module is the *analytical* fabric model: collective time estimates on
a named mesh, used (a) by roofline/analysis.py to attribute the collective
term per mesh axis, and (b) by benchmarks/bench_noc.py to reproduce the
paper's §4 bandwidth table.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """Bandwidths in bytes/second per device for each mesh axis tier."""

    ici_bw: float = 50e9      # v5e per-link ICI (on-pod axes)
    pod_bw: float = 25e9      # pod-to-pod tier (EPAC C2C analogue: 25 GB/s)
    latency_us: float = 1.0   # per-hop software+link latency


V5E_FABRIC = FabricSpec()

# The paper's own numbers (bench_noc reproduces this table).
EPAC_NOC = {
    "noc_port_bw_GBps_per_dir": 64.0,   # 512 b/cycle @ 1 GHz
    "c2c_bw_GBps_per_dir": 25.0,        # 8 SerDes lanes x 25 Gb/s
    "c2c_bw_GBps_aggregate": 50.0,
    "c2c_demonstrated_GBps": 20.0,      # bring-up measured (§5)
    "l2_slice_kB": 256,
    "l2_line_bytes": 64,
    "l2_outstanding": 128,
}


def _axis_bw(axis: str, fabric: FabricSpec) -> float:
    return fabric.pod_bw if axis == "pod" else fabric.ici_bw


def p2p_time(nbytes: float, hops: int, axis: str,
             fabric: FabricSpec = V5E_FABRIC) -> float:
    """Point-to-point transfer estimate: one source, one destination,
    ``hops`` links of the given axis tier apart.

    The EPAC analogue is a tile-to-tile line transfer over the CHI NoC
    (or across the C2C SerDes when the peers sit on different dies):
    the payload serializes once onto the first link and cuts through —
    wormhole routing, not store-and-forward — so bandwidth is paid once
    and only the per-hop latency accumulates with distance:

        time = nbytes / bw(axis) + hops * latency_us * 1e-6

    ``hops <= 0`` (same device) is free. Used by the serving layer's
    KV-block migration accounting (launch/engine/transport.py) to price
    a prefill->decode cache handoff the way the uncore prices an L2
    line movement.
    """
    if hops <= 0:
        return 0.0
    bw = _axis_bw(axis, fabric)
    return nbytes / bw + hops * fabric.latency_us * 1e-6


def all_reduce_time(bytes_per_device: float, axis_size: int, axis: str,
                    fabric: FabricSpec = V5E_FABRIC) -> float:
    """Ring all-reduce: 2(n-1)/n * bytes over the axis link."""
    if axis_size <= 1:
        return 0.0
    bw = _axis_bw(axis, fabric)
    return 2.0 * (axis_size - 1) / axis_size * bytes_per_device / bw


def all_gather_time(bytes_per_device_shard: float, axis_size: int, axis: str,
                    fabric: FabricSpec = V5E_FABRIC) -> float:
    """Ring all-gather of per-device shards: (n-1) * shard bytes."""
    if axis_size <= 1:
        return 0.0
    bw = _axis_bw(axis, fabric)
    return (axis_size - 1) * bytes_per_device_shard / bw


def reduce_scatter_time(bytes_per_device: float, axis_size: int, axis: str,
                        fabric: FabricSpec = V5E_FABRIC) -> float:
    if axis_size <= 1:
        return 0.0
    bw = _axis_bw(axis, fabric)
    return (axis_size - 1) / axis_size * bytes_per_device / bw


def all_to_all_time(bytes_per_device: float, axis_size: int, axis: str,
                    fabric: FabricSpec = V5E_FABRIC) -> float:
    if axis_size <= 1:
        return 0.0
    bw = _axis_bw(axis, fabric)
    return (axis_size - 1) / axis_size * bytes_per_device / bw


def interleave(addr: int, n_slices: int, line_bytes: int = 64,
               mode: str = "line") -> int:
    """EPAC L2 'programmable address interleaving' -> slice id.

    ``line`` interleaves consecutive cache lines across slices (the NoC
    default); ``block`` keeps 4 KiB blocks per slice. The sharding layer's
    layout rules are the tensor-level version of this choice.
    """
    if mode == "line":
        return (addr // line_bytes) % n_slices
    if mode == "block":
        return (addr // 4096) % n_slices
    raise ValueError(mode)
