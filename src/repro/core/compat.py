"""Version-skew shims for the pinned jax (0.4.x) vs newer APIs.

Two renames bite this codebase:

* ``jax.shard_map`` — promoted out of ``jax.experimental.shard_map`` in
  newer jax; the experimental path is the one that exists at 0.4.x.
* ``pltpu.CompilerParams`` — named ``TPUCompilerParams`` at 0.4.x.

All repo code imports these from here so either jax generation works.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as _pltpu

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

if hasattr(_pltpu, "CompilerParams"):
    TPUCompilerParams = _pltpu.CompilerParams
else:  # jax <= 0.4.x
    TPUCompilerParams = _pltpu.TPUCompilerParams

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:  # jax <= 0.4.x: no manual-axis variance typing; identity is correct
    def pvary(x, axis_name):
        del axis_name
        return x


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a single dict on newer jax but
    a one-element list of dicts at 0.4.x."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
