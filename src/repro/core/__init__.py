"""core — the paper's contribution: tiles (VEC/STX/VRP) + uncore model."""

from .precision import F64, VP128, VP256, VP512, PrecisionEnv, get_env
from .tiles import DEFAULT_POLICY, STX_POLICY, TilePolicy

__all__ = [
    "F64", "VP128", "VP256", "VP512", "PrecisionEnv", "get_env",
    "TilePolicy", "DEFAULT_POLICY", "STX_POLICY",
]
