"""Krylov solvers at runtime-selectable precision — the VRP use case.

The paper's target workload: "iterative linear solvers, such as Krylov
methods (e.g., CG, BiCG, PCG), where increasing precision can reduce
rounding errors, improve convergence, or enable convergence for
ill-conditioned systems" (refs [19][20]). These solvers run *entirely* in
expansion arithmetic (vectors, scalars, and reductions), with the precision
chosen at call time via PrecisionEnv — no recompilation of user code, as in
the silicon's environment registers.

All solvers are functional, jit-able (env static), and use lax.while_loop.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import vblas, vrp
from .precision import PrecisionEnv, get_env


class SolveResult(NamedTuple):
    x: jnp.ndarray          # solution as plain base-dtype array
    iterations: jnp.ndarray
    residual: jnp.ndarray   # final relative residual (plain float)
    converged: jnp.ndarray


def _as_matvec(A) -> Callable:
    if callable(A):
        return A
    return lambda x, env: vrp.matvec(A, x, env)


def _to_expansion(b, env):
    """Accept either a plain (n,) vector or an (n, K) expansion."""
    b = jnp.asarray(b)
    if b.ndim == 2:
        K = get_env(env).K
        if b.shape[-1] < K:
            b = jnp.pad(b, [(0, 0), (0, K - b.shape[-1])])
        return b[:, :K]
    return vrp.from_float(jnp.asarray(b, get_env(env).dtype), env)


@partial(jax.jit, static_argnames=("env", "maxiter", "matvec"))
def _cg_impl(A, b, env, tol, maxiter, matvec=None):
    env = get_env(env)
    mv = matvec if matvec is not None else (lambda v: vrp.matvec(A, v, env))
    bE = _to_expansion(b, env)
    bnorm = vrp.to_float(vblas.vnrm2(bE, env))
    x = vrp.zeros(bE.shape[:-1], env)
    r = bE
    p = r
    rz = vblas.vdot(r, r, env)

    def cond(state):
        x, r, p, rz, k, res = state
        return jnp.logical_and(k < maxiter, res > tol)

    def body(state):
        x, r, p, rz, k, _ = state
        Ap = mv(p)
        pAp = vblas.vdot(p, Ap, env)
        alpha = vrp.div(rz, pAp, env)
        x = vblas.vaxpy(alpha, p, x, env)
        r = vblas.vaxpy(-alpha, Ap, r, env)
        rz_new = vblas.vdot(r, r, env)
        beta = vrp.div(rz_new, rz, env)
        p = vblas.vaxpy(beta, p, r, env)
        res = jnp.sqrt(jnp.abs(vrp.to_float(rz_new))) / bnorm
        return x, r, p, rz_new, k + 1, res

    init = (x, r, p, rz, jnp.array(0, jnp.int32), jnp.array(jnp.inf, env.dtype))
    x, r, p, rz, k, res = jax.lax.while_loop(cond, body, init)
    return SolveResult(vrp.to_float(x), k, res, res <= tol)


def cg(A, b, env: PrecisionEnv, tol: float = 1e-10, maxiter: int = 1000):
    """Conjugate Gradient in expansion arithmetic. A: (n, n) SPD (plain)."""
    return _cg_impl(A, b, get_env(env), tol, maxiter)


@partial(jax.jit, static_argnames=("env", "maxiter"))
def _pcg_impl(A, b, Minv_diag, env, tol, maxiter):
    env = get_env(env)
    bE = _to_expansion(b, env)
    bnorm = vrp.to_float(vblas.vnrm2(bE, env))
    x = vrp.zeros(bE.shape[:-1], env)
    r = bE

    def precond(v):  # Jacobi: exact elementwise scale
        return vrp.scale(v, Minv_diag, env)

    z = precond(r)
    p = z
    rz = vblas.vdot(r, z, env)

    def cond(state):
        *_, k, res = state
        return jnp.logical_and(k < maxiter, res > tol)

    def body(state):
        x, r, z, p, rz, k, _ = state
        Ap = vrp.matvec(A, p, env)
        alpha = vrp.div(rz, vblas.vdot(p, Ap, env), env)
        x = vblas.vaxpy(alpha, p, x, env)
        r = vblas.vaxpy(-alpha, Ap, r, env)
        z = precond(r)
        rz_new = vblas.vdot(r, z, env)
        beta = vrp.div(rz_new, rz, env)
        p = vblas.vaxpy(beta, p, z, env)
        res = jnp.abs(vrp.to_float(vblas.vnrm2(r, env))) / bnorm
        return x, r, z, p, rz_new, k + 1, res

    init = (x, r, z, p, rz, jnp.array(0, jnp.int32), jnp.array(jnp.inf, env.dtype))
    x, r, z, p, rz, k, res = jax.lax.while_loop(cond, body, init)
    return SolveResult(vrp.to_float(x), k, res, res <= tol)


def pcg(A, b, env: PrecisionEnv, tol: float = 1e-10, maxiter: int = 1000):
    """Jacobi-preconditioned CG in expansion arithmetic."""
    Minv = 1.0 / jnp.diagonal(jnp.asarray(A, get_env(env).dtype))
    return _pcg_impl(A, b, Minv, get_env(env), tol, maxiter)


@partial(jax.jit, static_argnames=("env", "maxiter"))
def _bicgstab_impl(A, b, env, tol, maxiter):
    env = get_env(env)
    bE = _to_expansion(b, env)
    bnorm = vrp.to_float(vblas.vnrm2(bE, env))
    x = vrp.zeros(bE.shape[:-1], env)
    r = bE
    rhat = r
    one = vrp.from_float(jnp.asarray(1.0, env.dtype), env)
    rho = one
    alpha = one
    omega = one
    v = vrp.zeros(bE.shape[:-1], env)
    p = vrp.zeros(bE.shape[:-1], env)

    def cond(state):
        *_, k, res = state
        return jnp.logical_and(k < maxiter, res > tol)

    def body(state):
        x, r, rho, alpha, omega, v, p, k, _ = state
        rho_new = vblas.vdot(rhat, r, env)
        beta = vrp.mul(vrp.div(rho_new, rho, env), vrp.div(alpha, omega, env), env)
        p = vblas.vaxpy(beta, vblas.vaxpy(-omega, v, p, env), r, env)
        v = vrp.matvec(A, p, env)
        alpha = vrp.div(rho_new, vblas.vdot(rhat, v, env), env)
        s = vblas.vaxpy(-alpha, v, r, env)
        t = vrp.matvec(A, s, env)
        omega = vrp.div(vblas.vdot(t, s, env), vblas.vdot(t, t, env), env)
        x = vblas.vaxpy(alpha, p, vblas.vaxpy(omega, s, x, env), env)
        r = vblas.vaxpy(-omega, t, s, env)
        res = jnp.abs(vrp.to_float(vblas.vnrm2(r, env))) / bnorm
        return x, r, rho_new, alpha, omega, v, p, k + 1, res

    init = (x, r, rho, alpha, omega, v, p, jnp.array(0, jnp.int32),
            jnp.array(jnp.inf, env.dtype))
    x, r, rho, alpha, omega, v, p, k, res = jax.lax.while_loop(cond, body, init)
    return SolveResult(vrp.to_float(x), k, res, res <= tol)


def bicgstab(A, b, env: PrecisionEnv, tol: float = 1e-10, maxiter: int = 1000):
    """BiCGStab in expansion arithmetic (paper ref [20]'s stabilized use)."""
    return _bicgstab_impl(A, jnp.asarray(b), get_env(env), tol, maxiter)


# ---------------------------------------------------------------------------
# Test problems (ill-conditioned SPD systems, the paper's target class)
# ---------------------------------------------------------------------------


def hilbert_like(n: int, cond: float = 1e12, dtype=jnp.float64, seed: int = 0):
    """Random SPD matrix with prescribed condition number."""
    key = jax.random.PRNGKey(seed)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n), dtype))
    eigs = jnp.logspace(0.0, -jnp.log10(cond), n).astype(dtype)
    return (Q * eigs) @ Q.T


def hilbert(n: int, dtype=jnp.float64):
    """The Hilbert matrix — the classic ill-conditioned SPD example."""
    i = jnp.arange(n, dtype=dtype)
    return 1.0 / (1.0 + i[:, None] + i[None, :])
