"""Tile registry & dispatch — the EPAC heterogeneity made software.

EPAC integrates three compute tiles "not intended to operate together in
parallel, but rather to explore different architectural solutions and study
their behavior in a real system". Here a *tile* is an execution strategy
for an operator class, selectable per-op and per-model:

  VEC — general path: XLA-compiled jnp (compiler-driven vectorization,
        the analogue of the LLVM-EPI auto-vectorizer on the Avispado+VPU).
  STX — explicit-data-movement path: Pallas kernels with BlockSpec/VMEM
        tiling (SSR/FREP/scratchpad in silicon).
  VRP — extended-precision path: expansion arithmetic for numerically
        sensitive reductions and solvers.

A TilePolicy maps operator classes -> tile, so the same model runs on any
mix; benchmarks compare the strategies "under the same system-level
constraints", as the paper does in silicon.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

VALID_TILES = ("vec", "stx", "vrp")
OP_CLASSES = ("matmul", "attention", "stencil", "scan", "reduction")


@dataclasses.dataclass(frozen=True)
class TilePolicy:
    """Operator-class -> tile assignment (hashable; jit-static)."""

    matmul: str = "vec"
    attention: str = "vec"
    stencil: str = "stx"
    scan: str = "vec"
    reduction: str = "vec"
    # STX cluster geometry (paper: 4 clusters x 8 cores, 64-256 kB TCDM).
    stx_block_m: int = 128
    stx_block_n: int = 128
    stx_block_k: int = 128
    # VRP environment preset for 'vrp' reductions.
    vrp_env: str = "vp128"
    # On CPU, run Pallas kernels in interpret mode (tests); the jnp ref is
    # used for dry-run lowering so HLO stays representative.
    interpret: bool = False

    def __post_init__(self):
        for cls in OP_CLASSES:
            tile = getattr(self, cls)
            if tile not in VALID_TILES:
                raise ValueError(f"{cls}: unknown tile {tile!r}")

    def tile_for(self, op_class: str) -> str:
        return getattr(self, op_class)


# Paper-faithful default: general work on VEC, stencils on STX.
DEFAULT_POLICY = TilePolicy()
# All-STX policy: every hot op through Pallas (the "beyond-paper" point).
STX_POLICY = TilePolicy(matmul="stx", attention="stx", scan="stx")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dispatch_matmul(x, w, policy: TilePolicy):
    """Matmul through the policy's tile."""
    tile = policy.tile_for("matmul")
    if tile == "stx" and (on_tpu() or policy.interpret):
        from repro.kernels import ops as kops

        return kops.stx_matmul(x, w, block_m=policy.stx_block_m,
                               block_n=policy.stx_block_n,
                               block_k=policy.stx_block_k,
                               interpret=policy.interpret)
    # VEC path (and STX's jnp-identical lowering for dry-run on CPU).
    return jnp.einsum("...k,kn->...n", x, w)


def dispatch_reduction(x, policy: TilePolicy, axis=None):
    """Sum-reduction; 'vrp' uses compensated (expansion) accumulation."""
    tile = policy.tile_for("reduction")
    if tile == "vrp":
        from repro.core import vrp
        from repro.core.precision import get_env

        env = get_env(policy.vrp_env)
        flat = x.reshape(-1) if axis is None else jnp.moveaxis(x, axis, 0)
        return vrp.to_float(vrp.sum_floats(flat.astype(env.dtype), env)).astype(x.dtype)
    return jnp.sum(x, axis=axis)
