"""STX tile executor — cluster geometry -> kernel block geometry.

The silicon STX tile is parameterized: 4 clusters x (4-16 compute cores +
1 DMA core) x 64-256 kB TCDM scratchpad. The TPU adaptation keeps that
parameterization: an ``StxCluster`` maps the cluster geometry onto Pallas
block shapes whose VMEM working set respects the scratchpad budget, and
dispatches the STX kernels (kernels/ops.py) with those blocks. The VMEM
budget check is the software analogue of fitting the TCDM.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class StxCluster:
    """Paper-faithful defaults: 4 clusters x 8 cores @ 1 GHz, 256 kB."""

    n_clusters: int = 4
    cores_per_cluster: int = 8
    tcdm_kb: int = 256          # per-cluster scratchpad (VMEM analogue)
    freq_ghz: float = 1.0
    flops_per_core_cycle: int = 2   # DP FMA

    @property
    def peak_gflops(self) -> float:
        """The paper's 64 DP GFLOPS/tile claim at the defaults."""
        return (self.n_clusters * self.cores_per_cluster
                * self.flops_per_core_cycle * self.freq_ghz)

    # -- geometry ---------------------------------------------------------

    def matmul_blocks(self, dtype=jnp.float32) -> tuple:
        """Largest MXU-aligned square blocks with x/w/acc in budget."""
        itemsize = jnp.dtype(dtype).itemsize
        b = 128
        while 3 * (2 * b) ** 2 * itemsize <= self.tcdm_kb * 1024 * 4:
            b *= 2
        return b, b, b

    def stencil_blocks(self, dtype=jnp.float32) -> tuple:
        itemsize = jnp.dtype(dtype).itemsize
        bm = bn = 128
        while 2 * (2 * bm + 2) * (bn + 2) * itemsize <= self.tcdm_kb * 1024 * 4:
            bm *= 2
        return bm, bn

    def working_set_kb(self, block_m: int, block_n: int, block_k: int,
                       dtype=jnp.float32) -> float:
        itemsize = jnp.dtype(dtype).itemsize
        return (block_m * block_k + block_k * block_n
                + block_m * block_n) * itemsize / 1024

    # -- dispatch ---------------------------------------------------------

    def matmul(self, x, w, mode="auto", **kw):
        bm, bn, bk = self.matmul_blocks(x.dtype)
        return kops.stx_matmul(x, w, block_m=bm, block_n=bn, block_k=bk,
                               mode=mode, **kw)

    def stencil2d(self, x, weights, mode="auto", **kw):
        bm, bn = self.stencil_blocks(x.dtype)
        return kops.stencil2d(x, weights, block_m=bm, block_n=bn,
                              mode=mode, **kw)

    def stencil3d(self, x, weights, mode="auto", **kw):
        return kops.stencil3d(x, weights, mode=mode, **kw)


DEFAULT_CLUSTER = StxCluster()
