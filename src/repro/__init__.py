"""repro — EPAC-JAX: a heterogeneous-tile training/inference framework.

Reproduction of *EPAC: The Last Dance* (Mantovani et al., CF Companion '26)
adapted TPU-natively: the chip's three RISC-V compute tiles become three
execution strategies (VEC = XLA long-vector path, STX = Pallas scratchpad
kernels, VRP = variable-precision expansion arithmetic) under one
distribution fabric (the "uncore": mesh + collectives + sharded layouts).
"""

__version__ = "0.1.0"
