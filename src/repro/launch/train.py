"""Training driver: step builder + fault-tolerant loop.

Features exercised end-to-end by examples/train_lm.py and the integration
tests:
  * pjit train_step with 2-D FSDP x TP shardings (launch/sharding.py),
  * microbatch gradient accumulation (scan, f32 accumulators),
  * global-norm clipping (optionally via the VRP compensated reduction),
  * Kahan-compensated bf16 params (OptConfig.kahan),
  * checkpoint/restart (atomic + async, resume == uninterrupted run —
    tests/test_train_loop.py asserts bitwise-close resumption),
  * straggler detection hooks (step-time outlier monitor),
  * deterministic data skipping (data/pipeline.py batch_at(step)).

Run:  PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_source
from repro.launch import sharding as shlib
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.models.transformer import RunCtx
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.optim.schedule import warmup_cosine


def make_train_step(model: Model, opt_cfg: OptConfig, ctx: RunCtx,
                    lr_fn: Callable):
    """Pure (state, batch) -> (state, metrics); jit/pjit-ready."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, ctx)

    def grads_of(params, batch):
        if opt_cfg.grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        A = opt_cfg.grad_accum

        adt = jnp.dtype(opt_cfg.accum_dtype)

        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, gg: (a.astype(jnp.float32)
                               + gg.astype(jnp.float32) / A).astype(adt),
                acc, g)
            return (acc, loss_acc + loss / A), None

        split = jax.tree.map(
            lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, adt), params)
        (grads, loss), _ = jax.lax.scan(micro, (zero, 0.0), split)
        return loss, {"loss": loss}, grads

    def train_step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        lr = lr_fn(state["opt"]["step"])
        new_params, new_opt, om = apply_updates(
            state["params"], grads, state["opt"], opt_cfg, lr)
        metrics = {**metrics, **om, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(model: Model, opt_cfg: OptConfig, seed: int = 0):
    params = model.init(jax.random.PRNGKey(seed))
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def state_specs(state_shapes, shard: shlib.ShardCtx):
    pspecs = shlib.param_specs(state_shapes["params"], shard)
    ospecs = shlib.opt_state_specs(pspecs, state_shapes["opt"], shard)
    return {"params": pspecs, "opt": ospecs}


class StragglerMonitor:
    """Step-time outlier detector (straggler mitigation hook).

    At 1000-node scale the mitigation action is re-sharding around the
    slow host (launch/elastic.py); single-process here, so the monitor
    records and exposes decisions for the driver.
    """

    def __init__(self, window: int = 32, threshold: float = 3.0):
        self.times: list[float] = []
        self.window = window
        self.threshold = threshold
        self.flags = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) < 8:
            return False
        med = float(np.median(hist[:-1]))
        is_straggler = dt > self.threshold * med
        self.flags += int(is_straggler)
        return is_straggler


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None


def train_loop(model: Model, opt_cfg: OptConfig, ctx: RunCtx,
               data_cfg: DataConfig, loop_cfg: TrainLoopConfig,
               mesh=None, lr_fn=None, state=None, fail_at: Optional[int] = None):
    """Fault-tolerant training loop. Returns (state, metrics history).

    ``fail_at`` raises mid-run (tests use it to validate restart).
    Restores from the latest checkpoint in ckpt_dir if one exists.
    """
    lr_fn = lr_fn or functools.partial(
        warmup_cosine, peak_lr=3e-4, warmup_steps=20,
        total_steps=loop_cfg.steps)
    step_fn = make_train_step(model, opt_cfg, ctx, lr_fn)
    source = make_source(data_cfg)
    ckpt = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    monitor = StragglerMonitor()

    if mesh is not None:
        shard = shlib.make_shard_ctx(mesh)
        state_shapes = jax.eval_shape(
            lambda: init_state(model, opt_cfg))
        sspec = shlib.named(mesh, state_specs(state_shapes, shard))
        bspec = shlib.named(mesh, shlib.batch_specs(
            source.batch_at(0), shard))
        step_fn = jax.jit(step_fn, in_shardings=(sspec, bspec),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        template = jax.eval_shape(lambda: init_state(model, opt_cfg))
        shardings = None
        if mesh is not None:
            shardings = sspec
        state, meta = ckpt.restore(latest, template=template,
                                   shardings=shardings)
        start_step = int(meta.get("step", latest))
    elif state is None:
        state = init_state(model, opt_cfg)
        if mesh is not None:
            state = jax.device_put(state, sspec)

    history = []
    for step in range(start_step, loop_cfg.steps):
        if fail_at is not None and step == fail_at:
            ckpt.wait()
            raise RuntimeError(f"injected failure at step {step}")
        batch = source.batch_at(step)
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        metrics = jax.tree.map(float, jax.device_get(metrics))
        dt = time.time() - t0
        straggler = monitor.observe(dt)
        metrics.update(step=step, dt=dt, straggler=straggler)
        history.append(metrics)
        if loop_cfg.metrics_path:
            with open(loop_cfg.metrics_path, "a") as f:
                f.write(json.dumps(metrics) + "\n")
        if step % loop_cfg.log_every == 0:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics.get('grad_norm', 0):.2f} {dt*1e3:.0f} ms",
                  flush=True)
        if (step + 1) % loop_cfg.ckpt_every == 0 or step + 1 == loop_cfg.steps:
            ckpt.save(step + 1, state, metadata={"step": step + 1})
    ckpt.wait()
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--kahan", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    opt_cfg = OptConfig(kahan=args.kahan, grad_accum=args.grad_accum)
    ctx = RunCtx(kernel_mode="ref")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    mesh = make_local_mesh(tp=args.tp) if len(jax.devices()) > 1 else None
    loop_cfg = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir)
    _, hist = train_loop(model, opt_cfg, ctx, data_cfg, loop_cfg, mesh=mesh)
    print(f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
