"""StaticBackend: the lockstep batcher behind the unified Engine API.

A batch of waiting requests is admitted at once, prefilled as one
RIGHT-padded batch (real tokens at positions 0..len-1, so causal
attention never sees a pad key and rope positions match the unbatched
reference — fixing the PR-1 ``Server`` left-pad leak), then decoded in
lockstep with PER-ROW positions until every member finishes; only then
is the next batch admitted. Finished rows ride along shape-stably with
their outputs discarded. Dense (B, max_len) cache — no paging, no
preemption; the baseline the paged backend is benchmarked against.

Per-row prefill true lengths thread through ``model.prefill`` so ring
and recurrent caches capture state at each row's real boundary; prompt
lengths are padded to power-of-two buckets so the prefill jit cache
stays O(log max_len). Models whose prefill state cannot be extracted at
a traced length (mlstm/slstm) batch FCFS runs of equal prompt length
instead (exact prefill, no pad tokens ever enter the recurrence).
"""

from __future__ import annotations

import collections
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding as shlib
from repro.launch.engine.api import (EngineConfig, RequestHandle,
                                     RequestOutput, prefill_bucket,
                                     register_sample)
from repro.launch.engine.sampling import SlotSampler


class StaticBackend:
    """Lockstep batcher over a dense (B, max_len) cache (the baseline).

    One batch in, right-padded batched prefill, per-row-position decode
    until every member finishes, then the next batch — no paging, no
    preemption. See the module docstring for the padding/bucketing
    contract; the serve bench prices it against the paged backend at
    equal cache memory."""

    def __init__(self, model, params, cfg: EngineConfig, ctx):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.ragged = model.serving_caps().ragged_prefill
        B = cfg.num_slots
        self.waiting: collections.deque[RequestHandle] = collections.deque()
        self.finished: list[RequestHandle] = []
        self.batch: list[Optional[RequestHandle]] = [None] * B
        self.live = np.zeros((B,), bool)
        self.lengths = np.ones((B,), np.int32)
        self.last = np.zeros((B,), np.int32)
        self.cache = None
        self.sampler = SlotSampler(B)
        self.made_progress = False
        # telemetry
        self.steps = 0
        self.batches = 0
        self.slot_steps = 0
        self.live_token_steps = 0

        # Mesh-sharded serving: commit params once; shlib.jit_step pins
        # the cache's NamedShardings on every jit output so prefill
        # hands decode a stably-placed cache (batch over data axes,
        # kv-heads/state width over TP — launch/sharding.py cache rules).
        self.shard = ctx.shard
        self._cache_sh = None
        if self.shard is not None:
            self.params = shlib.place_params(params, self.shard)
            shapes = jax.eval_shape(
                lambda: model.init_cache(B, cfg.max_len))
            self._cache_sh = shlib.named(
                self.shard.mesh, shlib.batch_specs(shapes, self.shard))

        def decode_fn(params, cache, tokens, lengths):
            return model.decode_step(params, cache, tokens, lengths,
                                     self.ctx)

        self._decode = shlib.jit_step(decode_fn, self.shard,
                                      self._cache_sh, donate=(1,))
        self._prefill_cache = {}

    # -- public backend API ---------------------------------------------

    def enqueue(self, req: RequestHandle):
        """Append to the FCFS queue (validated by the caller)."""
        self.waiting.append(req)

    @property
    def num_active(self) -> int:
        """Live rows in the current lockstep batch."""
        return int(self.live.sum())

    @property
    def has_work(self) -> bool:
        """True while any request is waiting or live."""
        return bool(self.waiting) or bool(self.live.any())

    def live_handles(self):
        """Resident + queued request handles (latency aggregation —
        see ``api.latency_stats``)."""
        return [h for h in self.batch if h is not None
                and not h.finished] + list(self.waiting)

    def step(self) -> list[RequestOutput]:
        """Admit a fresh batch when idle, else one lockstep decode."""
        outs: list[RequestOutput] = []
        self.made_progress = False
        if not self.live.any():
            if not self.waiting:
                return outs
            self._admit_batch(outs)
            return outs
        rows = np.flatnonzero(self.live)
        tokens = jnp.asarray(self.last[:, None])
        logits, self.cache = self._decode(
            self.params, self.cache, tokens, jnp.asarray(self.lengths))
        toks = self.sampler.sample(logits)
        self.steps += 1
        self.slot_steps += len(rows)
        self.made_progress = True
        for i in rows:
            self.lengths[i] += 1          # the fed token got cached
            self.live_token_steps += int(self.lengths[i])
            outs.append(self._accept(int(i), int(toks[i])))
        if not self.live.any():
            self._clear_batch()
        return outs

    # -- internals ------------------------------------------------------

    def _admit_batch(self, outs: list[RequestOutput]):
        """Lockstep admission IS batched prefill admission here: the
        whole batch prefills as one right-padded call (one jit trace
        per pow-2 bucket of the max member). Admission is NOT
        fragmented by bucket — a lockstep lane idled by a bucket split
        stays idle for the entire generation cycle, which costs far
        more than the padding it saves. ``max_prefill_batch`` (> 0)
        bounds the admitted width and hence the prefill call width."""
        B = self.cfg.num_slots
        cap = B if self.cfg.max_prefill_batch <= 0 else \
            min(B, self.cfg.max_prefill_batch)
        reqs = []
        while self.waiting and len(reqs) < cap:
            # models without length-exact padded prefill (mlstm/slstm)
            # batch FCFS runs of EQUAL prompt length — correctness over
            # packing; the paged backend has no such restriction
            if not self.ragged and reqs and \
                    len(self.waiting[0].prompt) != len(reqs[0].prompt):
                break
            reqs.append(self.waiting.popleft())
        plens = [len(r.prompt) for r in reqs]
        Lb = self._bucket(max(plens))
        toks = np.zeros((B, Lb), np.int32)
        lens = np.ones((B,), np.int32)    # dummy rows: harmless length 1
        for i, r in enumerate(reqs):
            toks[i, :plens[i]] = r.prompt
            lens[i] = plens[i]
        logits, self.cache = self._prefill(Lb)(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        # each row's next-token logits live at its true last position
        row_logits = jnp.take_along_axis(
            logits, jnp.asarray(lens - 1)[:, None, None], axis=1)[:, 0]
        self.batches += 1
        self.lengths[:] = lens
        self.last[:] = 0
        for i, r in enumerate(reqs):
            self.batch[i] = r
            self.live[i] = True
            self.sampler.install(i, r.sampling, 0)
        first = self.sampler.sample(row_logits)
        for i in range(len(reqs)):
            outs.append(self._accept(i, int(first[i])))
        self.made_progress = True
        if not self.live.any():           # whole batch stopped at prefill
            self._clear_batch()

    def _bucket(self, maxp: int) -> int:
        if not self.ragged:
            return maxp                   # uniform lengths: exact
        # same floor/cap policy as the paged backend (one shared helper)
        # so both engines compile identical bucket sets on one trace
        return prefill_bucket(maxp, self.cfg.block_size, self.cfg.max_len)

    def _prefill(self, Lb: int):
        fn = self._prefill_cache.get(Lb)
        if fn is None:
            model, cfg, ctx = self.model, self.cfg, self.ctx
            ragged = self.ragged

            def prefill_fn(params, tokens, lengths):
                return model.prefill(params, {"tokens": tokens}, ctx,
                                     max_len=cfg.max_len,
                                     length=lengths if ragged else None)

            fn = shlib.jit_step(prefill_fn, self.shard, self._cache_sh)
            self._prefill_cache[Lb] = fn
        return fn

    def _accept(self, i: int, tok: int) -> RequestOutput:
        out = register_sample(self.batch[i], tok, self.cfg.eos_id,
                              lambda: self._finish(i))
        if not out.finished:
            self.sampler.steps[i] = self.batch[i]._n_sampled
            self.last[i] = tok
        return out

    def _finish(self, i: int):
        """Backend cleanup after register_sample flagged the handle."""
        self.finished.append(self.batch[i])
        self.live[i] = False              # rides along until batch ends

    def _clear_batch(self):
        B = self.cfg.num_slots
        self.batch = [None] * B
        self.live[:] = False
        self.lengths[:] = 1
        self.last[:] = 0
        self.cache = None
        for i in range(B):
            self.sampler.clear(i)

    # -- reporting ------------------------------------------------------

    def reset_telemetry(self):
        """Zero the counters behind ``stats()`` (e.g. after bench
        warmup); does not touch scheduling state or jit caches."""
        self.finished.clear()
        self.steps = self.batches = 0
        self.slot_steps = self.live_token_steps = 0

    def stats(self) -> dict:
        """Occupancy/utilization telemetry (dense-cache denominator:
        every lane pays max_len whether live or not)."""
        cap = self.steps * self.cfg.num_slots * self.cfg.max_len or 1
        return {
            "steps": self.steps,
            "batches": self.batches,
            "mean_active_slots": self.slot_steps / max(self.steps, 1),
            "cache_utilization": self.live_token_steps / cap,
            "prefill_compiles": len(self._prefill_cache),
        }
