"""PagedBackend: continuous batching over the block-paged KV cache.

Successor of the PR-1 ``Scheduler`` with the three ROADMAP serving items
landed:

* **Optimistic admission** — a request is admitted when the pool covers
  its *current* footprint (plus an optional free-block watermark), not
  its worst case. More concurrency on skewed traces; the pool can now
  genuinely run out mid-flight, which is handled by —
* **LIFO preemption** — when a sequence needs a growth block and the
  pool is dry, the most recently admitted active sequence is evicted:
  its blocks are freed, its state collapses to a host-side *recompute
  record* (prompt + emitted tokens + RNG-stream position), and it
  re-prefills over its full history on re-admission (front of queue).
  The oldest admission is never evicted, so it always runs to
  completion and the engine cannot livelock. Sampled outputs survive
  preemption bit-exactly because each request's RNG stream is a pure
  function of (seed, stream position).
* **Bucketed prefill** — prompts are right-padded to the next
  power-of-two bucket and prefilled through one jit per *bucket*
  (O(log max_len) compiles instead of one per distinct length). Causal
  attention keeps padded keys invisible; per-row true lengths thread
  through ``model.prefill`` so ring/recurrent caches capture state at
  the real boundary; pad-tail cache blocks are routed to the reserved
  null block. Models whose state cannot be re-extracted at a traced
  length (mlstm/slstm) fall back to exact-length prefill automatically.
* **Batched prefill admission** — each admission drains the maximal
  FCFS *prefix* of the queue that shares the head's prefill bucket
  (up to ``max_prefill_batch`` and the free-slot/pool budget) and
  prefills it as ONE right-padded batch call, scattering each row's
  true-length cache into its slot. Batch widths are power-of-two
  bucketed too, so the jit cache stays at one trace per
  (prompt-bucket, batch-bucket) pair. Strictly a prefix — never
  skip-ahead — so FCFS fairness survives batching.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding as shlib
from repro.launch.engine.api import (EngineConfig, RequestHandle,
                                     RequestOutput, prefill_bucket,
                                     register_sample)
from repro.launch.engine.sampling import SlotSampler
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx


@dataclasses.dataclass
class _Slot:
    req: Optional[RequestHandle] = None
    blocks: list[int] = dataclasses.field(default_factory=list)
    last_token: int = 0
    ticket: int = -1             # admission order; LIFO preemption key


class PagedBackend:
    """Host-side scheduler state + jit'd device steps (paged pools)."""

    def __init__(self, model: Model, params, cfg: EngineConfig,
                 ctx: RunCtx):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.layout = paged_kv.PagedLayout(
            num_slots=cfg.num_slots, num_blocks=cfg.num_blocks,
            block_size=cfg.block_size, max_len=cfg.max_len)
        self.alloc = paged_kv.BlockAllocator(
            self.layout, watermark=cfg.watermark_blocks)
        self.pools = model.init_paged_cache(self.layout)
        # Mesh-sharded serving: commit params and pools to their
        # NamedShardings once; shlib.jit_step pins every step's outputs
        # to the same shardings (stable placement, exact pool donation).
        self.shard = ctx.shard
        self._pool_sh = None
        if self.shard is not None:
            self.params = shlib.place_params(params, self.shard)
            self._pool_sh = shlib.named(
                self.shard.mesh,
                model.paged_cache_specs(self.layout, self.shard))
            self.pools = jax.device_put(self.pools, self._pool_sh)
        self.table = np.full(
            (cfg.num_slots, self.layout.max_blocks_per_seq),
            paged_kv.NULL_BLOCK, np.int32)
        self.lengths = np.zeros((cfg.num_slots,), np.int32)
        self.slots = [_Slot() for _ in range(cfg.num_slots)]
        self.sampler = SlotSampler(cfg.num_slots)
        self.waiting: collections.deque[RequestHandle] = collections.deque()
        self.finished: list[RequestHandle] = []
        self.ragged_prefill = (cfg.bucketed_prefill
                               and model.supports_ragged_prefill())
        self.made_progress = False
        self._ticket = 0
        # telemetry
        self.steps = 0
        self.slot_steps = 0          # active slots summed over steps
        self.block_token_steps = 0   # allocated token capacity x steps
        self.live_token_steps = 0    # live tokens x steps
        self.preemptions = 0
        self.prefill_calls = 0       # batched prefill launches
        self.prefill_reqs = 0        # requests prefilled (>= calls)

        def decode_fn(params, pools, table, lengths, tokens):
            return model.decode_step_paged(params, pools, table, lengths,
                                           tokens, self.ctx)

        self._decode = shlib.jit_step(decode_fn, self.shard,
                                      self._pool_sh, donate=(1,))
        self._prefill_cache = {}

    # -- public backend API ---------------------------------------------

    def check_request(self, prompt_len: int, sampling):
        """Reject requests whose WORST-CASE footprint exceeds the pool
        (they could never run to completion even alone)."""
        worst = paged_kv.blocks_for(
            prompt_len + sampling.max_tokens, self.cfg.block_size)
        if worst > self.layout.usable_blocks:
            raise ValueError(
                f"request worst case ({worst} blocks) exceeds pool "
                f"capacity ({self.layout.usable_blocks} usable blocks) — "
                "it could never run to completion even alone")

    def enqueue(self, req: RequestHandle):
        """Append to the FCFS queue. Callers validate first
        (Engine.add_request / the ReplicaSet shared queue both run
        check_request) — no double check here."""
        self.waiting.append(req)

    @property
    def num_active(self) -> int:
        """Occupied decode slots."""
        return sum(s.req is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        """True while any request is waiting or active."""
        return bool(self.waiting) or self.num_active > 0

    def step(self) -> list[RequestOutput]:
        """Admissions, growth (with preemption), one decode, sampling."""
        outs: list[RequestOutput] = []
        self.made_progress = False
        self._admit(outs)
        self._grow_blocks()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return outs
        tokens = np.zeros((self.cfg.num_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].last_token
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(self.table),
            jnp.asarray(self.lengths), jnp.asarray(tokens))
        toks = self.sampler.sample(logits)
        self.steps += 1
        self.slot_steps += len(active)
        self.block_token_steps += self.alloc.used_count * self.cfg.block_size
        self.made_progress = True
        for i in active:
            self.lengths[i] += 1          # the fed token got cached
            self.live_token_steps += int(self.lengths[i])
            outs.append(self._accept(i, int(toks[i])))
        return outs

    # -- internals ------------------------------------------------------

    def _accept(self, i: int, tok: int) -> RequestOutput:
        """Register one sampled token for slot i; emit/stop/retire."""
        slot = self.slots[i]
        out = register_sample(slot.req, tok, self.cfg.eos_id,
                              lambda: self._retire(i))
        if not out.finished:
            self.sampler.steps[i] = slot.req._n_sampled
            slot.last_token = tok
        return out

    def _grow_blocks(self):
        """Allocate growth blocks oldest-admission-first; when the pool
        is dry, preempt LIFO until the allocation fits (a sequence may
        preempt itself if it is the newest — it then waits in queue)."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.req is not None),
            key=lambda i: self.slots[i].ticket)
        for i in order:
            slot = self.slots[i]
            if slot.req is None:          # preempted earlier in this pass
                continue
            L = int(self.lengths[i])
            if L % self.cfg.block_size != 0 or \
                    L // self.cfg.block_size < len(slot.blocks):
                continue
            while not self.alloc.can_alloc(1):
                cands = [(j, self.slots[j].ticket)
                         for j, s in enumerate(self.slots)
                         if s.req is not None]
                victim = self.alloc.select_victim(cands)
                self._preempt(victim)
                if victim == i:
                    break
            if slot.req is None:
                continue
            (nb,) = self.alloc.alloc(1)
            slot.blocks.append(nb)
            self.table[i, len(slot.blocks) - 1] = nb

    def _imminent_growth(self) -> int:
        """Growth blocks active sequences will claim THIS step. Counted
        into admission so a new request cannot grab the last free blocks
        only to be LIFO-preempted by an older sequence's growth in the
        same step — a full prefill wasted per step until something
        retires."""
        bs = self.cfg.block_size
        return sum(1 for i, s in enumerate(self.slots)
                   if s.req is not None
                   and int(self.lengths[i]) % bs == 0
                   and int(self.lengths[i]) // bs >= len(s.blocks))

    def _cached_tokens(self, req: RequestHandle) -> list[int]:
        """Tokens a (re-)admitted request must have in cache before its
        next decode: the prompt, plus all-but-the-last emitted token on
        a preemption resume (the last one is fed to decode)."""
        if req._n_sampled > 0:            # preempted: re-prefill history
            return list(req.prompt) + req.token_ids[:-1]
        return list(req.prompt)

    def _bucket_key(self, S: int):
        """The prefill-trace identity of a cached length: the padded
        token width for ragged models, the exact length otherwise.
        Requests batch together iff their keys match."""
        bs = self.cfg.block_size
        if self.ragged_prefill:
            cap = paged_kv.blocks_for(self.cfg.max_len, bs) * bs
            return paged_kv.blocks_for(prefill_bucket(S, bs, cap), bs) * bs
        return ("exact", S)

    def _drain_bucket_run(self) -> list[RequestHandle]:
        """Pop the maximal FCFS PREFIX of the queue that (a) fits the
        free slots and the pool (cumulative current footprint + this
        step's imminent growth, watermark headroom while anything else
        runs), (b) shares the queue head's prefill bucket, and (c) stays
        within ``max_prefill_batch``. Strictly a prefix: a request that
        does not fit ends the run — no skipping ahead — so batching
        cannot starve the head of the queue."""
        free = sum(1 for s in self.slots if s.req is None)
        if not free:
            return []
        cap = free if self.cfg.max_prefill_batch <= 0 else \
            min(free, self.cfg.max_prefill_batch)
        run: list[RequestHandle] = []
        need = self._imminent_growth()
        key0 = None
        for req in self.waiting:
            if len(run) >= cap:
                break
            S = len(self._cached_tokens(req))
            key = self._bucket_key(S)
            if run and key != key0:
                break
            # + 1: the admitted slot decodes THIS step, caching the fed
            # token at position ``cached`` — without that block counted
            # a boundary-length request admits then self-preempts,
            # wasting a full prefill every step
            need += paged_kv.blocks_for(S + 1, self.cfg.block_size)
            # watermark headroom only matters while others are running;
            # a sole request must always pass (progress guarantee)
            strict = self.num_active > 0 or bool(run)
            if not self.alloc.can_admit(need, strict=strict):
                break
            run.append(req)
            key0 = key
        for _ in run:
            self.waiting.popleft()
        return run

    def _admit(self, outs: list[RequestOutput]):
        while self.waiting:
            run = self._drain_bucket_run()
            if not run:
                return                    # FCFS: no skipping ahead
            self._place_batch(run, outs)

    def _place_batch(self, reqs: list[RequestHandle],
                     outs: list[RequestOutput]):
        """Prefill ``reqs`` (all sharing one bucket) as ONE right-padded
        batch call and scatter each row's true-length cache into its
        slot. Rows are FCFS-ordered, so emission order matches the old
        one-at-a-time admission exactly."""
        bs = self.cfg.block_size
        free_slots = [i for i, s in enumerate(self.slots) if s.req is None]
        rows = []                          # (slot, req, cached, S, ids)
        for req in reqs:
            cached = self._cached_tokens(req)
            S = len(cached)
            nbp = paged_kv.blocks_for(S, bs)
            block_ids = self.alloc.alloc(nbp)
            i = free_slots.pop(0)
            slot = self.slots[i]
            slot.req = req
            slot.blocks = block_ids
            slot.ticket = self._ticket
            self._ticket += 1
            rows.append((i, req, cached, S, block_ids))
        fn, tok_w, cache_w, Nb = self._prefill(rows[0][3], len(rows))
        nbc = cache_w // bs
        toks = np.zeros((Nb, tok_w), np.int32)
        lens = np.ones((Nb,), np.int32)    # batch fillers: harmless len 1
        ids = np.full((Nb, nbc), paged_kv.NULL_BLOCK, np.int32)
        row_of_slot = np.zeros((self.cfg.num_slots,), np.int32)
        valid = np.zeros((self.cfg.num_slots,), bool)
        for r, (i, req, cached, S, block_ids) in enumerate(rows):
            toks[r, :S] = cached           # exact path: tok_w == S, no pad
            lens[r] = S
            ids[r, :len(block_ids)] = block_ids  # pad tail -> null block
            row_of_slot[i] = r
            valid[i] = True
            self.table[i, :] = paged_kv.NULL_BLOCK
            self.table[i, :len(block_ids)] = block_ids
            self.lengths[i] = S
        args = (self.params, self.pools, jnp.asarray(toks),
                jnp.asarray(ids), jnp.asarray(row_of_slot),
                jnp.asarray(valid), jnp.asarray(lens))
        row_logits, self.pools = fn(*args)
        self.prefill_calls += 1
        self.prefill_reqs += len(rows)
        row_logits = np.asarray(row_logits)  # (Nb, V): per-row position S-1
        self.made_progress = True
        for r, (i, req, cached, S, block_ids) in enumerate(rows):
            self.sampler.install(i, req.sampling, req._n_sampled)
            if req._n_sampled > 0:         # resume: nothing new to sample
                self.slots[i].last_token = req.token_ids[-1]
                continue
            outs.append(self._accept(
                i, self.sampler.sample_one(i, row_logits[r:r + 1])))
        self._post_admit(rows)

    def _prefill(self, S: int, n: int):
        """Prefill+pack, jit-cached per (prompt-bucket, batch-bucket):
        prompts pad to the power-of-two BUCKET (ragged models) or stay
        at the exact length (fallback — tokens keep width S, so
        recurrent chunk scans never see a pad token); batch widths pad
        to the next power of two (capped at num_slots). Returns
        (fn, token_width, cache_width, batch_width); cache_width is
        always a block multiple (pow-2 buckets are rounded up for
        non-pow-2 blocks)."""
        bs = self.cfg.block_size
        if self.ragged_prefill:
            Sb = self._bucket_key(S)
            tok_w = Sb
        else:
            Sb = paged_kv.blocks_for(S, bs) * bs
            tok_w = S
        Nb = min(1 << max(n - 1, 0).bit_length(), self.cfg.num_slots)
        key = (Sb, Nb) if self.ragged_prefill else ("exact", S, Nb)
        fn = self._prefill_cache.get(key)
        if fn is None:
            model, layout, ctx = self.model, self.layout, self.ctx
            ragged = self.ragged_prefill

            def prefill_fn(params, pools, tokens, block_ids, row_of_slot,
                           valid, length):
                logits, dense = model.prefill(
                    params, {"tokens": tokens}, ctx, max_len=Sb,
                    length=length if ragged else None)
                pools = model.pack_prefill_into_paged(
                    layout, pools, dense, row_of_slot, valid, block_ids)
                # only each row's next-token logits leave the device:
                # (Nb, V) instead of the full (Nb, tok_w, V) slab
                rows = jnp.take_along_axis(
                    logits, (length - 1)[:, None, None], axis=1)[:, 0]
                return rows, pools

            fn = shlib.jit_step(prefill_fn, self.shard, self._pool_sh,
                                donate=(1,))
            self._prefill_cache[key] = fn
        return fn, tok_w, Sb, Nb

    def _preempt(self, i: int):
        """Evict slot i to a host-side recompute record (LIFO victim)."""
        slot = self.slots[i]
        req = slot.req
        req.num_preemptions += 1
        self.preemptions += 1
        self.alloc.free(slot.blocks)
        self._clear_slot(i)
        self.waiting.appendleft(req)      # preempted work goes first
        self.made_progress = True

    def _retire(self, i: int):
        """Backend cleanup after register_sample flagged the handle."""
        slot = self.slots[i]
        self.finished.append(slot.req)
        self.alloc.free(slot.blocks)
        self._clear_slot(i)

    def _clear_slot(self, i: int):
        slot = self.slots[i]
        slot.req = None
        slot.blocks = []
        slot.last_token = 0
        slot.ticket = -1
        self.table[i, :] = paged_kv.NULL_BLOCK
        self.lengths[i] = 0
        self.sampler.clear(i)
        self._post_clear(i)

    def _post_admit(self, rows):
        """Subclass hook: ``(slot, req, cached, S, block_ids)`` rows just
        admitted (the speculative backend installs drafter state here)."""

    def _post_clear(self, i: int):
        """Subclass hook: slot ``i`` was just retired or preempted."""

    # -- reporting ------------------------------------------------------

    def reset_telemetry(self):
        """Zero the counters behind ``stats()`` (e.g. after bench
        warmup); does not touch scheduling state or jit caches."""
        self.finished.clear()
        self.steps = self.slot_steps = 0
        self.block_token_steps = self.live_token_steps = 0
        self.preemptions = 0
        self.prefill_calls = self.prefill_reqs = 0

    def stats(self) -> dict:
        """Cache/occupancy/scheduling telemetry for the run so far."""
        cap = self.block_token_steps or 1
        return {
            "steps": self.steps,
            "mean_active_slots": self.slot_steps / max(self.steps, 1),
            "cache_utilization": self.live_token_steps / cap,
            "blocks_free": self.alloc.free_count,
            "blocks_used": self.alloc.used_count,
            "preemptions": self.preemptions,
            "prefill_compiles": len(self._prefill_cache),
            "prefill_calls": self.prefill_calls,
            "prefill_reqs": self.prefill_reqs,
            "bucketed_prefill": self.ragged_prefill,
        }
