"""PagedBackend: continuous batching over the block-paged KV cache.

Successor of the PR-1 ``Scheduler`` with the three ROADMAP serving items
landed:

* **Optimistic admission** — a request is admitted when the pool covers
  its *current* footprint (plus an optional free-block watermark), not
  its worst case. More concurrency on skewed traces; the pool can now
  genuinely run out mid-flight, which is handled by —
* **LIFO preemption** — when a sequence needs a growth block and the
  pool is dry, the most recently admitted active sequence is evicted:
  its blocks are freed, its state collapses to a host-side *recompute
  record* (prompt + emitted tokens + RNG-stream position), and it
  re-prefills over its full history on re-admission (front of queue).
  The oldest admission is never evicted, so it always runs to
  completion and the engine cannot livelock. Sampled outputs survive
  preemption bit-exactly because each request's RNG stream is a pure
  function of (seed, stream position).
* **Bucketed prefill** — prompts are right-padded to the next
  power-of-two bucket and prefilled through one jit per *bucket*
  (O(log max_len) compiles instead of one per distinct length). Causal
  attention keeps padded keys invisible; per-row true lengths thread
  through ``model.prefill`` so ring/recurrent caches capture state at
  the real boundary; pad-tail cache blocks are routed to the reserved
  null block. Models whose state cannot be re-extracted at a traced
  length (mlstm/slstm) fall back to exact-length prefill automatically.
* **Batched prefill admission** — each admission drains the maximal
  FCFS *prefix* of the queue that shares the head's prefill bucket
  (up to ``max_prefill_batch`` and the free-slot/pool budget) and
  prefills it as ONE right-padded batch call, scattering each row's
  true-length cache into its slot. Batch widths are power-of-two
  bucketed too, so the jit cache stays at one trace per
  (prompt-bucket, batch-bucket) pair. Strictly a prefix — never
  skip-ahead — so FCFS fairness survives batching.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding as shlib
from repro.launch.engine.api import (EngineConfig, RequestHandle,
                                     RequestOutput, prefill_bucket,
                                     register_sample)
from repro.launch.engine.sampling import SlotSampler, fused_sample
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx


@dataclasses.dataclass
class _Slot:
    req: Optional[RequestHandle] = None
    blocks: list[int] = dataclasses.field(default_factory=list)
    last_token: int = 0
    ticket: int = -1             # admission order; LIFO preemption key
    shared: int = 0              # leading blocks held by shared reference


@dataclasses.dataclass
class _Pending:
    """One dispatched decode whose sampled tokens are still on device
    (overlap mode). ``rows`` records (slot, ticket) pairs so a harvest
    can discard draws whose slot retired or was re-admitted in between
    (tickets are globally monotonic — equality proves same request);
    ``toks`` is the (num_slots,) int32 device array; ``t_dispatch``
    feeds the non-overlapping device-busy clock."""
    rows: list
    toks: object
    t_dispatch: float


class PagedBackend:
    """Host-side scheduler state + jit'd device steps (paged pools)."""

    # Role specialization (launch/engine/disagg.py): a prefill-only
    # backend runs admission + prefill and returns before the decode
    # phase — its slots never grow, preempt or COW; they are exported
    # as MigrationPackets by the disaggregated front-end instead.
    prefill_only = False

    def __init__(self, model: Model, params, cfg: EngineConfig,
                 ctx: RunCtx):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.layout = paged_kv.PagedLayout(
            num_slots=cfg.num_slots, num_blocks=cfg.num_blocks,
            block_size=cfg.block_size, max_len=cfg.max_len)
        self.caps = model.serving_caps()
        # Quantized paged KV: a jit-static PoolSpec threaded through the
        # RunCtx (write frontiers + fused-dequant kernels) and the pool
        # constructors; None keeps the bf16 path bit-identical.
        self.kv_spec = None
        if getattr(cfg, "kv_dtype", "bf16") != "bf16":
            self.kv_spec = paged_kv.make_pool_spec(
                model.cfg, self.layout, kv_dtype=cfg.kv_dtype)
            ctx = dataclasses.replace(ctx, kv_spec=self.kv_spec)
        self.ctx = ctx
        # COW prefix caching: only when EVERY layer's decode state lives
        # in the shared pool blocks (rings/SSM carries are per-slot and
        # a matched block chain cannot reconstruct them)
        self.prefix = paged_kv.PrefixIndex(cfg.block_size) \
            if cfg.prefix_cache and self.caps.prefix_cache else None
        self.alloc = paged_kv.BlockAllocator(
            self.layout, watermark=cfg.watermark_blocks,
            on_evict=self._on_evict if self.prefix is not None else None)
        # Cross-KV arena (encoder-decoder): one row per resident
        # request, refcount-shared across identical feature arrays,
        # freed with the slot at retirement AND preemption (resume
        # re-encodes — the recompute philosophy of the block pool).
        self.arena = paged_kv.CrossArena(cfg.num_slots) \
            if self.caps.cross_attn else None
        self.arena_ids = np.zeros((cfg.num_slots,), np.int32)
        self.enc_lengths = np.zeros((cfg.num_slots,), np.int32)
        self.arena_hits = 0          # admissions sharing a resident row
        self.pools = model.init_paged_cache(self.layout,
                                            spec=self.kv_spec)
        # Mesh-sharded serving: commit params and pools to their
        # NamedShardings once; shlib.jit_step pins every step's outputs
        # to the same shardings (stable placement, exact pool donation).
        self.shard = ctx.shard
        self._pool_sh = None
        if self.shard is not None:
            self.params = shlib.place_params(params, self.shard)
            self._pool_sh = shlib.named(
                self.shard.mesh,
                model.paged_cache_specs(self.layout, self.shard,
                                        spec=self.kv_spec))
            self.pools = jax.device_put(self.pools, self._pool_sh)
        self.table = np.full(
            (cfg.num_slots, self.layout.max_blocks_per_seq),
            paged_kv.NULL_BLOCK, np.int32)
        self.lengths = np.zeros((cfg.num_slots,), np.int32)
        self.slots = [_Slot() for _ in range(cfg.num_slots)]
        self.sampler = SlotSampler(cfg.num_slots)
        self.waiting: collections.deque[RequestHandle] = collections.deque()
        self.finished: list[RequestHandle] = []
        self.ragged_prefill = (cfg.bucketed_prefill
                               and self.caps.ragged_prefill)
        # Expert-sharded MoE decode runs the shard_map whose batch spec
        # requires B to divide |dp| — true for the decode/verify widths
        # (num_slots, checked by the Engine) but NOT for pow-2 prefill
        # batch buckets (e.g. a single admission), so prefill keeps the
        # unsharded expert path and lets GSPMD partition it.
        self.prefill_ctx = dataclasses.replace(ctx, moe_sharded=False) \
            if ctx.moe_sharded else ctx
        self.made_progress = False
        self._ticket = 0
        # Async host/device overlap (cfg.overlap): the one in-flight
        # decode awaiting its token fetch, plus outputs harvested
        # outside step() (migration flushes) owed to the next step.
        self._pending: Optional[_Pending] = None
        self._flushed: list[RequestOutput] = []
        # telemetry
        self.steps = 0
        # Device-busy clock: union of dispatch->fetch intervals stamped
        # with the monotonic clock at the call boundaries, so overlapped
        # dispatch never double-counts in-flight device time (the
        # ReplicaSet busy-clock fix — see stats()["device_s"]).
        self.device_s = 0.0
        self._t_fetch_done = 0.0
        self.slot_steps = 0          # active slots summed over steps
        self.block_token_steps = 0   # allocated token capacity x steps
        self.live_token_steps = 0    # live tokens x steps
        self.preemptions = 0
        self.prefill_calls = 0       # batched prefill launches
        self.prefill_reqs = 0        # requests prefilled (>= calls)
        self.prefill_tokens = 0      # real tokens computed at admission
        self.prefix_lookups = 0      # admissions that consulted the index
        self.prefix_hits = 0         # admissions with a non-empty match
        self.prefix_hit_tokens = 0   # prompt tokens served from cache
        self.cow_copies = 0          # shared blocks copied before a write
        self.prefix_evictions = 0    # indexed blocks reclaimed by alloc

        if self.arena is not None:
            def decode_fn(params, pools, table, lengths, tokens,
                          arena_ids, enc_lengths):
                return model.decode_step_paged(
                    params, pools, table, lengths, tokens, self.ctx,
                    arena_ids=arena_ids, enc_lengths=enc_lengths)
        else:
            def decode_fn(params, pools, table, lengths, tokens):
                return model.decode_step_paged(params, pools, table,
                                               lengths, tokens, self.ctx)

        self._decode = shlib.jit_step(decode_fn, self.shard,
                                      self._pool_sh, donate=(1,))

        # Fused overlap step (cfg.overlap): token-feed select + decode +
        # on-device sampling in ONE jit call, so the overlapped path
        # pays a single dispatch per step and the logits never leave
        # the device — only the (num_slots,) sampled tokens are fetched,
        # one step later. ``use_prev`` rows take their fed token from
        # the previous step's device-resident draws (the double buffer).
        if self.arena is not None:
            def overlap_fn(params, pools, table, lengths, host_tokens,
                           prev_toks, use_prev, steps, samp,
                           arena_ids, enc_lengths):
                tokens = jnp.where(use_prev[:, None], prev_toks[:, None],
                                   host_tokens)
                logits, pools = model.decode_step_paged(
                    params, pools, table, lengths, tokens, self.ctx,
                    arena_ids=arena_ids, enc_lengths=enc_lengths)
                return fused_sample(logits, steps, samp), pools
        else:
            def overlap_fn(params, pools, table, lengths, host_tokens,
                           prev_toks, use_prev, steps, samp):
                tokens = jnp.where(use_prev[:, None], prev_toks[:, None],
                                   host_tokens)
                logits, pools = model.decode_step_paged(
                    params, pools, table, lengths, tokens, self.ctx)
                return fused_sample(logits, steps, samp), pools

        self._overlap_step = shlib.jit_step(overlap_fn, self.shard,
                                            self._pool_sh, donate=(1,))
        self._zero_toks = None       # lazy (num_slots,) int32 zero feed
        self._no_prev = np.zeros((cfg.num_slots,), bool)
        self._prefill_cache = {}
        self._suffix_cache = {}

        def cow_fn(pools, src, dst):
            # duplicate physical block src into dst across every pool
            # leaf (leading layer-count axis, then the block axis) —
            # only reachable when supports_prefix_cache gated the tree
            # to pure pool leaves
            return jax.tree.map(
                lambda p: p.at[:, dst].set(p[:, src]), pools)

        if self.shard is None:
            self._cow = jax.jit(cow_fn, donate_argnums=(0,))
        else:
            self._cow = jax.jit(cow_fn, donate_argnums=(0,),
                                out_shardings=self._pool_sh)

    # -- public backend API ---------------------------------------------

    def check_request(self, prompt_len: int, sampling):
        """Reject requests whose WORST-CASE footprint exceeds the pool
        (they could never run to completion even alone)."""
        worst = paged_kv.blocks_for(
            prompt_len + sampling.max_tokens, self.cfg.block_size)
        if worst > self.layout.usable_blocks:
            raise ValueError(
                f"request worst case ({worst} blocks) exceeds pool "
                f"capacity ({self.layout.usable_blocks} usable blocks) — "
                "it could never run to completion even alone")

    def enqueue(self, req: RequestHandle):
        """Append to the FCFS queue. Callers validate first
        (Engine.add_request / the ReplicaSet shared queue both run
        check_request) — no double check here."""
        self.waiting.append(req)

    @property
    def num_active(self) -> int:
        """Occupied decode slots."""
        return sum(s.req is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        """True while any request is waiting or active (or a migration
        flush harvested outputs the next step still owes the stream)."""
        return bool(self.waiting) or self.num_active > 0 \
            or bool(self._flushed)

    def step(self) -> list[RequestOutput]:
        """Admissions, growth (with preemption), one decode, sampling.

        With ``cfg.overlap`` the call routes through ``_step_overlap``:
        the decode for THIS step is dispatched before the previous
        step's sampled tokens are fetched, so host scheduling work
        hides under device compute. Token values are identical either
        way (the RNG-stream contract)."""
        outs: list[RequestOutput] = []
        self.made_progress = False
        if self._flushed:              # harvested during a migration
            outs.extend(self._flushed)
            self._flushed = []
            self.made_progress = True
        if self.cfg.overlap and not self.prefill_only:
            return self._step_overlap(outs)
        if self._pending is not None:  # overlap residue (role flip)
            outs.extend(self._harvest(self._pending))
            self._pending = None
        self._admit(outs)
        if self.prefill_only:
            return outs               # role-specialized: no decode here
        self._grow_blocks()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return outs
        self._ensure_cow(active)       # may LIFO-preempt under pressure
        active = [i for i in active if self.slots[i].req is not None]
        if not active:
            return outs
        tokens = np.zeros((self.cfg.num_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].last_token
        args = (self.params, self.pools, jnp.asarray(self.table),
                jnp.asarray(self.lengths), jnp.asarray(tokens))
        if self.arena is not None:
            args += (jnp.asarray(self.arena_ids),
                     jnp.asarray(self.enc_lengths))
        t0 = time.monotonic()
        logits, self.pools = self._decode(*args)
        toks = self.sampler.sample(logits)
        self._mark_device(t0)
        self.steps += 1
        self.slot_steps += len(active)
        self.block_token_steps += self.alloc.used_count * self.cfg.block_size
        self.made_progress = True
        for i in active:
            self.lengths[i] += 1          # the fed token got cached
            self.live_token_steps += int(self.lengths[i])
            outs.append(self._accept(i, int(toks[i])))
        return outs

    # -- async host/device overlap (cfg.overlap) -------------------------

    def _step_overlap(self, outs: list[RequestOutput]):
        """One overlapped step: (1) if a decode is in flight, try to
        dispatch THIS step's decode first, feeding the in-flight
        sampled tokens device-to-device (``_try_followup``); (2) block
        on the in-flight fetch and register its tokens; (3) admit — the
        admission prefill consumes the pools produced by whichever
        decode was dispatched last, so its writes are ordered after
        them by the data dependency; (4) when no follow-up could be
        dispatched, fall back to the sequential shape (growth with
        preemption, COW, dispatch) and leave the new decode pending.

        Outputs are bit-identical to the sequential path: every fed
        token and RNG-stream position matches, and the speculative
        writes of a follow-up that covered a row retired at harvest
        land only at positions nothing live ever reads (the row's own
        frontier, or blocks whose later reuse is write-ordered after
        this decode by the functional pool threading)."""
        pend, self._pending = self._pending, None
        followed = False
        if pend is not None:
            followed = self._try_followup(pend)
            outs.extend(self._harvest(pend))
        self._admit(outs)
        if followed:
            return outs
        self._grow_blocks()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return outs
        self._ensure_cow(active)
        active = [i for i in active if self.slots[i].req is not None]
        if not active:
            return outs
        tokens = np.zeros((self.cfg.num_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].last_token
        self._dispatch_decode(active, tokens, self._no_prev, None,
                              self.sampler.steps)
        return outs

    def _try_followup(self, pend: _Pending) -> bool:
        """Dispatch the next decode BEFORE harvesting ``pend`` when it
        is safe without host knowledge of the in-flight tokens:

        * rows whose in-flight token deterministically retires them
          (max_tokens reached) are excluded — their slot frees at
          harvest and must not decode again;
        * growth blocks and COW copies for every dispatched row must be
          allocatable WITHOUT preemption (preempting a row whose last
          token is still on device would need that token for the
          recompute record) — any shortfall bails to the sequential
          path, which may preempt after the harvest. Partial
          allocations are safe to keep: the sequential growth/COW
          passes skip rows already extended/privatized.

        An in-flight token that turns out to be a stop token retires
        its row at harvest anyway; the follow-up's draw for that row is
        discarded by the ticket check one step later, and its cache
        write landed one past the row's final frontier — never read.
        Returns True when the follow-up decode was dispatched."""
        bs = self.cfg.block_size
        inflight = set()
        for i, ticket in pend.rows:
            s = self.slots[i]
            if s.req is not None and s.ticket == ticket:
                inflight.add(i)
        dispatch = []
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if i in inflight and \
                    len(s.req.token_ids) + 1 >= s.req.sampling.max_tokens:
                continue              # harvest retires this row for sure
            dispatch.append(i)
        if not dispatch:
            return False
        for i in dispatch:
            slot = self.slots[i]
            L = int(self.lengths[i])
            if L % bs == 0 and L // bs >= len(slot.blocks):
                if not self.alloc.can_alloc(1):
                    return False      # pool dry: sequential path preempts
                (nb,) = self.alloc.alloc(1)
                slot.blocks.append(nb)
                self.table[i, len(slot.blocks) - 1] = nb
            if self.prefix is not None:
                idx = L // bs
                if idx < slot.shared:
                    assert idx == slot.shared - 1, \
                        "write frontier deeper than the shared tail block"
                    if not self.alloc.can_alloc(1):
                        return False
                    self._cow_block(i, idx)
        host = np.zeros((self.cfg.num_slots, 1), np.int32)
        use_prev = np.zeros((self.cfg.num_slots,), bool)
        for i in dispatch:
            if i in inflight:
                use_prev[i] = True    # token still on device
            else:
                host[i, 0] = self.slots[i].last_token
        steps = self.sampler.steps.copy()
        steps[use_prev] += 1          # one draw ahead of the host mirror
        self._dispatch_decode(dispatch, host, use_prev, pend.toks, steps)
        return True

    def _dispatch_decode(self, active, host_tokens, use_prev, prev_toks,
                         steps):
        """Launch the fused feed-select + decode + on-device sample
        WITHOUT fetching the tokens; the result parks in
        ``self._pending``. Lengths advance at dispatch (the fed token's
        cache write is in flight), so the harvest only registers the
        sampled values."""
        if prev_toks is None:         # no double buffer yet: dead feed
            if self._zero_toks is None:
                self._zero_toks = jnp.zeros((self.cfg.num_slots,),
                                            jnp.int32)
            prev_toks = self._zero_toks
        steps, samp = self.sampler.fused_args(steps)
        args = (self.params, self.pools, self.table, self.lengths,
                host_tokens, prev_toks, use_prev, steps, samp)
        if self.arena is not None:
            args += (self.arena_ids, self.enc_lengths)
        t0 = time.monotonic()
        toks, self.pools = self._overlap_step(*args)
        self.steps += 1
        self.slot_steps += len(active)
        self.block_token_steps += self.alloc.used_count * self.cfg.block_size
        self.made_progress = True
        rows = []
        for i in active:
            self.lengths[i] += 1
            self.live_token_steps += int(self.lengths[i])
            rows.append((i, self.slots[i].ticket))
        self._pending = _Pending(rows, toks, t0)

    def _harvest(self, pend: _Pending) -> list[RequestOutput]:
        """Block on an in-flight decode's token fetch and register the
        draws. Rows whose slot retired or was re-admitted since the
        dispatch (ticket mismatch) are discarded — their speculative
        cache writes landed at never-read positions."""
        toks = np.asarray(pend.toks)        # the one blocking fetch
        self._mark_device(pend.t_dispatch)
        outs = []
        for i, ticket in pend.rows:
            slot = self.slots[i]
            if slot.req is None or slot.ticket != ticket:
                continue
            outs.append(self._accept(i, int(toks[i])))
        if outs:
            self.made_progress = True
        return outs

    def flush_overlap(self):
        """Harvest any in-flight decode NOW (no new dispatch) and buffer
        its outputs for the next ``step()``. Migration paths call this
        before reading host slot state (``lengths`` already counts the
        in-flight fed token, but ``slot.last_token`` is only current
        after the harvest) — and a flush may retire slots, so callers
        re-check occupancy afterwards."""
        if self._pending is None:
            return
        pend, self._pending = self._pending, None
        self._flushed.extend(self._harvest(pend))

    def _mark_device(self, t_dispatch: float):
        """Account one dispatch->fetch interval into the device-busy
        clock, unioned against the previous fetch so overlapping host
        work never double-counts device time."""
        t1 = time.monotonic()
        self.device_s += t1 - max(t_dispatch, self._t_fetch_done)
        self._t_fetch_done = t1

    def live_handles(self) -> list[RequestHandle]:
        """Resident + queued request handles (latency aggregation —
        see ``api.latency_stats``)."""
        return [s.req for s in self.slots if s.req is not None] \
            + list(self.waiting)

    # -- internals ------------------------------------------------------

    def _accept(self, i: int, tok: int) -> RequestOutput:
        """Register one sampled token for slot i; emit/stop/retire."""
        slot = self.slots[i]
        out = register_sample(slot.req, tok, self.cfg.eos_id,
                              lambda: self._retire(i))
        if not out.finished:
            self.sampler.steps[i] = slot.req._n_sampled
            slot.last_token = tok
        return out

    def _grow_blocks(self):
        """Allocate growth blocks oldest-admission-first; when the pool
        is dry, preempt LIFO until the allocation fits (a sequence may
        preempt itself if it is the newest — it then waits in queue)."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.req is not None),
            key=lambda i: self.slots[i].ticket)
        for i in order:
            slot = self.slots[i]
            if slot.req is None:          # preempted earlier in this pass
                continue
            L = int(self.lengths[i])
            if L % self.cfg.block_size != 0 or \
                    L // self.cfg.block_size < len(slot.blocks):
                continue
            while not self.alloc.can_alloc(1):
                cands = [(j, self.slots[j].ticket)
                         for j, s in enumerate(self.slots)
                         if s.req is not None]
                victim = self.alloc.select_victim(cands)
                self._preempt(victim)
                if victim == i:
                    break
            if slot.req is None:
                continue
            (nb,) = self.alloc.alloc(1)
            slot.blocks.append(nb)
            self.table[i, len(slot.blocks) - 1] = nb

    def _on_evict(self, b: int):
        """Allocator reclaimed an unreferenced cached block: unlink it
        from the prefix index so it can never be matched again."""
        self.prefix.evict_block(b)
        self.prefix_evictions += 1

    def _ensure_cow(self, active):
        """Copy-on-write pass before a decode/verify device call: any
        slot whose next write position lands inside its SHARED prefix
        gets that block copied into a private one first, so the write
        cannot corrupt other slots sharing the block (or the pristine
        indexed copy future admissions will match). Only the LAST
        shared block is ever a write target — writes happen at the
        length frontier, which a full-prefix hit places one token
        inside the shared tail (lengths = S - 1)."""
        if self.prefix is None:
            return
        bs = self.cfg.block_size
        for i in active:
            slot = self.slots[i]
            idx = int(self.lengths[i]) // bs
            if idx >= slot.shared:
                continue
            assert idx == slot.shared - 1, \
                "write frontier deeper than the shared tail block"
            assert self.alloc.must_cow(slot.blocks[idx])
            while not self.alloc.can_alloc(1):   # LIFO, like _grow_blocks
                cands = [(j, self.slots[j].ticket)
                         for j, s in enumerate(self.slots)
                         if s.req is not None]
                victim = self.alloc.select_victim(cands)
                self._preempt(victim)
                if victim == i:
                    break
            if slot.req is None:           # preempted itself: waits in
                continue                   # queue, re-admits later
            self._cow_block(i, idx)

    def _cow_block(self, i: int, idx: int):
        """Copy shared block ``slot.blocks[idx]`` into a freshly owned
        one and swap the table entry; the old block keeps its other
        references (and its place in the prefix index) untouched."""
        slot = self.slots[i]
        old = slot.blocks[idx]
        (new,) = self.alloc.alloc(1)
        self.pools = self._cow(self.pools, old, new)
        slot.blocks[idx] = new
        self.table[i, idx] = new
        self.alloc.free([old])             # drop only THIS slot's ref
        slot.shared = idx                  # blocks before idx still shared
        self.cow_copies += 1

    def _imminent_growth(self) -> int:
        """Growth blocks active sequences will claim THIS step. Counted
        into admission so a new request cannot grab the last free blocks
        only to be LIFO-preempted by an older sequence's growth in the
        same step — a full prefill wasted per step until something
        retires."""
        bs = self.cfg.block_size
        return sum(1 for i, s in enumerate(self.slots)
                   if s.req is not None
                   and int(self.lengths[i]) % bs == 0
                   and int(self.lengths[i]) // bs >= len(s.blocks))

    def _cached_tokens(self, req: RequestHandle) -> list[int]:
        """Tokens a (re-)admitted request must have in cache before its
        next decode: the prompt, plus all-but-the-last emitted token on
        a preemption resume (the last one is fed to decode)."""
        if req._n_sampled > 0:            # preempted: re-prefill history
            return list(req.prompt) + req.token_ids[:-1]
        return list(req.prompt)

    def _bucket_key(self, S: int):
        """The prefill-trace identity of a cached length: the padded
        token width for ragged models, the exact length otherwise.
        Requests batch together iff their keys match."""
        bs = self.cfg.block_size
        if self.ragged_prefill:
            cap = paged_kv.blocks_for(self.cfg.max_len, bs) * bs
            return paged_kv.blocks_for(prefill_bucket(S, bs, cap), bs) * bs
        return ("exact", S)

    def _suffix_bucket(self, n: int) -> int:
        """Power-of-two bucket for a non-shared admission suffix: same
        policy as prompt buckets (floor = block size, capped), so
        suffix-prefill traces stay O(log max_len) like everything else."""
        bs = self.cfg.block_size
        cap = paged_kv.blocks_for(self.cfg.max_len, bs) * bs
        return prefill_bucket(n, bs, cap)

    def _enc_bucket(self, F: int) -> int:
        """Power-of-two bucket for an encoder frame count — its OWN
        axis (floor 8, capped at encoder_len), so prefill traces stay
        O(log max_len x log encoder_len) and the compile-cap gate keeps
        both axes observable."""
        return prefill_bucket(F, 8, self.model.cfg.encoder_len)

    def _admit_key(self, S: int, matched: int, req=None):
        """The admission-trace identity: full-hit installs (no device
        call), suffix prefills batched by suffix bucket, full prefills
        by the standard prompt bucket — times the frame bucket for
        encoder-decoder requests. Requests batch together iff their
        keys match."""
        if matched == S:
            key = ("hit",)
        elif matched > 0:
            key = ("sfx", self._suffix_bucket(S - matched))
        else:
            key = self._bucket_key(S)
        if self.arena is not None:
            key = (key, "enc",
                   self._enc_bucket(req.encoder_features.shape[0]))
        return key

    def _drain_bucket_run(self):
        """Pop the maximal FCFS PREFIX of the queue that (a) fits the
        free slots and the pool (cumulative current footprint + this
        step's imminent growth, watermark headroom while anything else
        runs), (b) shares the queue head's admission key (prefill
        bucket / suffix bucket / full hit), and (c) stays within
        ``max_prefill_batch``. Strictly a prefix: a request that does
        not fit ends the run — no skipping ahead — so batching cannot
        starve the head of the queue.

        Each accepted request's longest block-aligned cached prefix is
        matched here and its blocks are SHARED immediately (refcount
        pinned), so a later entry's fresh allocation cannot reclaim
        them out of the LRU mid-run; a request that then fails the pool
        check is un-pinned before the run closes. Returns
        ``(req, matched_blocks, cached_tokens, S)`` entries."""
        free = sum(1 for s in self.slots if s.req is None)
        if not free:
            return []
        bs = self.cfg.block_size
        cap = free if self.cfg.max_prefill_batch <= 0 else \
            min(free, self.cfg.max_prefill_batch)
        run = []
        need = self._imminent_growth()
        key0 = None
        arena_need = 0
        seen_feats: set[int] = set()
        for req in self.waiting:
            if len(run) >= cap:
                break
            cached = self._cached_tokens(req)
            S = len(cached)
            m = self.prefix.match(cached) if self.prefix is not None \
                else []
            key = self._admit_key(S, len(m) * bs, req)
            if run and key != key0:
                break
            if self.arena is not None:
                # a fresh feature array claims an arena row; identity-
                # shared features (resident or earlier in this run) ride
                # an existing row's refcount
                fk = id(req.encoder_features)
                fresh = (fk not in seen_feats and self.arena.lookup(fk)
                         == paged_kv.NULL_ARENA)
                if fresh and not self.arena.can_admit(arena_need + 1):
                    break
                if fresh:
                    arena_need += 1
                    seen_feats.add(fk)
            for b in m:                   # pin against mid-run reclaim
                self.alloc.share(b)
            # + 1: the admitted slot decodes THIS step, caching the fed
            # token at position ``cached`` — without that block counted
            # a boundary-length request admits then self-preempts,
            # wasting a full prefill every step. Matched blocks are
            # already resident; for a fresh full hit the +1 covers the
            # copy-on-write block the first decode claims instead.
            want = paged_kv.blocks_for(S + 1, bs) - len(m)
            # watermark headroom only matters while others are running;
            # a sole request must always pass (progress guarantee)
            strict = self.num_active > 0 or bool(run)
            if not self.alloc.can_admit(need + want, strict=strict):
                if m:
                    self.alloc.free(m)    # un-pin: hits return to LRU
                break
            need += want
            run.append((req, m, cached, S))
            key0 = key
        for _ in run:
            self.waiting.popleft()
        return run

    def _admit(self, outs: list[RequestOutput]):
        while self.waiting:
            run = self._drain_bucket_run()
            if not run:
                return                    # FCFS: no skipping ahead
            self._place_batch(run, outs)

    def _place_batch(self, run, outs: list[RequestOutput]):
        """Admit one drained run (entries all share one admission key):
        install matched prefix blocks, allocate the rest, and compute
        ONLY the non-shared tokens — a full-prefix hit costs no device
        call at all, a partial hit prefills just the suffix through the
        verify path, and a miss takes the batched full prefill. Rows
        are FCFS-ordered, so emission order matches one-at-a-time
        admission exactly (a fresh full hit emits its first token from
        this step's decode instead of at admission; the token VALUE is
        bit-identical because it is drawn at the same RNG stream
        position from the same logits row)."""
        bs = self.cfg.block_size
        free_slots = [i for i, s in enumerate(self.slots) if s.req is None]
        rows = []                          # (slot, req, cached, S, ids)
        for req, m, cached, S in run:
            nbp = paged_kv.blocks_for(S, bs)
            # matched blocks were share()'d at drain time; only the
            # non-shared tail is allocated (may reclaim from the LRU,
            # which cannot touch the pinned matches)
            block_ids = list(m) + self.alloc.alloc(nbp - len(m))
            i = free_slots.pop(0)
            slot = self.slots[i]
            slot.req = req
            slot.blocks = block_ids
            slot.shared = len(m)
            slot.ticket = self._ticket
            self._ticket += 1
            self.table[i, :] = paged_kv.NULL_BLOCK
            self.table[i, :len(block_ids)] = block_ids
            if self.arena is not None:
                self._install_arena(i, req)
            rows.append((i, req, cached, S, block_ids))
            if self.prefix is not None:
                self.prefix_lookups += 1
                if m:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += len(m) * bs
        _, m0, _, S0 = run[0]
        if m0 and len(m0) * bs == S0:
            row_logits = self._install_hits(rows)
        elif m0:
            row_logits = self._suffix_batch(rows)
        elif self.arena is not None:
            row_logits = self._encdec_batch(rows)
        else:
            row_logits = self._full_batch(rows)
        self.made_progress = True          # tokens cached in all flavors
        # index each row's full PROMPT-chunk blocks before sampling: a
        # max_tokens=1 row retires inside _accept, and its freed chain
        # must already be registered to land in the LRU (first-wins —
        # chunks cached earlier, including by this very batch, keep
        # their original block)
        if self.prefix is not None:
            for i, req, cached, S, block_ids in rows:
                for b in self.prefix.insert(cached, block_ids):
                    self.alloc.register(b)
        for i, req, cached, S, block_ids in rows:
            self.sampler.install(i, req.sampling, req._n_sampled)
            if req._n_sampled > 0:         # resume: nothing new to sample
                self.slots[i].last_token = req.token_ids[-1]
            elif row_logits is not None:   # miss/suffix: sample token 0
                outs.append(self._accept(
                    i, self.sampler.sample_one(i, row_logits[i:i + 1])))
            # fresh full hit: no logits yet — this step's decode replays
            # the prompt's last token and samples at stream position 0
        self._post_admit(rows)

    def _install_hits(self, rows):
        """Full-prefix hit: every block is already resident — no device
        call. A RESUME row's cache is complete (lengths = S, feed the
        last emitted token); a FRESH row still owes the sample after
        its prompt, so its length rewinds one token (lengths = S - 1)
        and this step's decode replays ``cached[-1]`` — the rewrite
        lands inside the shared tail block, which ``_ensure_cow``
        privatizes first."""
        for i, req, cached, S, block_ids in rows:
            if req._n_sampled > 0:
                self.lengths[i] = S
            else:
                self.lengths[i] = S - 1
                self.slots[i].last_token = cached[-1]
        return None

    def _suffix_batch(self, rows):
        """Partial hit: prefill ONLY each row's non-shared suffix, in
        one verify-path call (fed token j caches at ``lengths + j``,
        which is exactly suffix prefill when lengths = matched tokens).
        Non-participating slots ride along masked: local table rows at
        the null block and local lengths 0, so their writes land in the
        reserved block and their logits rows are ignored. Returns
        slot-indexed next-token logits."""
        bs = self.cfg.block_size
        i0, _, _, S0, _ = rows[0]
        W = self._suffix_bucket(S0 - self.slots[i0].shared * bs)
        fn = self._suffix_prefill(W)
        N = self.cfg.num_slots
        toks = np.zeros((N, W), np.int32)
        slens = np.zeros((N,), np.int32)
        stable = np.full((N, self.layout.max_blocks_per_seq),
                         paged_kv.NULL_BLOCK, np.int32)
        last = np.zeros((N,), np.int32)
        for i, req, cached, S, block_ids in rows:
            mt = self.slots[i].shared * bs
            sfx = S - mt
            toks[i, :sfx] = cached[mt:]
            slens[i] = mt
            stable[i, :len(block_ids)] = block_ids
            last[i] = sfx - 1
            self.lengths[i] = S
            self.prefill_tokens += sfx
        row_logits, self.pools = fn(
            self.params, self.pools, jnp.asarray(stable),
            jnp.asarray(slens), jnp.asarray(toks), jnp.asarray(last))
        self.prefill_calls += 1
        self.prefill_reqs += len(rows)
        return np.asarray(row_logits)      # (num_slots, V)

    def _full_batch(self, rows):
        """Prefix miss: the PR-4 batched full prefill — one right-padded
        batch call, each row's true-length cache scattered into its
        slot. Returns slot-indexed next-token logits."""
        fn, tok_w, cache_w, Nb = self._prefill(rows[0][3], len(rows))
        nbc = cache_w // self.cfg.block_size
        toks = np.zeros((Nb, tok_w), np.int32)
        lens = np.ones((Nb,), np.int32)    # batch fillers: harmless len 1
        ids = np.full((Nb, nbc), paged_kv.NULL_BLOCK, np.int32)
        row_of_slot = np.zeros((self.cfg.num_slots,), np.int32)
        valid = np.zeros((self.cfg.num_slots,), bool)
        for r, (i, req, cached, S, block_ids) in enumerate(rows):
            toks[r, :S] = cached           # exact path: tok_w == S, no pad
            lens[r] = S
            ids[r, :len(block_ids)] = block_ids  # pad tail -> null block
            row_of_slot[i] = r
            valid[i] = True
            self.lengths[i] = S
            self.prefill_tokens += S
        args = (self.params, self.pools, jnp.asarray(toks),
                jnp.asarray(ids), jnp.asarray(row_of_slot),
                jnp.asarray(valid), jnp.asarray(lens))
        row_logits, self.pools = fn(*args)
        self.prefill_calls += 1
        self.prefill_reqs += len(rows)
        row_logits = np.asarray(row_logits)  # (Nb, V): per-row pos S-1
        out = np.zeros((self.cfg.num_slots,) + row_logits.shape[1:],
                       row_logits.dtype)
        for r, (i, *_rest) in enumerate(rows):
            out[i] = row_logits[r]
        return out

    def _install_arena(self, i: int, req: RequestHandle) -> int:
        """Bind slot ``i`` to a cross-arena row: share the resident row
        when the SAME feature array (by identity) is already encoded,
        else claim a fresh one. The row is written by this admission's
        prefill call (idempotently for shared rows — the encoder is
        deterministic, so rewrites are bit-identical) and freed with the
        slot in ``_clear_slot``."""
        feats = req.encoder_features
        a = self.arena.lookup(id(feats))
        if a != paged_kv.NULL_ARENA:
            self.arena.share(a)
            self.arena_hits += 1
        else:
            a = self.arena.alloc(key=id(feats))
        self.arena_ids[i] = a
        self.enc_lengths[i] = feats.shape[0]
        return a

    def _encdec_batch(self, rows):
        """Encoder-decoder admission: one right-padded batch call runs
        the masked encoder forward, scatters each row's cross-KV into
        its arena row and packs the ragged decoder prefill into the
        block pool. Traces are cached per (prompt-bucket, frame-bucket,
        batch-bucket) triple. Returns slot-indexed next-token logits."""
        bs = self.cfg.block_size
        _, req0, _, S0, ids0 = rows[0]
        tok_w = self._bucket_key(S0) if self.ragged_prefill else S0
        Fb = self._enc_bucket(req0.encoder_features.shape[0])
        Nb = min(1 << max(len(rows) - 1, 0).bit_length(),
                 self.cfg.num_slots)
        fn = self._encdec_prefill(tok_w, Fb, Nb)
        nbc = paged_kv.blocks_for(tok_w, bs)
        d = self.model.cfg.d_model
        toks = np.zeros((Nb, tok_w), np.int32)
        lens = np.ones((Nb,), np.int32)    # batch fillers: harmless len 1
        frames = np.zeros((Nb, Fb, d), np.float32)
        enc_lens = np.zeros((Nb,), np.int32)   # fillers: fully masked
        ids = np.full((Nb, nbc), paged_kv.NULL_BLOCK, np.int32)
        aids = np.zeros((Nb,), np.int32)       # fillers: null arena row
        for r, (i, req, cached, S, block_ids) in enumerate(rows):
            toks[r, :S] = cached
            lens[r] = S
            F = req.encoder_features.shape[0]
            frames[r, :F] = np.asarray(req.encoder_features,
                                       np.float32)
            enc_lens[r] = F
            ids[r, :len(block_ids)] = block_ids
            aids[r] = self.arena_ids[i]
            self.lengths[i] = S
            self.prefill_tokens += S
        row_logits, self.pools = fn(
            self.params, self.pools, jnp.asarray(toks),
            jnp.asarray(frames), jnp.asarray(enc_lens),
            jnp.asarray(lens), jnp.asarray(ids), jnp.asarray(aids))
        self.prefill_calls += 1
        self.prefill_reqs += len(rows)
        row_logits = np.asarray(row_logits)    # (Nb, V)
        out = np.zeros((self.cfg.num_slots,) + row_logits.shape[1:],
                       row_logits.dtype)
        for r, (i, *_rest) in enumerate(rows):
            out[i] = row_logits[r]
        return out

    def _encdec_prefill(self, tok_w: int, Fb: int, Nb: int):
        """Encoder-decoder prefill+pack, jit-cached per (prompt-bucket,
        frame-bucket, batch-bucket) — shares ``_prefill_cache`` so the
        compile-cap telemetry covers both axes."""
        key = ("encdec", tok_w, Fb, Nb)
        fn = self._prefill_cache.get(key)
        if fn is None:
            model, ctx = self.model, self.prefill_ctx

            def prefill_fn(params, pools, tokens, frames, enc_lens,
                           lengths, block_ids, arena_ids):
                return model.prefill_paged_encdec(
                    params, pools, tokens, frames, enc_lens, lengths,
                    block_ids, arena_ids, ctx)

            fn = shlib.jit_step(prefill_fn, self.shard, self._pool_sh,
                                donate=(1,))
            self._prefill_cache[key] = fn
        return fn

    def _prefill(self, S: int, n: int):
        """Prefill+pack, jit-cached per (prompt-bucket, batch-bucket):
        prompts pad to the power-of-two BUCKET (ragged models) or stay
        at the exact length (fallback — tokens keep width S, so
        recurrent chunk scans never see a pad token); batch widths pad
        to the next power of two (capped at num_slots). Returns
        (fn, token_width, cache_width, batch_width); cache_width is
        always a block multiple (pow-2 buckets are rounded up for
        non-pow-2 blocks)."""
        bs = self.cfg.block_size
        if self.ragged_prefill:
            Sb = self._bucket_key(S)
            tok_w = Sb
        else:
            Sb = paged_kv.blocks_for(S, bs) * bs
            tok_w = S
        Nb = min(1 << max(n - 1, 0).bit_length(), self.cfg.num_slots)
        key = (Sb, Nb) if self.ragged_prefill else ("exact", S, Nb)
        fn = self._prefill_cache.get(key)
        if fn is None:
            model, layout = self.model, self.layout
            ctx = self.prefill_ctx
            ragged = self.ragged_prefill
            kv_spec = self.kv_spec

            def prefill_fn(params, pools, tokens, block_ids, row_of_slot,
                           valid, length):
                logits, dense = model.prefill(
                    params, {"tokens": tokens}, ctx, max_len=Sb,
                    length=length if ragged else None)
                pools = model.pack_prefill_into_paged(
                    layout, pools, dense, row_of_slot, valid, block_ids,
                    spec=kv_spec)
                # only each row's next-token logits leave the device:
                # (Nb, V) instead of the full (Nb, tok_w, V) slab
                rows = jnp.take_along_axis(
                    logits, (length - 1)[:, None, None], axis=1)[:, 0]
                return rows, pools

            fn = shlib.jit_step(prefill_fn, self.shard, self._pool_sh,
                                donate=(1,))
            self._prefill_cache[key] = fn
        return fn, tok_w, Sb, Nb

    def _suffix_prefill(self, W: int):
        """Suffix-only prefill, jit-cached per suffix bucket ``W``
        (separate cache from full prefill so the O(log max_len) compile
        caps on each stay independently observable). Reuses the verify
        pass: fed token j caches at ``lengths + j`` reading the shared
        prefix through the block table, and ``commit_fn`` exports only
        each row's next-token logits row. Pad positions past a row's
        real blocks route to the null block (table rows are NULL beyond
        the chain; logical indices past the table width null-route in
        the kernel)."""
        fn = self._suffix_cache.get(W)
        if fn is None:
            model, ctx = self.model, self.ctx

            def suffix_fn(params, pools, table, lengths, tokens, last):
                def commit_fn(logits):    # (B, W, V) -> per-row last real
                    rows = jnp.take_along_axis(
                        logits, last[:, None, None], axis=1)[:, 0]
                    return rows, jnp.full(lengths.shape, W, jnp.int32)

                rows, _, pools = model.decode_verify(
                    params, pools, table, lengths, tokens, commit_fn, ctx)
                return rows, pools

            fn = shlib.jit_step(suffix_fn, self.shard, self._pool_sh,
                                donate=(1,))
            self._suffix_cache[W] = fn
        return fn

    def _preempt(self, i: int):
        """Evict slot i to a host-side recompute record (LIFO victim).
        NOT progress: a step that only evicts and re-queues emits no
        token and caches none, so reporting progress here would let
        Engine.drive spin through preempt/re-prefill churn forever —
        only admissions and decodes flip ``made_progress``."""
        slot = self.slots[i]
        req = slot.req
        req.num_preemptions += 1
        self.preemptions += 1
        self.alloc.free(slot.blocks)
        self._clear_slot(i)
        self.waiting.appendleft(req)      # preempted work goes first

    def _retire(self, i: int):
        """Backend cleanup after register_sample flagged the handle."""
        slot = self.slots[i]
        self.finished.append(slot.req)
        self.alloc.free(slot.blocks)
        self._clear_slot(i)

    def _clear_slot(self, i: int):
        slot = self.slots[i]
        slot.req = None
        slot.blocks = []
        slot.last_token = 0
        slot.ticket = -1
        slot.shared = 0
        self.table[i, :] = paged_kv.NULL_BLOCK
        self.lengths[i] = 0
        if self.arena is not None and self.arena_ids[i]:
            # retirement, preemption and migration detach all land here:
            # the arena row's refcount drops with the slot (resume
            # re-encodes), so rows can never outlive their requests
            self.arena.free(int(self.arena_ids[i]))
            self.arena_ids[i] = paged_kv.NULL_ARENA
            self.enc_lengths[i] = 0
        self.sampler.clear(i)
        self._post_clear(i)

    # -- migration (prefill/decode disaggregation) ----------------------

    def export_slot(self, i: int):
        """Host-side migration snapshot of occupied slot ``i``: the
        handle, its physical block chain, the cached length and the
        next token to feed. Device content is gathered separately by
        launch/engine/transport.py — JAX arrays are functional, so the
        gather may happen before or after ``detach_slot`` frees the
        chain without ever observing the reuse.

        An overlapped in-flight decode is harvested first: ``lengths``
        already counts the fed token (its pool write is ordered before
        any gather by the functional threading), but ``last_token`` is
        only current once the sampled value lands — exporting around an
        un-harvested token would migrate a stale feed. Callers must
        gate on occupancy AFTER any flush (the harvest can retire
        slots)."""
        self.flush_overlap()
        slot = self.slots[i]
        assert slot.req is not None, "exporting an empty slot"
        return slot.req, list(slot.blocks), int(self.lengths[i]), \
            slot.last_token

    def detach_slot(self, i: int):
        """Drop slot ``i`` WITHOUT retiring or re-queueing: its request
        now lives in a MigrationPacket. The block chain is freed here
        (shared references just decrement) because the packet carries
        gathered *content*, not block ids into this pool — a packet
        dropped mid-migration therefore leaks nothing on either side."""
        self.flush_overlap()           # no-op after export_slot's flush
        slot = self.slots[i]
        self.alloc.free(slot.blocks)
        self._clear_slot(i)

    def import_slot(self, req: RequestHandle, block_ids: list[int],
                    length: int, last_token: int) -> int:
        """Install a migrated request into a free slot over freshly
        alloc()'d ``block_ids`` (the transport scatters the packet's
        content into them; this installs the host-side view). The path
        is position-agnostic: ``length`` may sit anywhere from the
        full-hit rewind (S - 1, nothing sampled yet) to deep mid-decode
        re-export — ``cached`` reconstructs the block contents from the
        handle exactly like ``_cached_tokens`` does on a preemption
        resume. Full prompt+output chunks are registered in the prefix
        index so later admissions on THIS replica can share them."""
        free = [i for i, s in enumerate(self.slots) if s.req is None]
        assert free, "import into a full backend (caller gates on this)"
        i = free[0]
        slot = self.slots[i]
        slot.req = req
        slot.blocks = list(block_ids)
        slot.shared = 0                  # fresh private copies, COW-free
        slot.last_token = last_token
        slot.ticket = self._ticket
        self._ticket += 1
        self.table[i, :] = paged_kv.NULL_BLOCK
        self.table[i, :len(block_ids)] = block_ids
        if self.arena is not None:
            # the transport scatters the packet's cross row into this
            # arena row right after installing the host view
            self._install_arena(i, req)
        self.lengths[i] = length
        self.sampler.install(i, req.sampling, req._n_sampled)
        cached = (list(req.prompt) + req.token_ids)[:length]
        if self.prefix is not None:
            for b in self.prefix.insert(cached, slot.blocks):
                self.alloc.register(b)
        self._post_admit([(i, req, cached, length, list(block_ids))])
        self.made_progress = True
        return i

    def _post_admit(self, rows):
        """Subclass hook: ``(slot, req, cached, S, block_ids)`` rows just
        admitted (the speculative backend installs drafter state here)."""

    def _post_clear(self, i: int):
        """Subclass hook: slot ``i`` was just retired or preempted."""

    # -- reporting ------------------------------------------------------

    def reset_telemetry(self):
        """Zero the counters behind ``stats()`` (e.g. after bench
        warmup); does not touch scheduling state or jit caches."""
        self.finished.clear()
        self.steps = self.slot_steps = 0
        self.block_token_steps = self.live_token_steps = 0
        self.device_s = 0.0
        self.preemptions = 0
        self.prefill_calls = self.prefill_reqs = self.prefill_tokens = 0
        self.prefix_lookups = self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = self.prefix_evictions = 0
        self.arena_hits = 0

    def stats(self) -> dict:
        """Cache/occupancy/scheduling telemetry for the run so far."""
        cap = self.block_token_steps or 1
        return {
            "steps": self.steps,
            "mean_active_slots": self.slot_steps / max(self.steps, 1),
            "cache_utilization": self.live_token_steps / cap,
            "overlap": bool(self.cfg.overlap),
            "device_s": self.device_s,
            "blocks_free": self.alloc.free_count,
            "blocks_used": self.alloc.used_count,
            "preemptions": self.preemptions,
            "prefill_compiles": len(self._prefill_cache),
            "prefill_calls": self.prefill_calls,
            "prefill_reqs": self.prefill_reqs,
            "prefill_tokens": self.prefill_tokens,
            "bucketed_prefill": self.ragged_prefill,
            "prefix_cache": {
                "enabled": self.prefix is not None,
                "lookups": self.prefix_lookups,
                "hits": self.prefix_hits,
                "hit_rate": self.prefix_hits / max(self.prefix_lookups, 1),
                "hit_tokens": self.prefix_hit_tokens,
                "cow_copies": self.cow_copies,
                "evictions": self.prefix_evictions,
                "lru_blocks": self.alloc.lru_count,
                "suffix_compiles": len(self._suffix_cache),
            },
            "cross_arena": {
                "enabled": self.arena is not None,
                "rows_used": self.arena.used_count if self.arena else 0,
                "rows_free": self.arena.free_count if self.arena else 0,
                "shared_hits": self.arena_hits,
            },
        }
