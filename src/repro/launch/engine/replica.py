"""ReplicaSet: data-parallel engine replicas behind ONE admission queue.

EPAC scales throughput by replicating compute tiles behind one coherent
hub — VEC/STX/VRP share a CHI NoC and the uncore arbitrates work across
them. This is the serving analogue: R full ``Engine`` replicas over the
``data`` axis of the mesh (each gets its OWN KV block pool and its
model-axis TP subgrid via ``mesh.submeshes``), fed from one shared
admission queue. Requests are dispatched strictly FCFS — always the
queue head, never skip-ahead — through a pluggable placement policy:

  * ``least_loaded`` (default) — the replica with the fewest committed
    cache blocks (used + queued footprint), ties to the lowest replica
    index; the tensor-level version of the uncore routing a transaction
    to the least-occupied L2 slice.
  * ``round_robin`` — rotate over accepting replicas.

Fairness invariant: because dispatch only ever pops the HEAD of the
shared queue, and every replica's own queue is FCFS with a guaranteed-
progress oldest admission, no request waits unboundedly — the head is
dispatched as soon as ANY replica frees capacity, and within a replica
it inherits the engine's no-livelock guarantee. Preemption stays local
to a replica: an evicted request re-enters its OWN replica's queue
(front), never the shared queue, so its blocks/RNG bookkeeping never
crosses replicas.

On real accelerators each replica's submesh executes in parallel and
wall-clock throughput scales with R; on a CPU host simulating devices
the replicas time-share the cores, so the set also meters each
replica's BUSY time (cumulative wall spent inside its step calls) and
per-replica token counts — ``stats()['busy_s']`` — from which the
bench reports aggregate *capacity* (sum of per-replica-clock rates),
the number parallel hardware would sustain. Busy clocks are stamped
with ``time.monotonic()`` at the step dispatch/return boundaries
(never ``time.time()``, which can jump under NTP slew and is not an
interval clock); the finer-grained device-occupancy clock lives in the
paged backend itself (``stats()['device_s']``, a non-overlapping
interval union across dispatch→fetch windows) so that with
``overlap=True`` the in-flight device call is not double-counted
across consecutive steps. ``step_workers > 1`` opts into
thread-parallel stepping. An honest accounting of when that helps:
the step loop is host-Python-heavy (dispatch bookkeeping, numpy
mirrors, jit-call argument marshalling all hold the GIL) and only the
blocking device fetch releases it, so threads pay GIL ping-pong on
every step and win only when per-step device compute is large enough
to dominate — big models on real accelerators, not smoke shapes. With
``overlap=True`` the blocking fetch shrinks further (the device call
of step N+1 is dispatched before step N's tokens are fetched), so the
GIL-released window threads could exploit mostly disappears;
overlap-within-a-replica and threads-across-replicas are largely
substitutes on a CPU host, and overlap is the cheaper of the two. It
therefore stays off by default.

Token streams are bit-identical to a single engine serving the same
requests: outputs are a pure function of (params, prompt,
SamplingParams) by the engine's RNG-stream contract, independent of
which replica, slot, or co-batch a request lands in.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np

from repro.launch.engine import api
from repro.launch.engine.api import (Engine, EngineConfig, RequestHandle,
                                     RequestOutput, SamplingParams)
from repro.models import paged_kv
from repro.models.model import Model


def least_loaded(rset: "ReplicaSet", candidates: list[int]) -> int:
    """Fewest committed blocks (paged) / occupied lanes (static); ties
    break to the LOWEST replica index so placement is deterministic."""
    return min(candidates, key=lambda r: (rset.load(r), r))


def round_robin(rset: "ReplicaSet", candidates: list[int]) -> int:
    """Rotate over accepting replicas (fallback policy)."""
    pick = min(candidates,
               key=lambda r: (r - rset._rr) % len(rset.replicas))
    rset._rr = pick + 1
    return pick


_POLICIES = {"least_loaded": least_loaded, "round_robin": round_robin}


class ReplicaSet:
    """Engine-shaped front-end over R data-parallel engine replicas.

    Parameters
    ----------
    model, params
        The target model and its parameter tree (shared by replicas).
    cfg : EngineConfig, optional
        The PER-REPLICA configuration (slots, pool, spec_tokens, ...);
        must not carry a mesh — pass it as ``mesh=`` instead.
    dp : int, optional
        Replica count; inferred from ``mesh.shape["data"]`` when a mesh
        is given.
    mesh : jax.sharding.Mesh, optional
        A (data, model) mesh; each replica runs on its own
        ``(1, tp)`` submesh of the data axis.
    policy : str or callable
        FCFS dispatch placement: ``"least_loaded"`` (default,
        fewest committed blocks, ties to the lowest index),
        ``"round_robin"``, or a callable ``(rset, candidates) -> int``.
    overrides : sequence of dict or None, optional
        Per-replica ``EngineConfig`` field replacements (one entry per
        replica; None entries keep ``cfg``) — e.g. ``spec_tokens`` per
        role so prefill replicas skip speculative decoding. May not
        carry ``mesh`` (pass ``mesh=``) or ``eos_id`` (stop semantics
        must match for outputs to stay request-pure). With overrides
        present, requests validate against EVERY replica, since any of
        them may end up serving the request.
    ctx : RunCtx, optional
        Kernel/sharding context forwarded to every replica.
    step_workers : int, optional
        Opt-in thread pool width for stepping busy replicas
        concurrently. Only the blocking device fetch releases the GIL,
        so this pays off only when per-step device compute dominates
        the host-side bookkeeping; with ``EngineConfig(overlap=True)``
        the fetch window shrinks further and threads gain almost
        nothing (see the module docstring). Off by default.

    Attributes
    ----------
    replicas : list of Engine
        The R identical engines (own KV pool, own submesh).
    queue : deque of RequestHandle
        The ONE shared admission queue; dispatch only ever pops its
        head (strict FCFS — no skip-ahead).
    finished : list of RequestHandle
        Handles retired so far, across replicas, in completion order.

    Notes
    -----
    Token streams are bit-identical to a single engine serving the same
    requests: outputs are a pure function of (params, prompt,
    SamplingParams) by the engine's RNG-stream contract, independent of
    which replica, slot, or co-batch a request lands in. Preemption
    stays replica-local — an evicted request re-enters its OWN
    replica's queue, never the shared queue. No request waits
    unboundedly: the head is dispatched as soon as ANY replica frees
    capacity, and within a replica it inherits the engine's
    no-livelock guarantee.
    """

    def __init__(self, model: Model, params, cfg: EngineConfig = None,
                 *, dp: Optional[int] = None, mesh=None,
                 policy="least_loaded", ctx=None, step_workers=None,
                 overrides: Optional[Sequence[Optional[dict]]] = None):
        cfg = cfg or EngineConfig()
        if mesh is not None:
            from repro.launch.mesh import submeshes

            dp = int(mesh.shape["data"]) if dp is None else dp
            meshes = submeshes(mesh, dp, axis="data")
        else:
            # replica meshes come from EITHER the mesh argument or
            # cfg.mesh — per-replica submeshing of cfg.mesh would be
            # ambiguous with a set-level mesh, so reject the combination
            if cfg.mesh is not None:
                raise ValueError("pass the mesh to ReplicaSet(mesh=...), "
                                 "not through EngineConfig")
            meshes = [None] * (dp or 1)
        if not meshes:
            raise ValueError("dp must be >= 1")
        self.dp = len(meshes)
        if overrides is not None and len(overrides) != self.dp:
            raise ValueError(f"{len(overrides)} overrides for "
                             f"{self.dp} replicas")
        cfgs = [cfg] * self.dp
        if overrides is not None:
            bad = {"mesh", "eos_id"} & set().union(
                *(ov.keys() for ov in overrides if ov))
            if bad:
                raise ValueError(f"per-replica overrides cannot change "
                                 f"{sorted(bad)}")
            cfgs = [dataclasses.replace(cfg, **(ov or {}))
                    for ov in overrides]
        self.replicas = [
            Engine(model, params, dataclasses.replace(c, mesh=m),
                   ctx=ctx) for c, m in zip(cfgs, meshes)]
        self.cfg = cfg                   # baseline per-replica config
        # replicas usually vouch for each other; with overrides any of
        # them may serve a request, so each must accept it individually
        self._validators = self.replicas if overrides is not None \
            else self.replicas[:1]
        self.policy = _POLICIES.get(policy, policy)
        if not callable(self.policy):
            raise ValueError(f"unknown dispatch policy {policy!r}")
        self.queue: collections.deque[RequestHandle] = collections.deque()
        self.finished: list[RequestHandle] = []
        self.made_progress = False
        self._uid = 0
        self._rr = 0                     # round-robin cursor
        # in-flight handles only: entries are pruned at retirement so a
        # long-running set does not accumulate every request ever served
        self._by_uid: dict[int, RequestHandle] = {}
        self._enq: dict[int, tuple[int, float]] = {}  # uid -> (step, t)
        workers = 1 if step_workers is None else \
            min(step_workers, os.cpu_count() or 1)
        self._pool = ThreadPoolExecutor(workers) if workers > 1 else None
        # telemetry
        self.steps = 0
        self.dispatched = [0] * self.dp
        self.busy_s = [0.0] * self.dp     # wall inside each replica's step
        self.tokens_out = [0] * self.dp   # tokens emitted per replica
        self.wait_steps: list[int] = []   # shared-queue wait per request
        self.wait_wall: list[float] = []

    @property
    def total_slots(self) -> int:
        """Decode slots across the whole set (per-replica cfgs summed)."""
        return sum(e.cfg.num_slots for e in self.replicas)

    # -- request lifecycle ----------------------------------------------

    def add_request(self, prompt,
                    sampling: Optional[SamplingParams] = None,
                    encoder_features=None) -> RequestHandle:
        """Validate against a representative replica and append to the
        shared FCFS queue; returns the live handle. ``prompt`` is a
        token-id sequence or an ``api.Request``."""
        if isinstance(prompt, api.Request):
            if sampling is not None or encoder_features is not None:
                raise ValueError("pass sampling/encoder_features inside "
                                 "the Request, not alongside it")
            sampling = prompt.sampling
            encoder_features = prompt.encoder_features
            prompt = prompt.prompt
        sampling = sampling or SamplingParams()
        prompt = list(prompt)
        # identical replicas: replica 0 vouches for all of them;
        # per-replica overrides: every replica must accept
        for eng in self._validators:
            eng.check_request(prompt, sampling, encoder_features)
        handle = RequestHandle(self._uid, prompt, sampling,
                               encoder_features=encoder_features)
        self._uid += 1
        self._by_uid[handle.uid] = handle
        self._enq[handle.uid] = (self.steps, time.monotonic())
        self.queue.append(handle)
        return handle

    def step(self) -> list[RequestOutput]:
        """Dispatch from the shared queue, then step every busy replica
        (concurrently when a thread pool is available) and merge their
        streams in replica order."""
        self.steps += 1
        moved = self._dispatch()
        busy = [(r, eng) for r, eng in enumerate(self.replicas)
                if eng.has_work]
        outs = self._timed_steps(busy)
        self.made_progress = moved > 0 or any(
            eng.backend.made_progress for _, eng in busy)
        self._finish(outs)
        return outs

    def _timed_steps(self, busy) -> list[RequestOutput]:
        """Step the given ``(index, engine)`` pairs — through the thread
        pool when one is configured — metering per-replica busy clocks
        and token counts; streams merge in replica order."""
        def timed_step(pair):
            r, eng = pair
            # monotonic: wall-clock (time.time) can jump under NTP
            # slew, making a busy interval negative or double-length
            t0 = time.monotonic()
            part = eng.step()
            self.busy_s[r] += time.monotonic() - t0
            self.tokens_out[r] += sum(len(o.new_tokens) for o in part)
            return part

        if self._pool is not None and len(busy) > 1:
            outs_per = list(self._pool.map(timed_step, busy))
        else:
            outs_per = [timed_step(p) for p in busy]
        outs: list[RequestOutput] = []
        for part in outs_per:
            outs.extend(part)
        return outs

    def _finish(self, outs: list[RequestOutput]):
        """Move retired handles from the in-flight map to ``finished``."""
        for out in outs:
            if out.finished:
                self.finished.append(self._by_uid.pop(out.request_id))

    @property
    def has_work(self) -> bool:
        """True while anything is queued or active on any replica."""
        return bool(self.queue) or any(e.has_work for e in self.replicas)

    def stats(self) -> dict:
        """Set-level telemetry: per-replica stats, dispatch counts,
        busy clocks, queue-wait distribution, and the aggregate
        occupancy/leak views the bench and CI read."""
        per = [e.stats() for e in self.replicas]
        paged = [e.backend for e in self.replicas
                 if hasattr(e.backend, "alloc")]
        live = sum(b.live_token_steps for b in paged)
        cap = sum(b.block_token_steps for b in paged)
        return {
            "dp": self.dp,
            "steps": self.steps,
            "per_replica": per,
            "dispatched": list(self.dispatched),
            "busy_s": list(self.busy_s),
            "tokens_out": list(self.tokens_out),
            "queue_depth": len(self.queue),
            "queue_wait_steps_mean": (sum(self.wait_steps)
                                      / max(len(self.wait_steps), 1)),
            "queue_wait_steps_max": max(self.wait_steps, default=0),
            "queue_wait_s_mean": (sum(self.wait_wall)
                                  / max(len(self.wait_wall), 1)),
            "ttft": self._ttft_stats(),
            "latency": api.latency_stats(
                list(self.finished) + list(self._by_uid.values())),
            "device_s": [p.get("device_s", 0.0) for p in per],
            # aggregate views the bench / leak checks read
            "mean_active_slots": sum(p["mean_active_slots"] for p in per),
            "cache_utilization": live / max(cap, 1),
            "blocks_used": sum(p.get("blocks_used", 0) for p in per),
            "preemptions": sum(p.get("preemptions", 0) for p in per),
            "prefill_compiles": sum(p["prefill_compiles"] for p in per),
            "prefill_calls": sum(p.get("prefill_calls", 0) for p in per),
            "prefill_reqs": sum(p.get("prefill_reqs", 0) for p in per),
        }

    def _ttft_stats(self) -> dict:
        """Time-to-first-token distribution (seconds) over every request
        that has sampled its first token so far — retired handles plus
        the in-flight map; the metric disaggregation is meant to win."""
        lat = [h.t_first_token - h.t_submit
               for h in list(self.finished) + list(self._by_uid.values())
               if h.t_first_token is not None]
        if not lat:
            return {"count": 0, "mean_s": 0.0, "p50_s": 0.0,
                    "p95_s": 0.0, "p99_s": 0.0}
        arr = np.asarray(lat)
        return {"count": len(lat),
                "mean_s": float(arr.mean()),
                "p50_s": float(np.percentile(arr, 50)),
                "p95_s": float(np.percentile(arr, 95)),
                "p99_s": float(np.percentile(arr, 99))}

    def reset_telemetry(self):
        """Zero every replica's counters and the set-level telemetry
        (bench warmup boundary); scheduling state is untouched."""
        for eng in self.replicas:
            eng.backend.reset_telemetry()
        self.finished.clear()
        self.steps = 0
        self.dispatched = [0] * self.dp
        self.busy_s = [0.0] * self.dp
        self.tokens_out = [0] * self.dp
        self.wait_steps.clear()
        self.wait_wall.clear()

    # -- dispatch -------------------------------------------------------

    def load(self, r: int) -> int:
        """Committed-capacity estimate: cache blocks held + the block
        footprint already queued at the replica (paged), or occupied +
        queued lanes (static)."""
        be = self.replicas[r].backend
        if hasattr(be, "alloc"):
            # emitted tokens count too: a preempted request waiting to
            # resume re-prefills its whole history, not just the prompt
            queued = sum(paged_kv.blocks_for(
                len(h.prompt) + len(h.token_ids) + 1,
                self.replicas[r].cfg.block_size) for h in be.waiting)
            return be.alloc.used_count + queued
        return be.num_active + len(be.waiting)

    def can_accept(self, r: int) -> bool:
        """A replica accepts while it has decode lanes not yet spoken
        for; beyond that, requests are better off in the shared queue
        where the policy can still steer them."""
        be = self.replicas[r].backend
        return self.replicas[r].cfg.num_slots \
            - be.num_active - len(be.waiting) > 0

    def _dispatch_candidates(self) -> list[int]:
        """Replica indices dispatch may target (subclass hook: the
        disaggregated engine restricts fresh admissions to prefill
        replicas, with a packet-backpressure gate)."""
        return list(range(self.dp))

    def _dispatch(self) -> int:
        moved = 0
        while self.queue:
            cands = [r for r in self._dispatch_candidates()
                     if self.can_accept(r)]
            if not cands:
                break                     # head waits; never skip ahead
            handle = self.queue.popleft()
            r = self.policy(self, cands)
            self.replicas[r].backend.enqueue(handle)
            self.dispatched[r] += 1
            step0, t0 = self._enq.pop(handle.uid)
            self.wait_steps.append(self.steps - 1 - step0)
            self.wait_wall.append(time.monotonic() - t0)
            moved += 1
        return moved

    # -- convenience drivers (Engine-shaped) ----------------------------

    def drain(self, max_steps: int = 100_000) -> list[RequestOutput]:
        """Step until idle; returns the concatenated output stream."""
        return api.drive(
            self, max_steps,
            "replica set stalled: waiting requests cannot be admitted "
            "on any replica")

    def generate(self, prompts: Sequence[Sequence[int]],
                 sampling=None, max_steps: int = 100_000,
                 encoder_features=None) -> list[list[int]]:
        """Submit ``prompts`` and drive to completion; returns token ids
        per prompt in submission order (token-identical to a single
        Engine serving the same prompts)."""
        return api.run_generate(self, prompts, sampling, max_steps,
                                encoder_features=encoder_features)
