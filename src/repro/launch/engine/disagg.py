"""DisaggregatedEngine: prefill/decode role-specialized replicas.

EPAC's defining move is heterogeneous specialization behind a coherent
fabric: VEC, STX and VRP tiles split the workload by *kind* and share
one CHI NoC, distributed L2 and a C2C SerDes off-chip. Serving has the
same split hiding inside every request: prefill is compute-bound batch
work, decode is latency-bound incremental work, and a symmetric replica
set makes every replica do both — a long prompt's prefill stalls the
decode steps of everything co-resident, which is exactly what TTFT p95
measures. This module dedicates replicas to one role each and hands
finished prefill caches across as paged-block transfers
(launch/engine/transport.py), priced per packet by the uncore model's
point-to-point primitive (``core.noc.p2p_time``).

Role lifecycle of one request::

    shared queue --dispatch--> prefill replica: admit + prefill + token 0
                 --export--> MigrationPacket (blocks + RNG position)
                 --migrate--> gather / device_put / scatter
                 --import--> decode replica: decode to retirement

Straggler handling is the same code path run backwards: an idle decode
replica pulls the oldest exported-but-unclaimed packet, and when none
are in flight it *steals* — the busiest decode replica re-exports its
newest-ticket slot mid-decode (migration is position-agnostic) so the
idle replica shares the tail. The donor keeps its oldest admission, so
the engine-level no-livelock guarantee survives stealing.

Invariants (pinned by tests/test_disagg_serve.py):

* **Bit-identical outputs** to a single ``Engine`` and a symmetric
  ``ReplicaSet``: the sampler seed and stream position travel in the
  packet, so by the RNG-stream contract tokens are a pure function of
  (params, prompt, SamplingParams) — independent of roles, migration,
  stealing and preemption.
* **Strict FCFS**: fresh dispatch only ever pops the shared-queue head,
  packets only ever land from the head of the packet deque — no
  request is overtaken at either hop.
* **Zero leaks across BOTH pools**: export frees source blocks eagerly
  (the packet carries gathered content, not block ids), so a packet
  dropped mid-migration holds nothing; import allocs destination
  blocks under the same admission accounting as the scheduler.
"""

from __future__ import annotations

import collections
from typing import Optional, Sequence

from repro.core import noc
from repro.launch.engine import transport
from repro.launch.engine.api import EngineConfig
from repro.launch.engine.replica import ReplicaSet
from repro.models import paged_kv
from repro.models.model import Model

ROLES = ("prefill", "decode")


class DisaggregatedEngine(ReplicaSet):
    """Engine-shaped front-end over role-specialized engine replicas.

    Same surface as ``ReplicaSet`` (``add_request`` / ``step`` /
    ``generate`` / ``stats``), but each replica is pinned to one role:
    prefill replicas run admission + prefill only (their backends are
    ``prefill_only`` and never decode, grow, preempt or COW) and export
    every first-token slot as a ``MigrationPacket``; decode replicas
    import migrated slots ahead of fresh work and run them to
    retirement.

    Parameters
    ----------
    model, params
        The target model and its parameter tree (shared by replicas).
    cfg : EngineConfig, optional
        The baseline PER-REPLICA configuration. Must select the paged
        backend; must not carry a mesh (pass ``mesh=``).
    roles : tuple of str or "auto"
        One role per replica over ``mesh.submeshes`` order, e.g.
        ``("prefill", "prefill", "decode", "decode")``; at least one of
        each. ``"auto"`` splits ``dp`` replicas by
        ``prefill_fraction``.
    prefill_fraction : float, optional
        ``roles="auto"`` split: ``round(dp * prefill_fraction)`` prefill
        replicas, clamped to [1, dp - 1]. Default 0.5.
    role_overrides : dict, optional
        ``EngineConfig`` field replacements per role name, e.g.
        ``{"decode": {"spec_tokens": 4}}`` so decode replicas keep
        speculative decoding (prefill replicas are always forced to
        ``spec_tokens=0`` — they never decode, so drafts are waste).
        Migration geometry (``block_size``, ``max_len``) and
        ``backend`` may not differ per role.
    max_inflight : int, optional
        Packet backpressure: fresh dispatch to prefill replicas pauses
        while this many packets are exported-but-unclaimed (default:
        2x the decode-side slot count). Keeps "prefill replicas never
        decode" true with bounded staging memory.
    fabric : core.noc.FabricSpec, optional
        Fabric model pricing each packet via ``noc.p2p_time`` (bytes,
        data-axis hop distance). Default ``noc.V5E_FABRIC``.
    dp, mesh, policy, ctx, step_workers
        As for ``ReplicaSet``; the placement policy picks among role
        candidates only (prefill for dispatch, decode for imports).

    Attributes
    ----------
    roles : tuple of str
        The resolved per-replica role assignment.
    prefill_ids, decode_ids : list of int
        Replica indices per role.
    packets : deque of MigrationPacket
        Exported-but-unclaimed packets, oldest first (import pops the
        head only).

    Notes
    -----
    Outputs are bit-identical to a plain ``ReplicaSet`` and a single
    ``Engine`` on the same requests (RNG-stream contract; the packet
    carries the sampler stream position). ``stats()["disagg"]`` reports
    packets exported/imported/stolen, bytes moved and estimated fabric
    seconds. Decode-side preemption stays replica-local, exactly as in
    ``ReplicaSet``; a preempted imported request re-prefills on its
    decode replica (correct, merely not role-pure — the same tradeoff
    EPAC makes when a VRP iteration falls back to scalar code).

    Examples
    --------
    >>> eng = DisaggregatedEngine(model, params, cfg, dp=4, roles="auto")
    >>> outs = eng.generate(prompts, sampling)     # == ReplicaSet's
    >>> eng.stats()["disagg"]["bytes_moved"]
    """

    def __init__(self, model: Model, params, cfg: EngineConfig = None,
                 *, roles="auto", prefill_fraction: float = 0.5,
                 role_overrides: Optional[dict] = None,
                 max_inflight: Optional[int] = None, fabric=None,
                 dp: Optional[int] = None, mesh=None,
                 policy="least_loaded", ctx=None, step_workers=None):
        cfg = cfg or EngineConfig()
        if cfg.backend != "paged":
            raise ValueError("disaggregation requires the paged backend "
                             "(block migration has no static analogue)")
        n = int(mesh.shape["data"]) if mesh is not None and dp is None \
            else (dp or 1)
        self.roles = self._resolve_roles(roles, n, prefill_fraction)
        role_overrides = role_overrides or {}
        if not set(role_overrides) <= set(ROLES):
            raise ValueError(f"unknown role in overrides "
                             f"{sorted(role_overrides)} (have {ROLES})")
        frozen = {"block_size", "max_len", "backend"}
        for role, ov in role_overrides.items():
            if frozen & set(ov):
                raise ValueError(
                    f"{sorted(frozen & set(ov))} cannot differ per role "
                    "(shared migration geometry)")
        overrides = []
        for role in self.roles:
            ov = dict(role_overrides.get(role, {}))
            if role == "prefill":
                ov["spec_tokens"] = 0     # never decodes; drafts are waste
            overrides.append(ov)
        super().__init__(model, params, cfg, dp=len(self.roles),
                         mesh=mesh, policy=policy, ctx=ctx,
                         step_workers=step_workers, overrides=overrides)
        self.prefill_ids = [r for r, ro in enumerate(self.roles)
                            if ro == "prefill"]
        self.decode_ids = [r for r, ro in enumerate(self.roles)
                           if ro == "decode"]
        for r in self.prefill_ids:
            self.replicas[r].backend.prefill_only = True
        self.packets: collections.deque = collections.deque()
        dec_slots = sum(self.replicas[r].cfg.num_slots
                        for r in self.decode_ids)
        self.max_inflight = 2 * dec_slots if max_inflight is None \
            else max_inflight
        self.fabric = fabric or noc.V5E_FABRIC
        # migration telemetry
        self.exported = 0
        self.imported = 0
        self.stolen = 0
        self.bytes_moved = 0
        self.fabric_s = 0.0

    @staticmethod
    def _resolve_roles(roles, dp: int, prefill_fraction: float):
        if roles == "auto":
            if dp < 2:
                raise ValueError("disaggregation needs dp >= 2 "
                                 "(one replica per role minimum)")
            n_pre = max(1, min(dp - 1, round(dp * prefill_fraction)))
            roles = ("prefill",) * n_pre + ("decode",) * (dp - n_pre)
        roles = tuple(roles)
        if not set(roles) <= set(ROLES):
            raise ValueError(f"unknown role in {roles} (have {ROLES})")
        if "prefill" not in roles or "decode" not in roles:
            raise ValueError(f"need at least one replica per role, "
                             f"got {roles}")
        return roles

    # -- step loop -------------------------------------------------------

    def step(self):
        """One engine step: dispatch fresh work to prefill replicas
        (packet backpressure permitting), step them, export every
        first-token slot, land packets FCFS on decode replicas, steal
        for idle ones, then step the decode side."""
        self.steps += 1
        moved = self._dispatch()
        busy_pre = [(r, self.replicas[r]) for r in self.prefill_ids
                    if self.replicas[r].has_work]
        outs = self._timed_steps(busy_pre)
        exported = self._export_ready()
        imported = self._import_packets()
        stolen = self._steal()
        busy_dec = [(r, self.replicas[r]) for r in self.decode_ids
                    if self.replicas[r].has_work]
        outs += self._timed_steps(busy_dec)
        self.made_progress = bool(
            moved or exported or imported or stolen
            or any(eng.backend.made_progress
                   for _, eng in busy_pre + busy_dec))
        self._finish(outs)
        return outs

    @property
    def has_work(self) -> bool:
        """True while anything is queued, in flight, or active."""
        return bool(self.queue) or bool(self.packets) \
            or any(e.has_work for e in self.replicas)

    def _dispatch_candidates(self) -> list[int]:
        """Fresh admissions go to prefill replicas only; pause dispatch
        under packet backpressure so staging stays bounded."""
        if len(self.packets) >= self.max_inflight:
            return []
        return list(self.prefill_ids)

    # -- migration -------------------------------------------------------

    def _export_ready(self) -> int:
        """Export every occupied prefill slot (its prefill — and token 0
        unless it was a full-prefix hit — happened this step) to the
        packet deque, freeing the source blocks immediately."""
        n = 0
        for r in self.prefill_ids:
            be = self.replicas[r].backend
            for i, slot in enumerate(be.slots):
                if slot.req is not None:
                    self.packets.append(
                        transport.extract_slot(be, i, src=r))
                    n += 1
        self.exported += n
        return n

    def _import_packets(self) -> int:
        """Land packets on decode replicas, oldest first, head-blocking:
        a head that no decode replica can take yet parks the whole
        deque (never overtaken; an idle decode replica can always take
        it, so the head waits boundedly — same no-deadlock argument as
        the shared queue)."""
        n = 0
        while self.packets:
            pkt = self.packets[0]
            cands = [r for r in self.decode_ids if transport.can_import(
                self.replicas[r].backend, pkt)]
            if not cands:
                break
            self.packets.popleft()
            self._land(pkt, self.policy(self, cands))
            n += 1
        return n

    def _land(self, pkt, r: int):
        """Insert a packet into replica ``r`` and account the transfer:
        payload bytes over the data-axis hop distance between source
        and destination submeshes, priced by ``noc.p2p_time``."""
        transport.insert_packet(self.replicas[r].backend, pkt)
        self.imported += 1
        self.bytes_moved += pkt.payload_bytes
        self.fabric_s += noc.p2p_time(pkt.payload_bytes,
                                      abs(pkt.src - r), "data",
                                      self.fabric)

    def _steal(self) -> int:
        """Straggler handling: when no packets are in flight, an idle
        decode replica pulls work from the busiest one — the donor
        re-exports its NEWEST-ticket slot mid-decode (keeping its
        oldest admission, so the no-livelock guarantee survives) and
        the thief imports it through the ordinary migration path."""
        if self.packets:
            return 0
        n = 0
        for thief in self.decode_ids:
            tbe = self.replicas[thief].backend
            if tbe.has_work:
                continue
            donors = [r for r in self.decode_ids
                      if r != thief
                      and self.replicas[r].backend.num_active >= 2
                      and not self.replicas[r].backend.waiting]
            if not donors:
                continue
            donor = max(donors,
                        key=lambda r: self.replicas[r].backend.num_active)
            dbe = self.replicas[donor].backend
            # flush the donor's in-flight overlap token BEFORE choosing
            # a slot: harvesting can retire a request (max_tokens/stop),
            # and export_slot's own flush would then trip on a slot we
            # selected while it was still nominally occupied
            dbe.flush_overlap()
            live = [j for j, s in enumerate(dbe.slots) if s.req is not None]
            if len(live) < 2:
                continue                  # flush retired it below donor bar
            i = max(live, key=lambda j: dbe.slots[j].ticket)
            # pre-check the thief can land it (idle => no watermark),
            # so the slot is only uprooted when the move will succeed
            need = paged_kv.blocks_for(int(dbe.lengths[i]) + 1,
                                       tbe.cfg.block_size)
            if not tbe.alloc.can_admit(need, strict=False):
                continue
            self._land(transport.extract_slot(dbe, i, src=donor), thief)
            self.stolen += 1
            n += 1
        return n

    # -- reporting -------------------------------------------------------

    def stats(self) -> dict:
        """ReplicaSet telemetry plus a ``"disagg"`` section: roles,
        packet counts (exported / imported / stolen / in flight), bytes
        moved and estimated fabric seconds."""
        st = super().stats()
        st["disagg"] = {
            "roles": list(self.roles),
            "packets_inflight": len(self.packets),
            "exported": self.exported,
            "imported": self.imported,
            "stolen": self.stolen,
            "bytes_moved": self.bytes_moved,
            "fabric_s": self.fabric_s,
            "bytes_per_packet": (self.bytes_moved
                                 / max(self.imported, 1)),
        }
        return st

    def reset_telemetry(self):
        """Zero replica + set counters and the migration telemetry
        (bench warmup boundary); in-flight packets are untouched."""
        super().reset_telemetry()
        self.exported = self.imported = self.stolen = 0
        self.bytes_moved = 0
        self.fabric_s = 0.0
