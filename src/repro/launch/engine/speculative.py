"""Speculative decoding on the paged Engine: draft, verify, commit.

EPAC pairs each workload class with a specialized tile behind one
coherent uncore; the serving analogue adds a "fast tile" for the
memory-bound decode loop. A cheap drafter proposes K tokens per
scheduled request, and the target model scores all K+1 positions in ONE
batched pass through the paged KV cache (the multi-query verify kernel
fetches every pool block once for the whole window instead of once per
token). Acceptance couples the drafts to the request's own RNG stream:
the engine's sampler is a deterministic function of (seed, stream
position), so the standard rejection-sampling rule collapses to
exact-match acceptance and outputs are **bit-identical** to the
non-speculative engine — greedy and seeded alike
(engine/sampling.verify_accept has the full argument).

Rollback is free where it matters: full-attention layers live in the
block pool, so a rejected tail is erased by rewinding the slot's length
pointer and returning surplus tail blocks to the allocator — no block
copies. Per-slot state (windowed rings, SSM carries) is committed by
selecting the per-position candidate at the accept boundary inside the
same jit (transformer.select_verify_state).

Two pluggable drafters:

* ``NgramDrafter`` — zero extra parameters: prompt-lookup / self-
  drafting. The longest recent n-gram suffix of the request's history
  is matched against its own earlier tokens and the continuation is
  proposed. Free wins on repetitive text (code, templated prose).
* ``DraftModelDrafter`` — a small draft model sharing the target's
  tokenizer/config machinery, decoded greedily slot-parallel over
  dense per-slot caches; its cache rolls back by the same
  position-pointer rewind (hence the attention-only requirement).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding as shlib
from repro.launch.engine.api import (EngineConfig, RequestOutput,
                                     prefill_bucket)
from repro.launch.engine.sampling import (verify_accept,
                                          verify_accept_greedy)
from repro.launch.engine.scheduler import PagedBackend
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx


class NgramDrafter:
    """Zero-parameter prompt-lookup drafter (self-drafting).

    Proposes continuations by matching the longest suffix of a
    request's own history (up to ``max_ngram`` tokens) against earlier
    occurrences in that history and replaying the tokens that followed
    the most recent match. No device state, nothing to roll back —
    ``begin``/``rewind``/``drop`` are no-ops.

    Parameters
    ----------
    k : int
        Maximum drafts proposed per request per step.
    max_ngram : int
        Longest suffix length to key on; falls back to shorter
        suffixes (down to 1 token) before giving up.
    """

    def __init__(self, k: int, max_ngram: int = 3):
        self.k = k
        self.max_ngram = max_ngram

    def begin(self, slot: int, context):
        """No-op: the drafter reads each request's history directly."""

    def rewind(self, slot: int, new_len: int, tail_token: int):
        """No-op: no device state to roll back."""

    def drop(self, slot: int):
        """No-op: nothing installed per slot."""

    def propose(self, active, last_tokens, histories):
        """Per-slot proposals: ``{slot: [draft, ...]}`` (possibly [])."""
        return {i: self.lookup(histories[i]) for i in active}

    def lookup(self, history) -> list[int]:
        """Longest-suffix prompt lookup over one token history.

        Longest suffix first; within a suffix length, the MOST RECENT
        match with a full K-token continuation wins (on periodic text
        the very latest match sits so close to the end that its
        continuation is clipped — an earlier period offers the same
        tokens at full draft width). Falls back to the longest partial
        continuation when no match has K tokens after it.
        """
        H = len(history)
        best: list[int] = []
        for n in range(min(self.max_ngram, H - 1), 0, -1):
            suffix = history[H - n:]
            for e in range(H - 1, n - 1, -1):
                if history[e - n:e] == suffix:
                    cont = list(history[e:e + self.k])
                    if len(cont) == self.k:
                        return cont
                    if len(cont) > len(best):
                        best = cont
        return best


class DraftModelDrafter:
    """Draft-model drafter: greedy slot-parallel decode of a small LM.

    The draft shares the target's vocabulary and decodes over dense
    per-slot caches (one row per engine slot); its proposals never
    affect output correctness — only the acceptance rate — so it always
    decodes greedily. Rollback after a rejected tail is the same
    position-pointer rewind the paged pool uses, which is why the draft
    architecture must keep ALL state position-addressed: full-attention
    linear caches only (no sliding windows, no SSM carries).

    Parameters
    ----------
    model, params
        The draft ``Model`` (decoder-only, pattern all-"attn", no
        sliding window, same vocab as the target) and its params.
    cfg : EngineConfig
        The engine config (slot count, max_len, spec_tokens).
    ctx : RunCtx
        Kernel/sharding context shared with the engine.
    """

    def __init__(self, model: Model, params, cfg: EngineConfig,
                 ctx: RunCtx):
        if model is None or params is None:
            raise ValueError("drafter='draft_model' needs "
                             "EngineConfig.draft_model/draft_params")
        mc = model.cfg
        if (set(mc.block_pattern) != {"attn"} or mc.sliding_window
                or mc.enc_dec or mc.pos_embed != "none"):
            raise ValueError(
                "the draft model must be attention-only (linear caches "
                "roll back by position rewind; rings/SSM carries do not)")
        self.model = model
        self.params = params
        self.ctx = ctx
        self.k = cfg.spec_tokens
        self.num_slots = cfg.num_slots
        self.max_len = cfg.max_len
        self.cache = model.init_cache(cfg.num_slots, cfg.max_len)
        self.pos = np.zeros((cfg.num_slots,), np.int32)
        # slot -> token the draft cache is missing at its frontier: on a
        # FULL unshrunk accept the main cache is one token ahead of the
        # draft (the last draft was emitted but never fed back), so the
        # next propose() feeds it first — otherwise the draft cache
        # keeps a permanently unwritten position and proposal quality
        # silently erodes
        self._pending: dict[int, int] = {}
        self.ragged = model.serving_caps().ragged_prefill
        self._prefill_cache = {}

        def dec(params, cache, tokens, pos):
            logits, cache = model.decode_step(params, cache, tokens, pos,
                                              ctx)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._dec = jax.jit(dec, donate_argnums=(1,))

    def begin(self, slot: int, context):
        """(Re-)prefill the draft cache row for ``slot`` over the tokens
        the target has cached (admission and preemption-resume)."""
        S = len(context)
        Sb = prefill_bucket(S, 8, self.max_len) if self.ragged else S
        fn = self._prefill_cache.get(Sb)
        if fn is None:
            model, ctx, ragged, max_len = (self.model, self.ctx,
                                           self.ragged, self.max_len)

            def prefill_fn(params, cache, tokens, length, row_of_slot,
                           valid):
                _, dense = model.prefill(
                    params, {"tokens": tokens}, ctx, max_len=max_len,
                    length=length if ragged else None)
                return paged_kv.pack_prefill_state(cache, dense,
                                                   row_of_slot, valid)

            fn = jax.jit(prefill_fn, donate_argnums=(1,))
            self._prefill_cache[Sb] = fn
        toks = np.zeros((1, Sb), np.int32)
        toks[0, :S] = context
        row_of_slot = np.zeros((self.num_slots,), np.int32)
        valid = np.zeros((self.num_slots,), bool)
        valid[slot] = True
        self.cache = fn(self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray([S], dtype=jnp.int32),
                        jnp.asarray(row_of_slot), jnp.asarray(valid))
        self.pos[slot] = S
        self._pending.pop(slot, None)

    def rewind(self, slot: int, new_len: int, tail_token: int):
        """Resynchronise with the main cache after a verify.

        ``new_len`` is the main cache's new length, ``tail_token`` the
        token at its last position. Rejected tail: entries past
        ``new_len`` are masked by the position predicate and
        overwritten in place as decode re-advances — the dense-cache
        analogue of the paged pool's length-pointer rollback. FULL
        accept: the main cache is one token AHEAD of the draft
        (``tail_token`` was emitted from the window, never fed to the
        draft), so it is stashed and fed first at the next propose —
        leaving no unwritten hole behind the frontier."""
        if new_len > self.pos[slot]:
            self._pending[slot] = tail_token
        else:
            self.pos[slot] = new_len
            self._pending.pop(slot, None)

    def drop(self, slot: int):
        """Forget the slot: its cache row is garbage until ``begin``."""
        self.pos[slot] = 0
        self._pending.pop(slot, None)

    def propose(self, active, last_tokens, histories):
        """K greedy draft tokens for every active slot in K slot-parallel
        decode calls on the draft model. Slots with a pending catch-up
        token spend their first call feeding it (the cache position the
        last full accept skipped), so they return K-1 drafts that step."""
        toks = np.zeros((self.num_slots, 1), np.int32)
        queued = {}                       # catch-up slots: fed at step 1
        for i in active:
            if i in self._pending:
                toks[i, 0] = self._pending.pop(i)
                queued[i] = last_tokens[i]
            else:
                toks[i, 0] = last_tokens[i]
        pos = self.pos.copy()
        outs = np.zeros((self.num_slots, self.k), np.int32)
        for t in range(self.k):
            nxt, self.cache = self._dec(self.params, self.cache,
                                        jnp.asarray(toks),
                                        jnp.asarray(pos))
            nxt = np.asarray(nxt)
            outs[:, t] = nxt
            toks = nxt[:, None].astype(np.int32)
            if t == 0:
                for i, tok in queued.items():
                    toks[i, 0] = tok
            pos += 1
        for i in active:
            self.pos[i] += self.k
        # a catch-up slot's step-0 output followed the re-fed token, not
        # the actual next token (the bonus) — it is not a usable draft
        return {i: [int(x) for x in outs[i, (1 if i in queued else 0):]]
                for i in active}


_DRAFTERS = ("ngram", "draft_model")


class SpecDecodeBackend(PagedBackend):
    """Speculative-decoding backend: PagedBackend + draft/verify/commit.

    Wraps the paged scheduler unchanged for admission, growth,
    preemption and retirement; only the decode step differs. Each step:

    1. the drafter proposes up to K tokens per active slot;
    2. growth covers each slot's verify window (positions L..L+k_i),
       preferring to SHRINK a slot's window over preempting others
       (drafts are opportunistic; a preemption wastes a re-prefill) —
       the plain-decode footprint keeps the base LIFO guarantee;
    3. ONE jit'd device call embeds the (B, K+1) window, verifies it
       through the multi-query paged-attention kernel, applies the
       exact-match accept rule on-device against each request's own RNG
       stream, and commits per-slot state at the accept boundary;
    4. the host registers the emitted tokens through the standard
       acceptance state machine (stop tokens, max_tokens, streaming
       increments), rewinds each slot's length pointer over the
       rejected tail and returns surplus blocks to the pool.

    Attributes
    ----------
    drafter : NgramDrafter | DraftModelDrafter
        Proposal source, selected by ``EngineConfig.drafter``.
    spec_steps, spec_proposed, spec_accepted, spec_emitted : int
        Window telemetry surfaced by ``stats()['spec']``; per-request
        counters live on ``RequestHandle.num_draft_proposed/accepted``.

    Notes
    -----
    Output tokens are bit-identical to ``PagedBackend`` for any
    SamplingParams: the verify logits at row j equal the baseline
    decode logits after feeding tokens 0..j, and the accept rule IS the
    baseline sampler evaluated ahead on the same stream positions
    (tests/test_spec_decode.py pins both, greedy and seeded).
    """

    def __init__(self, model: Model, params, cfg: EngineConfig,
                 ctx: RunCtx):
        super().__init__(model, params, cfg, ctx)
        self.k = cfg.spec_tokens
        self.k1 = self.k + 1
        if cfg.max_len <= self.k1:
            raise ValueError(f"spec_tokens={self.k} needs max_len > "
                             f"{self.k1}")
        if cfg.drafter == "ngram":
            self.drafter = NgramDrafter(self.k, cfg.ngram_max)
        elif cfg.drafter == "draft_model":
            if cfg.draft_model is not None \
                    and cfg.draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError("draft and target models must share a "
                                 "vocabulary")
            self.drafter = DraftModelDrafter(cfg.draft_model,
                                             cfg.draft_params, cfg, ctx)
        else:
            raise ValueError(f"unknown drafter {cfg.drafter!r} "
                             f"(have {_DRAFTERS})")
        self.spec_steps = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_emitted = 0

        def verify_fn(greedy, params, pools, table, lengths, tokens,
                      num_drafts, seeds, steps, temps, top_ks, top_ps):
            def commit_fn(logits):
                if greedy:      # static: all slots argmax — skip the RNG
                    return verify_accept_greedy(logits, tokens,
                                                num_drafts)
                return verify_accept(logits, tokens, num_drafts, seeds,
                                     steps, temps, top_ks, top_ps)

            return model.decode_verify(params, pools, table, lengths,
                                       tokens, commit_fn, self.ctx)

        if self.shard is None:
            self._verify = jax.jit(verify_fn, static_argnums=(0,),
                                   donate_argnums=(2,))
        else:
            rep = shlib.replicated(self.shard)
            self._verify = jax.jit(
                verify_fn, static_argnums=(0,), donate_argnums=(2,),
                out_shardings=(rep, rep, self._pool_sh))

    # -- drafter synchronisation hooks ----------------------------------

    def _post_admit(self, rows):
        for (i, req, cached, S, block_ids) in rows:
            self.drafter.begin(i, list(cached))

    def _post_clear(self, i: int):
        self.drafter.drop(i)

    # -- scheduling ------------------------------------------------------

    def _imminent_growth(self) -> int:
        """Admission headroom: a verify window can claim up to
        blocks_for(L + K + 1) per active slot this step (the base
        backend's single growth block is the K=0 case)."""
        bs = self.cfg.block_size
        return sum(
            max(paged_kv.blocks_for(int(self.lengths[i]) + self.k1, bs)
                - len(s.blocks), 0)
            for i, s in enumerate(self.slots) if s.req is not None)

    def _grow_for_verify(self, drafts: dict):
        """Cover each slot's verify window, oldest-admission-first.

        The plain-decode footprint (blocks_for(L+1)) keeps the base
        backend's LIFO-preemption guarantee; beyond it, a slot SHRINKS
        its own draft window to what the free pool covers rather than
        evicting other sequences — speculation must never cost another
        request its slot."""
        bs = self.cfg.block_size
        order = sorted(
            (i for i, s in enumerate(self.slots) if s.req is not None),
            key=lambda i: self.slots[i].ticket)
        for i in order:
            slot = self.slots[i]
            if slot.req is None:          # preempted earlier in this pass
                continue
            L = int(self.lengths[i])
            need_min = paged_kv.blocks_for(L + 1, bs) - len(slot.blocks)
            while need_min > 0 and not self.alloc.can_alloc(need_min):
                cands = [(j, self.slots[j].ticket)
                         for j, s in enumerate(self.slots)
                         if s.req is not None]
                victim = self.alloc.select_victim(cands)
                self._preempt(victim)
                if victim == i:
                    break
            if slot.req is None:
                drafts.pop(i, None)
                continue
            while drafts.get(i):
                want = paged_kv.blocks_for(
                    L + len(drafts[i]) + 1, bs) - len(slot.blocks)
                if want <= 0 or self.alloc.can_alloc(want):
                    break
                drafts[i].pop()           # shrink, don't evict
            want = paged_kv.blocks_for(
                L + len(drafts.get(i, ())) + 1, bs) - len(slot.blocks)
            if want > 0:
                new = self.alloc.alloc(want)
                start = len(slot.blocks)
                slot.blocks.extend(new)
                self.table[i, start:start + len(new)] = new

    def _trim_blocks(self, i: int):
        """Return the rejected tail's surplus blocks to the pool and
        null their table entries — the length pointer was already
        rewound, so the blocks hold only invisible garbage."""
        slot = self.slots[i]
        extra = paged_kv.rollback_tail(slot.blocks, int(self.lengths[i]),
                                       self.cfg.block_size)
        if extra:
            self.alloc.free(extra)
            self.table[i, len(slot.blocks):] = paged_kv.NULL_BLOCK
        # rollback can only drop unwritten growth blocks: the committed
        # length never retreats below the shared-prefix frontier, so a
        # shared block can never be freed (or double-freed) here
        assert len(slot.blocks) >= slot.shared, \
            "verify rollback rewound into the shared prefix"

    # -- the speculative step -------------------------------------------

    def step(self) -> list[RequestOutput]:
        """Admissions, drafting, window growth, ONE verify call, commit."""
        outs: list[RequestOutput] = []
        self.made_progress = False
        self._admit(outs)
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return outs
        last = {i: self.slots[i].last_token for i in active}
        hist = {i: list(self.slots[i].req.prompt)
                + list(self.slots[i].req.token_ids) for i in active}
        drafts = {}
        for i, d in self.drafter.propose(active, last, hist).items():
            # clamp the window to the position cap: fed token j caches
            # at position L + j, which must stay < max_len (beyond it
            # there is no block-table row to grow into)
            cap = max(0, min(self.k,
                             self.cfg.max_len - 1 - int(self.lengths[i])))
            drafts[i] = list(d)[:cap]
        self._grow_for_verify(drafts)
        active = [i for i in active if self.slots[i].req is not None]
        if not active:
            return outs
        # the verify window starts writing at lengths[i]; a fresh
        # full-prefix hit puts that frontier inside its shared tail
        # block, which must be privatized before the device call
        self._ensure_cow(active)
        active = [i for i in active if self.slots[i].req is not None]
        if not active:
            return outs
        B = self.cfg.num_slots
        tokens = np.zeros((B, self.k1), np.int32)
        num_drafts = np.zeros((B,), np.int32)
        start_len = {}
        for i in active:
            row = [self.slots[i].last_token] + drafts.get(i, [])
            row += [row[-1]] * (self.k1 - len(row))  # pad: never accepted
            tokens[i] = row
            num_drafts[i] = len(drafts.get(i, ()))
            start_len[i] = int(self.lengths[i])
        sm = self.sampler
        out_toks, commit, self.pools = self._verify(
            bool((sm.temps <= 0.0).all()),
            self.params, self.pools, jnp.asarray(self.table),
            jnp.asarray(self.lengths), jnp.asarray(tokens),
            jnp.asarray(num_drafts), jnp.asarray(sm.seeds),
            jnp.asarray(sm.steps), jnp.asarray(sm.temps),
            jnp.asarray(sm.top_ks), jnp.asarray(sm.top_ps))
        out_toks = np.asarray(out_toks)
        commit = np.asarray(commit)
        self.steps += 1
        self.spec_steps += 1
        self.slot_steps += len(active)
        self.block_token_steps += self.alloc.used_count * self.cfg.block_size
        self.made_progress = True
        for i in active:
            n_emit = int(commit[i])
            req = self.slots[i].req
            nd = int(num_drafts[i])
            self.spec_proposed += nd
            req.num_draft_proposed += nd
            self.spec_accepted += n_emit - 1
            req.num_draft_accepted += n_emit - 1
            # fed tokens 0..commit-1 are validly cached; the pointer
            # rewind IS the rollback for the pool layers
            self.lengths[i] = start_len[i] + n_emit
            self.live_token_steps += int(self.lengths[i])
            for j in range(n_emit):
                out = self._accept(i, int(out_toks[i, j]))
                outs.append(out)
                self.spec_emitted += 1
                if out.finished:
                    break
            if self.slots[i].req is not None:
                self._trim_blocks(i)
                self.drafter.rewind(i, int(self.lengths[i]),
                                    int(tokens[i, n_emit - 1]))
        return outs

    # -- reporting ------------------------------------------------------

    def reset_telemetry(self):
        """Zero base + speculative counters (bench warmup boundary) —
        including the per-request draft counters on handles that are
        still active or queued, which would otherwise leak warmup
        proposals into the post-reset ``stats()['spec']`` accept rate
        (finished handles are dropped by the base reset)."""
        super().reset_telemetry()
        self.spec_steps = self.spec_proposed = 0
        self.spec_accepted = self.spec_emitted = 0
        live = [s.req for s in self.slots if s.req is not None]
        for r in live + list(self.waiting):
            r.num_draft_proposed = r.num_draft_accepted = 0

    def stats(self) -> dict:
        """Base paged stats + a ``spec`` section (window telemetry and
        the per-request accepted/proposed counters the bench cites)."""
        st = super().stats()
        reqs = [s.req for s in self.slots if s.req is not None]
        reqs += list(self.waiting) + list(self.finished)
        st["spec"] = {
            "spec_tokens": self.k,
            "steps": self.spec_steps,
            "proposed": self.spec_proposed,
            "accepted": self.spec_accepted,
            "emitted": self.spec_emitted,
            "accept_rate": self.spec_accepted / max(self.spec_proposed, 1),
            "emitted_per_step": self.spec_emitted / max(self.spec_steps, 1),
            "per_request": {
                r.uid: {"proposed": r.num_draft_proposed,
                        "accepted": r.num_draft_accepted,
                        "preemptions": r.num_preemptions} for r in reqs},
        }
        return st
