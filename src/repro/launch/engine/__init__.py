"""Unified serving engine: one front-end API over pluggable backends.

EPAC's host-device execution model — the host packs offloaded work and
drives jit'd device steps — behind a single dispatch interface, per the
Occamy/Epiphany lesson that heterogeneous execution strategies want one
entry point, not one API per strategy:

    engine = Engine(model, params, EngineConfig(backend="paged"))
    handle = engine.add_request(prompt, SamplingParams(temperature=0.7))
    while engine.has_work:
        for out in engine.step():          # streaming outputs
            consume(out.request_id, out.new_tokens)

Backends:
  * ``PagedBackend``  — continuous batching over a block-paged KV cache
    with optimistic admission, LIFO preemption (host-side recompute
    records) and power-of-two bucketed prefill.
  * ``StaticBackend`` — lockstep batcher: right-padded batched prefill
    (length-exact caches), per-row-position decode, batch retired as a
    unit.

Both sample on-device through one jit'd vectorized sampling step with
per-slot parameter arrays and per-request RNG streams
(engine/sampling.py), so outputs are independent of admission order and
slot placement even for stochastic decoding.

Both backends shard natively over a named mesh
(``EngineConfig(mesh=...)``): params by the 2-D FSDP x TP rules, the KV
block pool head-sharded over the TP axis (each device owns its kv-head
shard of every block), prefill/decode steps compiled against
NamedSharding — token-identical to single-device serving by contract.

``ReplicaSet`` scales out over the ``data`` axis: R full engine
replicas (own KV pool, own TP subgrid) behind ONE shared admission
queue with pluggable FCFS dispatch (least-loaded blocks / round-robin)
— EPAC's many-tiles-behind-one-hub, at the serving layer. Paged
admission drains same-bucket FCFS runs of the queue and prefills them
as one right-padded batch call (one jit trace per (bucket,
batch-bucket) pair); the static lockstep batch is already one batched
prefill call, width-capped by the same ``max_prefill_batch``.

``DisaggregatedEngine`` specializes those replicas by ROLE — prefill
replicas run admission + prefill only and export first-token slots as
``MigrationPacket``s; decode replicas import them (paged-block gather /
device_put / scatter, engine/transport.py) and run them to retirement —
EPAC's heterogeneous tiles behind one fabric, with ``core.noc.p2p_time``
pricing each migration. Outputs stay bit-identical to ``ReplicaSet`` by
the RNG-stream contract (sampler state travels in the packet).
"""

from repro.launch.engine.api import (Engine, EngineConfig, Request,
                                     RequestHandle, RequestOutput,
                                     SamplingParams)
from repro.launch.engine.disagg import DisaggregatedEngine
from repro.launch.engine.replica import ReplicaSet
from repro.launch.engine.sampling import sample_tokens
from repro.launch.engine.scheduler import PagedBackend
from repro.launch.engine.speculative import (DraftModelDrafter,
                                             NgramDrafter,
                                             SpecDecodeBackend)
from repro.launch.engine.static import StaticBackend
from repro.launch.engine.transport import MigrationPacket

__all__ = [
    "DisaggregatedEngine", "DraftModelDrafter", "Engine", "EngineConfig",
    "MigrationPacket", "NgramDrafter", "PagedBackend", "ReplicaSet",
    "Request", "RequestHandle", "RequestOutput", "SamplingParams",
    "SpecDecodeBackend", "StaticBackend", "sample_tokens",
]
