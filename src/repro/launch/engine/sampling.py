"""Vectorized on-device sampling step for the serving engine.

One jit'd function samples every decode slot at once from per-slot
parameter arrays (temperature / top-k / top-p / seed / RNG-stream step)
— replacing the old host-side per-row argmax/softmax loop. Retired or
empty slots ride along with default parameters; their draws are
discarded by the scheduler, keeping the call shape-stable.

Determinism contract: token t of a request is drawn from
``fold_in(PRNGKey(seed), t)`` — a pure function of the request's own
(seed, t) and its own logits — so sampled outputs do not depend on
admission order, slot index, co-batched requests, or preemption/resume
history (the stream position survives a preemption in the request's
recompute record).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fold_seed(seed: int) -> int:
    """Fold an arbitrary Python int seed into the non-negative int32
    range the device-side param arrays carry (numpy 2.x raises on
    out-of-range int32 assignment). Pure masking — a given seed always
    selects the same stream through every entry point."""
    return int(seed) & 0x7FFFFFFF


def _sample_row(logits, seed, step, temp, top_k, top_p):
    """One slot: logits (V,) f32 -> sampled token id (int32)."""
    V = logits.shape[0]
    greedy = jnp.argmax(logits).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    t = jnp.maximum(temp, 1e-6).astype(jnp.float32)
    scaled = logits.astype(jnp.float32) / t
    desc = jnp.flip(jnp.sort(scaled))               # descending
    # top-k: logits below the k-th highest are cut (k <= 0 disables;
    # ties at the threshold survive — the standard caveat)
    k_eff = jnp.where(top_k <= 0, V, jnp.clip(top_k, 1, V))
    thresh_k = desc[jnp.clip(k_eff - 1, 0, V - 1)]
    # top-p (nucleus): keep the smallest descending-probability prefix
    # whose mass reaches top_p — i.e. tokens whose PRECEDING cumulative
    # mass is < p. The argmax token is always kept.
    probs = jax.nn.softmax(desc)
    cum = jnp.cumsum(probs)
    kept = (cum - probs) < jnp.clip(top_p, 1e-6, 1.0)
    thresh_p = desc[jnp.maximum(jnp.sum(kept) - 1, 0)]
    allowed = (scaled >= thresh_k) & (scaled >= thresh_p)
    masked = jnp.where(allowed, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, masked).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)


@jax.jit
def sample_tokens(logits, seeds, steps, temps, top_ks, top_ps):
    """logits (B, V) f32 + per-slot param arrays (B,) -> (B,) int32."""
    return jax.vmap(_sample_row)(logits, seeds, steps, temps, top_ks,
                                 top_ps)


def fused_sample(logits, steps, samp):
    """Traced sampling tail of the scheduler's fused overlap step:
    greedy argmax when ``samp`` is None (same first-occurrence
    tie-break as the host fast path in ``SlotSampler.sample``), else
    the full per-slot sampler — ``samp`` is the (seeds, temps, top_ks,
    top_ps) arrays and ``steps`` the per-slot RNG-stream positions.
    Returns the (B,) int32 tokens still on device."""
    if samp is None:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    seeds, temps, top_ks, top_ps = samp
    return sample_tokens(logits, seeds, steps, temps, top_ks, top_ps)


def verify_accept(logits, tokens, num_drafts, seeds, steps, temps,
                  top_ks, top_ps):
    """Vectorized accept/resample rule for a speculative verify window.

    Because every request's sampler is a *deterministic* function of its
    own RNG stream (token t draws from ``fold_in(PRNGKey(seed), t)``
    applied to the target logits at position t), the standard
    rejection-sampling acceptance collapses to exact-match coupling:
    compute the token the baseline sampler WOULD emit at each of the
    K+1 window positions (greedy argmax, or the seeded categorical draw
    at stream position ``steps + j``), accept the longest draft prefix
    that matches those draws, and emit the first mismatching target
    token as the correction (the row-K target is the bonus token when
    every draft matches). Outputs are therefore bit-identical to
    non-speculative decoding — distribution preservation is trivial
    because this IS the same sampler, evaluated ahead of time.

    Parameters
    ----------
    logits : (B, K1, V) f32
        Target-model logits for the K+1 fed tokens; row j scores the
        position after fed token j.
    tokens : (B, K1) int32
        The fed window: row 0 is the last accepted token, rows 1..K the
        draft proposals (garbage-padded past ``num_drafts``).
    num_drafts : (B,) int32
        Usable drafts per slot; padded rows can never be accepted.
    seeds, steps, temps, top_ks, top_ps : (B,) arrays
        The per-slot sampling parameters (SlotSampler layout); ``steps``
        is each request's RNG-stream position at the window start.

    Returns
    -------
    out_tokens : (B, K1) int32
        The emitted tokens, -1 past each row's emitted prefix.
    commit : (B,) int32 in [1, K1]
        Fed tokens whose cache state is valid (accepted drafts + 1).
    """
    tgt = jax.vmap(
        lambda lg, s, st, t, k, p: jax.vmap(
            lambda l, j: _sample_row(l, s, st + j, t, k, p))(
                lg, jnp.arange(lg.shape[0], dtype=jnp.int32))
    )(logits, seeds, steps, temps, top_ks, top_ps)           # (B, K1)
    return _accept_targets(tgt, tokens, num_drafts)


def verify_accept_greedy(logits, tokens, num_drafts):
    """All-greedy fast path of ``verify_accept`` (the serving default):
    targets are plain argmax rows — no sort/top-k/top-p/RNG machinery,
    which dominates the verify step's device time on small models. The
    backend selects it at call time when every slot decodes greedily;
    outputs equal ``verify_accept`` with ``temps <= 0``."""
    return _accept_targets(jnp.argmax(logits, -1).astype(jnp.int32),
                           tokens, num_drafts)


def _accept_targets(tgt, tokens, num_drafts):
    """Shared tail of the accept rule: longest draft prefix matching the
    per-position target draws, plus the correction/bonus target."""
    K1 = tgt.shape[1]
    jidx = jnp.arange(K1, dtype=jnp.int32)
    ok = (tokens[:, 1:] == tgt[:, :-1]) \
        & (jidx[None, :-1] < num_drafts[:, None])
    # accepted = length of the leading all-True prefix
    acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    out = jnp.where(jidx[None, :] <= acc[:, None], tgt, -1)
    return out, acc + 1


class SlotSampler:
    """Host-side mirror of the per-slot sampling parameter arrays.

    The backend installs a request's SamplingParams at admission and
    resets the slot at retirement; ``sample`` forwards the arrays to the
    jit'd step. ``steps[i]`` is the owning request's RNG-stream position
    and must be advanced by the backend after every accepted draw.
    """

    def __init__(self, num_slots: int):
        self.temps = np.zeros((num_slots,), np.float32)
        self.top_ks = np.zeros((num_slots,), np.int32)
        self.top_ps = np.ones((num_slots,), np.float32)
        self.seeds = np.zeros((num_slots,), np.int32)
        self.steps = np.zeros((num_slots,), np.int32)

    def install(self, slot: int, sampling, n_sampled: int):
        """Install a request's SamplingParams at admission; ``n_sampled``
        is its RNG-stream position (nonzero on preemption resume)."""
        self.temps[slot] = sampling.temperature
        self.top_ks[slot] = sampling.top_k
        self.top_ps[slot] = sampling.top_p
        self.seeds[slot] = fold_seed(sampling.seed)
        self.steps[slot] = n_sampled

    def clear(self, slot: int):
        """Reset a retired/preempted slot to the default (greedy) row."""
        self.temps[slot] = 0.0
        self.top_ks[slot] = 0
        self.top_ps[slot] = 1.0
        self.seeds[slot] = 0
        self.steps[slot] = 0

    def sample(self, logits):
        """logits: (B, V) device array -> (B,) numpy int32 tokens."""
        if (self.temps <= 0.0).all():
            # all-greedy fast path (the default): skip the per-slot
            # sort/softmax/cumsum machinery the stochastic step needs
            return np.argmax(np.asarray(logits), -1).astype(np.int32)
        toks = sample_tokens(logits, jnp.asarray(self.seeds),
                             jnp.asarray(self.steps),
                             jnp.asarray(self.temps),
                             jnp.asarray(self.top_ks),
                             jnp.asarray(self.top_ps))
        return np.asarray(toks)

    def fused_args(self, steps):
        """The (steps, samp) pair the scheduler threads into its fused
        overlap step: ``samp`` is None on the all-greedy fast path
        (selecting ``fused_sample``'s argmax variant — a distinct jit
        trace, since the pytree structure differs), else the per-slot
        parameter arrays. ``steps`` overrides ``self.steps`` — under
        overlap a slot with an un-harvested in-flight token sits one
        stream position ahead of the host mirror."""
        if (self.temps <= 0.0).all():
            return steps, None
        return steps, (self.seeds, self.temps, self.top_ks, self.top_ps)

    def sample_one(self, slot: int, row_logits):
        """Sample for ONE slot (prefill admission) from the parameters
        just installed — same streams as the batch path, no duplicate
        parameter marshalling. row_logits: (1, V)."""
        if self.temps[slot] <= 0.0:
            return int(np.argmax(np.asarray(row_logits)[0]))
        sl = slice(slot, slot + 1)
        toks = sample_tokens(row_logits, jnp.asarray(self.seeds[sl]),
                             jnp.asarray(self.steps[sl]),
                             jnp.asarray(self.temps[sl]),
                             jnp.asarray(self.top_ks[sl]),
                             jnp.asarray(self.top_ps[sl]))
        return int(np.asarray(toks)[0])
