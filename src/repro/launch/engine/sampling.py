"""Vectorized on-device sampling step for the serving engine.

One jit'd function samples every decode slot at once from per-slot
parameter arrays (temperature / top-k / top-p / seed / RNG-stream step)
— replacing the old host-side per-row argmax/softmax loop. Retired or
empty slots ride along with default parameters; their draws are
discarded by the scheduler, keeping the call shape-stable.

Determinism contract: token t of a request is drawn from
``fold_in(PRNGKey(seed), t)`` — a pure function of the request's own
(seed, t) and its own logits — so sampled outputs do not depend on
admission order, slot index, co-batched requests, or preemption/resume
history (the stream position survives a preemption in the request's
recompute record).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fold_seed(seed: int) -> int:
    """Fold an arbitrary Python int seed into the non-negative int32
    range the device-side param arrays carry (numpy 2.x raises on
    out-of-range int32 assignment). Pure masking — a given seed always
    selects the same stream through every entry point."""
    return int(seed) & 0x7FFFFFFF


def _sample_row(logits, seed, step, temp, top_k, top_p):
    """One slot: logits (V,) f32 -> sampled token id (int32)."""
    V = logits.shape[0]
    greedy = jnp.argmax(logits).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    t = jnp.maximum(temp, 1e-6).astype(jnp.float32)
    scaled = logits.astype(jnp.float32) / t
    desc = jnp.flip(jnp.sort(scaled))               # descending
    # top-k: logits below the k-th highest are cut (k <= 0 disables;
    # ties at the threshold survive — the standard caveat)
    k_eff = jnp.where(top_k <= 0, V, jnp.clip(top_k, 1, V))
    thresh_k = desc[jnp.clip(k_eff - 1, 0, V - 1)]
    # top-p (nucleus): keep the smallest descending-probability prefix
    # whose mass reaches top_p — i.e. tokens whose PRECEDING cumulative
    # mass is < p. The argmax token is always kept.
    probs = jax.nn.softmax(desc)
    cum = jnp.cumsum(probs)
    kept = (cum - probs) < jnp.clip(top_p, 1e-6, 1.0)
    thresh_p = desc[jnp.maximum(jnp.sum(kept) - 1, 0)]
    allowed = (scaled >= thresh_k) & (scaled >= thresh_p)
    masked = jnp.where(allowed, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, masked).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)


@jax.jit
def sample_tokens(logits, seeds, steps, temps, top_ks, top_ps):
    """logits (B, V) f32 + per-slot param arrays (B,) -> (B,) int32."""
    return jax.vmap(_sample_row)(logits, seeds, steps, temps, top_ks,
                                 top_ps)


class SlotSampler:
    """Host-side mirror of the per-slot sampling parameter arrays.

    The backend installs a request's SamplingParams at admission and
    resets the slot at retirement; ``sample`` forwards the arrays to the
    jit'd step. ``steps[i]`` is the owning request's RNG-stream position
    and must be advanced by the backend after every accepted draw.
    """

    def __init__(self, num_slots: int):
        self.temps = np.zeros((num_slots,), np.float32)
        self.top_ks = np.zeros((num_slots,), np.int32)
        self.top_ps = np.ones((num_slots,), np.float32)
        self.seeds = np.zeros((num_slots,), np.int32)
        self.steps = np.zeros((num_slots,), np.int32)

    def install(self, slot: int, sampling, n_sampled: int):
        self.temps[slot] = sampling.temperature
        self.top_ks[slot] = sampling.top_k
        self.top_ps[slot] = sampling.top_p
        self.seeds[slot] = fold_seed(sampling.seed)
        self.steps[slot] = n_sampled

    def clear(self, slot: int):
        self.temps[slot] = 0.0
        self.top_ks[slot] = 0
        self.top_ps[slot] = 1.0
        self.seeds[slot] = 0
        self.steps[slot] = 0

    def sample(self, logits):
        """logits: (B, V) device array -> (B,) numpy int32 tokens."""
        if (self.temps <= 0.0).all():
            # all-greedy fast path (the default): skip the per-slot
            # sort/softmax/cumsum machinery the stochastic step needs
            return np.argmax(np.asarray(logits), -1).astype(np.int32)
        toks = sample_tokens(logits, jnp.asarray(self.seeds),
                             jnp.asarray(self.steps),
                             jnp.asarray(self.temps),
                             jnp.asarray(self.top_ks),
                             jnp.asarray(self.top_ps))
        return np.asarray(toks)

    def sample_one(self, slot: int, row_logits):
        """Sample for ONE slot (prefill admission) from the parameters
        just installed — same streams as the batch path, no duplicate
        parameter marshalling. row_logits: (1, V)."""
        if self.temps[slot] <= 0.0:
            return int(np.argmax(np.asarray(row_logits)[0]))
        sl = slice(slot, slot + 1)
        toks = sample_tokens(row_logits, jnp.asarray(self.seeds[sl]),
                             jnp.asarray(self.steps[sl]),
                             jnp.asarray(self.temps[sl]),
                             jnp.asarray(self.top_ks[sl]),
                             jnp.asarray(self.top_ps[sl]))
        return int(np.asarray(toks)[0])
