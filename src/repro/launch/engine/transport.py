"""KV-cache migration between replica pools (prefill/decode handoff).

EPAC moves a cache line between tiles by gathering it from the owning L2
slice, cutting it through the CHI NoC (or the C2C SerDes when the peers
sit on different dies) and installing it in the destination slice with
the directory updated. This module is the serving analogue for one
request's paged KV state: **gather** the slot's block chain and per-slot
recurrent state out of the source replica's pools
(``paged_kv.extract_blocks``), **move** it across submeshes with
``jax.device_put`` onto the destination pool's shardings, and
**scatter** it into freshly alloc()'d destination blocks
(``paged_kv.insert_blocks``) with the host-side view installed by
``PagedBackend.import_slot`` (refcounts, block table, sampler stream
position, prefix-index registration).

Design notes:

* **One jit trace per backend and direction.** Block-id vectors are
  padded to ``layout.max_blocks_per_seq`` with the reserved null block:
  pad gathers read null content nobody consumes, pad scatters collide
  in the destination null block (harmless by the same argument as
  ``pack_prefill_kv``'s pad routing), and the destination slot index is
  a traced scalar — so chain length and slot never retrigger
  compilation.
* **Leak-free by construction.** ``extract_slot`` gathers *content*
  (functional arrays — the gather snapshots values, so freeing the
  chain afterwards can never corrupt the packet), then
  ``detach_slot`` returns the source blocks immediately. A packet that
  is later dropped — cancellation mid-migration, shutdown — holds no
  block in ANY pool.
* **Position-agnostic.** The packet carries the cached length, the next
  token to feed and the handle (whose ``_n_sampled`` is the RNG stream
  position), so first-token handoff, the full-hit rewind
  (``length = S - 1``, nothing sampled yet) and mid-decode re-export
  for straggler stealing all take the same path, and outputs stay
  bit-identical by the engine's RNG-stream contract.

``payload_bytes`` counts the *useful* payload (real blocks + per-slot
state, not the null-block padding); the disaggregated front-end prices
it with ``core.noc.p2p_time`` per packet.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.engine.api import RequestHandle
from repro.models import paged_kv


@dataclasses.dataclass
class MigrationPacket:
    """One request's cache in flight between replica pools.

    Attributes
    ----------
    req : RequestHandle
        The live handle — prompt, emitted tokens, SamplingParams and
        the RNG stream position (``_n_sampled``) all travel with it.
    length : int
        Cached tokens at export (position-agnostic: anywhere from the
        full-hit rewind to deep mid-decode).
    last_token : int
        The next token the destination decode feeds.
    n_blocks : int
        Real blocks in the chain (the gathered state is padded to the
        layout's max chain width with null-block content).
    state : Any
        The gathered device tree: block-pool leaves ``(L, W, ...)`` and
        per-slot leaves ``(L, 1, ...)``, same structure as the pools.
    payload_bytes : int
        Useful payload (real blocks + per-slot state; padding excluded)
        — what ``core.noc.p2p_time`` prices.
    src : int
        Exporting replica index (hop-count accounting).
    kv_format : Any
        The source pool's ``paged_kv.PoolSpec`` (None = bf16). Scale
        leaves travel inside ``state`` like any pool leaf, so extract/
        insert are bit-exact on the stored payload; ``insert_packet``
        rejects a format mismatch by naming this gate.
    """

    req: RequestHandle
    length: int
    last_token: int
    n_blocks: int
    state: Any
    payload_bytes: int
    src: int
    kv_format: Any = None


def _pool_mask(backend):
    """Cached kind-string tree ("pool" | "slot" | "cross") for a
    backend's pools."""
    mask = getattr(backend, "_migration_mask", None)
    if mask is None:
        mask = backend.model.paged_pool_mask(
            backend.layout, spec=getattr(backend, "kv_spec", None))
        backend._migration_mask = mask
    return mask


def _gather_fn(backend):
    """Cached jit: (pools, padded ids, slot, arena) -> gathered packet
    state. The arena row index is a traced scalar like the slot (and
    simply unused when the model has no "cross" leaves), so every
    backend keeps the one-trace-per-direction property."""
    fn = getattr(backend, "_migration_gather", None)
    if fn is None:
        mask = _pool_mask(backend)

        def gather(pools, ids, slot, arena):
            return paged_kv.extract_blocks(pools, mask, ids, slot,
                                           arena=arena)

        fn = jax.jit(gather)
        backend._migration_gather = fn
    return fn


def _scatter_fn(backend):
    """Cached jit: (pools, state, padded ids, slot, arena) -> pools,
    with the destination pools donated (same buffer-reuse pattern as
    the COW copy) and pinned to their NamedShardings when sharded."""
    fn = getattr(backend, "_migration_scatter", None)
    if fn is None:
        mask = _pool_mask(backend)

        def scatter(pools, state, ids, slot, arena):
            return paged_kv.insert_blocks(pools, mask, state, ids, slot,
                                          arena=arena)

        if backend._pool_sh is None:
            fn = jax.jit(scatter, donate_argnums=(0,))
        else:
            fn = jax.jit(scatter, donate_argnums=(0,),
                         out_shardings=backend._pool_sh)
        backend._migration_scatter = fn
    return fn


def _pad_ids(ids, width: int):
    """Pad a block chain to the fixed trace width with the null block."""
    out = np.full((width,), paged_kv.NULL_BLOCK, np.int32)
    out[:len(ids)] = ids
    return jnp.asarray(out)


def _payload_bytes(state, mask, n_blocks: int) -> int:
    """Useful packet bytes: real blocks of every pool leaf (padding to
    the trace width excluded) plus the full per-slot and cross-arena
    rows (each travels whole — size 1 along axis 1)."""
    total = 0
    for leaf, kind in zip(jax.tree.leaves(state), jax.tree.leaves(mask)):
        nbytes = leaf.size * jnp.dtype(leaf.dtype).itemsize
        if kind == "pool":
            nbytes = nbytes // leaf.shape[1] * n_blocks
        total += nbytes
    return int(total)


def extract_slot(backend, i: int, *, src: int = 0) -> MigrationPacket:
    """Export occupied slot ``i`` as a MigrationPacket and release it.

    Gathers the slot's block chain and per-slot state out of the pools,
    then ``detach_slot`` frees the chain — eagerly, so the packet holds
    no source-pool blocks and dropping it leaks nothing. Safe because
    the gather snapshots values (JAX arrays are functional); a later
    reuse of those physical blocks cannot reach into the packet.
    """
    req, blocks, length, last_token = backend.export_slot(i)
    width = backend.layout.max_blocks_per_seq
    # snapshot the slot's arena binding before detach frees it (the
    # scalar is unused in the trace for models with no "cross" leaves)
    arena = int(backend.arena_ids[i])
    state = _gather_fn(backend)(
        backend.pools, _pad_ids(blocks, width), jnp.int32(i),
        jnp.int32(arena))
    nbytes = _payload_bytes(state, _pool_mask(backend), len(blocks))
    backend.detach_slot(i)
    return MigrationPacket(req, length, last_token, len(blocks), state,
                           nbytes, src,
                           kv_format=getattr(backend, "kv_spec", None))


def can_import(backend, packet: MigrationPacket) -> bool:
    """True when ``backend`` can land the packet now: a decode lane not
    spoken for, admission headroom for the chain plus this step's
    growth block (the watermark is waived for an idle backend — the
    same sole-request progress guarantee as ``_drain_bucket_run``, and
    why an idle decode replica can ALWAYS take the queue head), and a
    cross-arena row when the request carries encoder features and no
    resident row already shares them."""
    if backend.num_active + len(backend.waiting) >= backend.cfg.num_slots:
        return False
    if backend.arena is not None:
        resident = backend.arena.lookup(id(packet.req.encoder_features))
        if resident == paged_kv.NULL_ARENA \
                and not backend.arena.can_admit(1):
            return False
    need = paged_kv.blocks_for(packet.length + 1, backend.cfg.block_size)
    return backend.alloc.can_admit(need, strict=backend.num_active > 0)


def insert_packet(backend, packet: MigrationPacket) -> int:
    """Land a packet: alloc destination blocks, install the host-side
    slot view (``import_slot``), move the state onto the destination
    pools' placement and scatter it in. Returns the slot index.

    Callers gate on ``can_import`` first; the alloc here may still
    reclaim prefix-LRU blocks (the allocator unlinks them from the
    index via its eviction hook, exactly like admission).
    """
    if packet.kv_format != getattr(backend, "kv_spec", None):
        raise ValueError(
            "KV-format mismatch on migration "
            f"(MigrationPacket.kv_format={packet.kv_format!r} vs "
            f"destination pool spec {getattr(backend, 'kv_spec', None)!r})"
            ": source and destination replicas must share one "
            "EngineConfig.kv_dtype")
    ids = backend.alloc.alloc(packet.n_blocks)
    i = backend.import_slot(packet.req, ids, packet.length,
                            packet.last_token)
    state = jax.tree.map(lambda d, p: jax.device_put(p, d.sharding),
                         backend.pools, packet.state)
    width = backend.layout.max_blocks_per_seq
    # import_slot bound the slot to an arena row (fresh, or shared with
    # a resident request); scattering the packet's cross row into a
    # shared row rewrites identical content — the encoder is
    # deterministic — so the overwrite is idempotent
    arena = int(backend.arena_ids[i])
    backend.pools = _scatter_fn(backend)(
        backend.pools, state, _pad_ids(ids, width), jnp.int32(i),
        jnp.int32(arena))
    return i
