"""Engine front-end: SamplingParams, request handles, streaming outputs.

The Engine owns request admission and the step loop; backends own the
device state (dense cache or paged pools) and implement three methods:
``enqueue(handle)``, ``step() -> list[RequestOutput]`` and ``stats()``.
Every token is *emitted the step it is sampled* (prefill included), so
``step()`` doubles as the streaming interface; the final decode step of a
request never pays for caching a token nobody will attend.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence

from repro.models.model import Model
from repro.models.transformer import RunCtx


def prefill_bucket(n: int, floor: int, cap: int) -> int:
    """Shared prompt-bucket policy for BOTH backends: the smallest power
    of two >= max(n, floor), clamped to cap. One helper so static and
    paged compile the SAME O(log(max_len / floor)) prefill buckets on any
    trace — the floor (the engine's block size) cuts the sub-block
    buckets the static backend used to compile on short prompts (7 vs 4
    compiles on the bench smoke trace before unification)."""
    return min(max(1 << max(n - 1, 0).bit_length(), floor), cap)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.

    ``temperature <= 0`` selects greedy (argmax) decoding; otherwise
    logits are temperature-scaled, truncated to the ``top_k`` highest
    and to the top-p nucleus, then sampled from the request's own RNG
    stream.

    Parameters
    ----------
    max_tokens : int
        Retire the request after this many emitted tokens (>= 1).
    temperature : float
        Softmax temperature; ``<= 0`` selects greedy decoding.
    top_k : int
        Keep only the ``top_k`` highest logits (0 disables; ties at
        the threshold survive — the standard caveat).
    top_p : float
        Nucleus sampling in (0, 1]: keep the smallest prefix of
        descending-probability tokens with cumulative mass >= top_p.
    seed : int
        Derives the request's own RNG stream: token t draws from
        ``fold_in(PRNGKey(seed), t)``, so sampled outputs are
        reproducible and independent of admission order, slot
        placement, co-batched traffic, preemption history, replica
        placement and speculative decoding. Requests SHARING a seed
        share the stream (two identical prompts sample identically) —
        pass distinct seeds when you want diversity, e.g. best-of-n
        over one prompt.
    stop_token_ids : tuple of int
        Retire the request on match (the stop token is stripped, never
        emitted), on top of the engine-level ``eos_id``.

    Raises
    ------
    ValueError
        On ``max_tokens < 1``, ``top_p`` outside (0, 1], or negative
        ``top_k``.
    """

    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_token_ids: tuple[int, ...] = ()

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")

    @property
    def greedy(self) -> bool:
        """True when this request decodes greedily (temperature <= 0)."""
        return self.temperature <= 0.0


@dataclasses.dataclass(frozen=True)
class Request:
    """One unit of admission work, as submitted.

    ``Engine.add_request`` accepts either a bare prompt or a Request;
    the Request form is how encoder-decoder workloads attach their
    encoder features. The same object travels unchanged through the
    ``ReplicaSet`` shared queue and ``DisaggregatedEngine`` migration
    packets — validation happens once, at submission.

    Parameters
    ----------
    prompt : sequence of int
        Decoder prompt token ids (>= 1 token; for enc-dec models this
        is the decoder-side prompt, e.g. whisper's task tokens).
    sampling : SamplingParams, optional
        Decoding parameters; defaults to ``SamplingParams()``.
    encoder_features : array or None
        Precomputed encoder-frontend embeddings of shape
        ``(frames, d_model)`` — whisper log-mel conv frames or qwen2-vl
        patch embeds per ``input_specs``. Required for enc-dec configs,
        rejected otherwise (``Engine.check_request``). Submitting the
        SAME array object with several requests shares one cross-KV
        arena row by refcount (e.g. best-of-n over one audio clip).
    """

    prompt: Sequence[int]
    sampling: Optional[SamplingParams] = None
    encoder_features: Any = None


@dataclasses.dataclass
class RequestHandle:
    """Live view of one request; ``token_ids`` grows as the engine steps.

    Attributes
    ----------
    uid : int
        Engine-assigned request id (matches ``RequestOutput.request_id``).
    prompt : list of int
        The prompt token ids as submitted.
    sampling : SamplingParams
        The request's decoding parameters.
    token_ids : list of int
        Tokens emitted so far, in order (stop tokens are stripped).
    finished : bool
        True once the request retired.
    finish_reason : str or None
        ``"length"`` (max_tokens) or ``"stop"`` (eos / stop token).
    num_preemptions : int
        Times this request was LIFO-preempted and later resumed.
    num_draft_proposed, num_draft_accepted : int
        Speculative-decoding counters: draft tokens proposed for /
        accepted into this request (0 unless ``spec_tokens > 0``) —
        the per-request source of truth behind
        ``Engine.stats()["spec"]``.
    t_submit, t_first_token : float or None
        Monotonic-clock stamps at handle creation and at the first
        sampled token; their difference is the request's TTFT,
        aggregated into p50/p95/p99 by ``latency_stats`` (surfaced via
        ``Engine.stats()["latency"]`` and ``ReplicaSet.stats()``).
    t_tokens : list of float
        Monotonic stamp per *sampled* token (stripped stop tokens
        included — the stream advanced even though nothing was
        emitted). Mean inter-token gap is the request's TPOT;
        aggregated by ``latency_stats``.
    encoder_features : array or None
        The submitted ``Request.encoder_features``, carried with the
        handle through replica queues and migration packets (the
        cross-KV arena row is recomputed from it on (re-)admission).
    """

    uid: int
    prompt: list[int]
    sampling: SamplingParams
    encoder_features: Any = None
    token_ids: list[int] = dataclasses.field(default_factory=list)
    finished: bool = False
    finish_reason: Optional[str] = None      # "length" | "stop"
    num_preemptions: int = 0
    # speculative decoding: drafts proposed for / accepted into this
    # request (the bench's accepted-tokens-per-step source of truth)
    num_draft_proposed: int = 0
    num_draft_accepted: int = 0
    # latency telemetry: stamped at submission / first sampled token /
    # every sampled token (monotonic clock throughout)
    t_submit: float = dataclasses.field(default_factory=time.monotonic)
    t_first_token: Optional[float] = None
    t_tokens: list[float] = dataclasses.field(default_factory=list)
    # internal: RNG stream position (== tokens sampled; differs from
    # len(token_ids) only after a stripped stop token)
    _n_sampled: int = 0

    @property
    def out(self) -> list[int]:
        """Legacy PR-1 ``Scheduler`` alias for ``token_ids``."""
        return self.token_ids

    @property
    def done(self) -> bool:
        """Legacy PR-1 ``Scheduler`` alias for ``finished``."""
        return self.finished


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """One streaming increment: tokens a request gained this step.

    Attributes
    ----------
    request_id : int
        The owning request's ``RequestHandle.uid``.
    new_tokens : tuple of int
        Tokens emitted this step — usually one; empty on a stripped
        stop token; several under speculative decoding.
    num_tokens : int
        Total tokens emitted for the request so far.
    finished : bool
        True when this increment retires the request.
    finish_reason : str or None
        ``"length"`` or ``"stop"`` when ``finished``, else None.
    """

    request_id: int
    new_tokens: tuple[int, ...]
    num_tokens: int                          # total emitted so far
    finished: bool
    finish_reason: Optional[str] = None


def register_sample(req: RequestHandle, tok: int, eos_id: int,
                    on_finish) -> RequestOutput:
    """Shared token-acceptance state machine for all backends: advance
    the request's RNG stream, strip stop tokens, retire on stop or
    max_tokens, and emit the streaming increment. ``on_finish()`` runs
    backend cleanup (free blocks / park the lane) after the handle's
    finished/finish_reason flags are set — keeping both backends on
    byte-identical emission semantics."""
    now = time.monotonic()
    req._n_sampled += 1
    req.t_tokens.append(now)
    if req._n_sampled == 1:
        req.t_first_token = now
    stop = (eos_id >= 0 and tok == eos_id) \
        or tok in req.sampling.stop_token_ids
    if not stop:
        req.token_ids.append(tok)
        if len(req.token_ids) < req.sampling.max_tokens:
            return RequestOutput(req.uid, (tok,), len(req.token_ids),
                                 False)
    reason = "stop" if stop else "length"
    req.finished = True
    req.finish_reason = reason
    on_finish()
    return RequestOutput(req.uid, () if stop else (tok,),
                         len(req.token_ids), True, reason)


def _pctl(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted sample (no
    interpolation — p99 of 3 samples is the max, not an extrapolation)."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(round(q * (len(sorted_vals) - 1)))))
    return float(sorted_vals[i])


def latency_stats(handles) -> dict:
    """Aggregate per-request latency stamps into TTFT/TPOT percentiles.

    TTFT is ``t_first_token - t_submit`` per request; TPOT is the mean
    inter-token gap ``(t_tokens[-1] - t_tokens[0]) / (n - 1)`` over
    requests with at least two sampled tokens. Both are summarized with
    nearest-rank percentiles. This is the one aggregation behind
    ``Engine.stats()["latency"]``, ``ReplicaSet.stats()`` and the bench
    ``open_loop`` section, so every surface reports the same numbers.

    Parameters
    ----------
    handles : iterable of RequestHandle
        Finished and/or in-flight handles; requests with no sampled
        token yet contribute to neither distribution.

    Returns
    -------
    dict
        ``{"ttft": {count, mean_s, p50_s, p95_s, p99_s},
        "tpot": {...}}`` — zeros when a distribution is empty.
    """
    ttft = sorted(h.t_first_token - h.t_submit for h in handles
                  if h.t_first_token is not None)
    tpot = sorted((h.t_tokens[-1] - h.t_tokens[0]) / (len(h.t_tokens) - 1)
                  for h in handles if len(h.t_tokens) >= 2)

    def summarize(vals):
        return {"count": len(vals),
                "mean_s": float(sum(vals) / len(vals)) if vals else 0.0,
                "p50_s": _pctl(vals, 0.50),
                "p95_s": _pctl(vals, 0.95),
                "p99_s": _pctl(vals, 0.99)}

    return {"ttft": summarize(ttft), "tpot": summarize(tpot)}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine/backends configuration (immutable; shared by replicas).

    Parameters
    ----------
    backend : {"paged", "static"}
        ``"paged"`` — continuous batching over the block-paged KV pool
        (becomes the speculative backend when ``spec_tokens > 0``);
        ``"static"`` — the lockstep right-padded baseline.
    num_slots : int
        Decode batch width (concurrent sequences on device).
    block_size, num_blocks : int
        Paged pool geometry: tokens per cache block and pool size
        (block 0 is the reserved null block).
    max_len : int
        Per-sequence position cap (prompt + output).
    eos_id : int
        Engine-level stop token; -1 retires on length only.
    watermark_blocks : int
        Paged admission headroom: keep this many blocks free for
        in-flight growth while admitting new sequences.
    bucketed_prefill : bool
        Right-pad prompts to power-of-two buckets when the model
        supports ragged prefill (O(log max_len) prefill compiles).
    max_prefill_batch : int
        Cap on requests prefilled in one batched admission call;
        <= 0 lifts the cap to the slot count.
    prefix_cache : bool
        Copy-on-write prefix caching on the paged backend: admissions
        match the longest block-aligned cached prefix against a
        host-side trie, share those physical blocks by refcount, and
        prefill only the non-shared suffix; retirement parks
        unreferenced indexed blocks in an LRU reclaimed before the
        allocator reports exhaustion. Active only when the model's
        whole state lives in the shared pool
        (``ServingCaps.prefix_cache``); outputs are token-identical
        with it on or off.
    mesh : jax.sharding.Mesh or None
        Shard params (2-D FSDP x TP), the KV pool (head-sharded over
        ``tp_axis``) and the compiled steps over this mesh. Host-side
        scheduling is unchanged; tokens are mesh-independent.
    tp_axis : str
        Tensor-parallel mesh axis name.
    spec_tokens : int
        Speculative decoding: draft tokens proposed per request per
        step (K); the verify step scores K+1 positions in one pass.
        0 disables (see launch/engine/speculative.py).
    drafter : {"ngram", "draft_model"}
        Speculative proposal source: zero-parameter prompt lookup, or
        a small draft model passed via ``draft_model``/``draft_params``.
    ngram_max : int
        Longest history suffix the ngram drafter keys on.
    draft_model, draft_params
        The draft ``Model`` (attention-only, same vocab) and params for
        ``drafter="draft_model"``.
    kv_dtype : {"bf16", "int8", "fp8"}
        Storage precision of the paged K/V block pool. ``"bf16"`` keeps
        the historical full-precision pool (bit-identical outputs).
        ``"int8"``/``"fp8"`` (float8_e4m3) store quantized payloads
        with per-(token, kv-head) f32 scale leaves alongside in the
        pool tree; dequant is fused into the decode/verify kernels, so
        no full-precision copy of the pool is ever materialized.
        Requires ``ServingCaps.quantized_kv`` and the paged backend.
    overlap : bool
        Async host/device overlap on the paged backend: ``step()``
        dispatches the NEXT decode (feeding the in-flight sampled
        tokens device-to-device) *before* blocking on the previous
        step's token fetch, so host-side scheduling/admission work
        hides under device compute. Outputs are bit-identical with it
        on or off (the RNG-stream contract — the overlapped dispatch
        changes when work runs, never what is computed). Requires the
        paged backend and ``spec_tokens == 0``.
    """

    backend: str = "paged"       # "paged" | "static"
    num_slots: int = 8           # decode batch width
    block_size: int = 16         # paged: tokens per cache block
    num_blocks: int = 512        # paged: pool size (block 0 reserved)
    max_len: int = 256           # per-sequence position cap
    eos_id: int = -1             # -1: length-based retirement only
    watermark_blocks: int = 0    # paged: admission headroom (see alloc)
    bucketed_prefill: bool = True  # pow-2 prompt buckets (when exact)
    # Batched prefill admission: each paged admission drains up to this
    # many queued requests sharing one prefill bucket and prefills them
    # in a single right-padded batch call (one jit trace per (bucket,
    # batch-bucket) pair); the static backend bounds its lockstep batch
    # width with it. <= 0 (default) lifts the cap to the slot count.
    max_prefill_batch: int = 0
    # Copy-on-write prefix caching (paged backend): share block-aligned
    # cached prompt prefixes across requests via refcounts, prefill only
    # the non-shared suffix, keep unreferenced indexed blocks in an LRU
    # reclaimed before exhaustion. Silently inactive for models with
    # per-slot decode state (rings/SSM) or cross-attention — see
    # ServingCaps.prefix_cache.
    prefix_cache: bool = True
    # Mesh-sharded serving: when a jax.sharding.Mesh is given, the
    # backend shards params (2-D FSDP x TP rules of launch/sharding.py),
    # the KV block pools (head-sharded over ``tp_axis`` — each device
    # owns its kv-head shard of every block; block tables and lengths
    # stay replicated host state) and per-slot caches, and compiles the
    # prefill/decode steps against NamedSharding so device placement is
    # stable across steps. Host-side scheduling is unchanged.
    mesh: Any = None             # jax.sharding.Mesh | None
    tp_axis: str = "model"       # tensor-parallel mesh axis name
    # Speculative decoding (paged backend only): each scheduled request
    # proposes up to ``spec_tokens`` draft tokens per step and the
    # target model verifies the whole window in ONE batched pass
    # (engine/speculative.py). 0 disables. ``drafter`` picks the
    # proposal source: "ngram" (zero-extra-params prompt lookup) or
    # "draft_model" (a small model passed via draft_model/draft_params,
    # sharing the target's tokenizer/config machinery).
    spec_tokens: int = 0
    drafter: str = "ngram"       # "ngram" | "draft_model"
    ngram_max: int = 3           # longest suffix the ngram drafter keys on
    draft_model: Any = None      # Model (drafter="draft_model")
    draft_params: Any = None     # its params
    # Paged KV pool storage precision: "bf16" (full precision,
    # bit-identical), "int8" or "fp8" (float8_e4m3 payloads +
    # per-(token, kv-head) scale leaves, dequant fused into the kernels).
    kv_dtype: str = "bf16"       # "bf16" | "int8" | "fp8"
    # Async host/device overlap (paged backend): dispatch decode N+1
    # before fetching decode N's sampled tokens (double-buffered token
    # fetch; admission prefills are ordered after the in-flight decode
    # by the functional pool data dependency). Bit-identical outputs.
    overlap: bool = False


class Engine:
    """Single serving front-end over pluggable execution backends.

    The Engine owns request validation and the step loop; the backend
    owns device state and scheduling (admission, growth, preemption,
    retirement). Three workload classes share the one stack: dense
    decoder-only text LMs, MoE LMs (expert-sharded decode under a
    mesh), and encoder-decoder models whose requests carry encoder
    features (``Request.encoder_features`` -> per-slot cross-KV arena).

    Parameters
    ----------
    model : Model
        The target model; configs without a paged decode path
        (``ServingCaps.paged_decode`` — e.g. qwen2-vl's mrope/visual
        prefix frontend) raise NotImplementedError.
    params
        Its parameter tree (placed onto ``cfg.mesh`` when sharded).
    cfg : EngineConfig, optional
        Backend selection and geometry; defaults to ``EngineConfig()``.
    ctx : RunCtx, optional
        Kernel/sharding context; defaults to the jnp reference kernels.

    Attributes
    ----------
    backend : PagedBackend | SpecDecodeBackend | StaticBackend
        The execution backend selected by ``cfg``.
    finished : list of RequestHandle
        Handles retired so far, in completion order.

    Notes
    -----
    Every token is *emitted the step it is sampled* (prefill included),
    so ``step()`` doubles as the streaming interface. Outputs obey the
    RNG-stream contract (see ``SamplingParams.seed`` and
    docs/serving.md): they do not depend on admission order, slot
    placement, co-batched traffic, preemption, sharding, replica
    placement, or speculative decoding.

    Invariants the tests rely on: the FCFS queue head is never
    overtaken (admission drains a queue *prefix*); zero block leaks —
    every pool block returns to the allocator on retirement,
    preemption, and speculative rejected-tail rewind (double-frees
    raise); both backends compile the same power-of-two prefill bucket
    set (``prefill_bucket``), keeping the jit cache at
    O(buckets x batch-buckets).

    Examples
    --------
    >>> engine = Engine(model, params, EngineConfig(backend="paged"))
    >>> handle = engine.add_request(prompt, SamplingParams(max_tokens=8))
    >>> while engine.has_work:
    ...     for out in engine.step():
    ...         print(out.request_id, out.new_tokens)
    """

    def __init__(self, model: Model, params, cfg: EngineConfig = None,
                 ctx: Optional[RunCtx] = None):
        from repro.launch.engine.scheduler import PagedBackend
        from repro.launch.engine.static import StaticBackend

        self.cfg = cfg or EngineConfig()
        self.model = model
        mc = model.cfg
        self.caps = model.serving_caps()
        if not self.caps.paged_decode:
            raise NotImplementedError(
                f"no paged decode path for config {mc.family}/{mc.name}: "
                "mrope / visual-prefix frontends (qwen2-vl) and "
                "decoder-only absolute-position embeddings are not "
                "served (ServingCaps.paged_decode)")
        if self.caps.cross_attn and self.cfg.backend == "static":
            raise ValueError(
                "encoder-decoder serving needs the paged backend "
                "(the cross-KV arena lives in the paged pool); use "
                "backend='paged'")
        if self.caps.cross_attn and self.cfg.spec_tokens > 0:
            raise ValueError(
                "speculative decoding is decoder-only: the verify pass "
                "has no cross-attention path; set spec_tokens=0 for "
                f"{mc.family}/{mc.name}")
        from repro.models.paged_kv import KV_DTYPES
        if self.cfg.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {self.cfg.kv_dtype!r}; expected one "
                f"of {KV_DTYPES}")
        if self.cfg.kv_dtype != "bf16":
            if self.cfg.backend == "static":
                raise ValueError(
                    "quantized KV (kv_dtype="
                    f"{self.cfg.kv_dtype!r}) requires the paged backend "
                    "— the static baseline keeps dense full-precision "
                    "caches; use backend='paged'")
            if not self.caps.quantized_kv:
                raise ValueError(
                    f"config {mc.family}/{mc.name} does not support a "
                    f"quantized paged KV pool (kv_dtype="
                    f"{self.cfg.kv_dtype!r}): ServingCaps.quantized_kv "
                    "is False — encoder-decoder cross-KV arenas and "
                    "non-paged frontends stay bf16")
        ctx = ctx or RunCtx(kernel_mode="ref")
        if self.cfg.mesh is not None and ctx.shard is None:
            from repro.launch.sharding import make_shard_ctx
            from repro.models.paged_kv import head_shard_ok

            shard = make_shard_ctx(self.cfg.mesh,
                                   tp_axis=self.cfg.tp_axis)
            ctx = dataclasses.replace(
                ctx, shard=shard,
                decode_head_shard=head_shard_ok(mc, shard.tp_size))
        # Expert-sharded decode: shard_map the MoE FFN over the model
        # axis when the widths divide (decode/verify run at num_slots
        # width; the scheduler drops back to GSPMD for pow-2 prefill
        # buckets, which need not divide dp — see PagedBackend).
        if (self.caps.moe and ctx.shard is not None
                and self.cfg.backend == "paged"
                and mc.n_experts % ctx.shard.tp_size == 0
                and self.cfg.num_slots % ctx.shard.dp_size == 0):
            ctx = dataclasses.replace(ctx, moe_sharded=True)
        if self.cfg.overlap:
            if self.cfg.backend != "paged":
                raise ValueError(
                    "overlap=True requires the paged backend — the "
                    "static baseline fetches lockstep; use "
                    "backend='paged'")
            if self.cfg.spec_tokens > 0:
                raise ValueError(
                    "overlap=True is incompatible with speculative "
                    "decoding: the verify step consumes the sampled "
                    "tokens on the host before the next dispatch; set "
                    "spec_tokens=0")
        if self.cfg.backend == "paged":
            if self.cfg.spec_tokens > 0:
                from repro.launch.engine.speculative import SpecDecodeBackend
                self.backend = SpecDecodeBackend(model, params, self.cfg,
                                                 ctx)
            else:
                self.backend = PagedBackend(model, params, self.cfg, ctx)
        elif self.cfg.backend == "static":
            if self.cfg.spec_tokens > 0:
                raise ValueError(
                    "speculative decoding requires the paged backend")
            self.backend = StaticBackend(model, params, self.cfg, ctx)
        else:
            raise ValueError(f"unknown backend {self.cfg.backend!r}")
        self._uid = 0

    # -- request lifecycle ----------------------------------------------

    def check_request(self, prompt: Sequence[int],
                      sampling: SamplingParams,
                      encoder_features=None):
        """Raise ValueError when this engine could never serve the
        request (empty prompt, position cap, backend capacity bound,
        encoder features absent/present against the config's declared
        ``ServingCaps.cross_attn``). Shared by ``add_request`` and the
        ReplicaSet front-end, which validates once against a
        representative replica before the request enters the shared
        queue."""
        mc = self.model.cfg
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if len(prompt) + sampling.max_tokens > self.cfg.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_tokens "
                f"({sampling.max_tokens}) exceeds max_len "
                f"{self.cfg.max_len}")
        if encoder_features is not None and not self.caps.cross_attn:
            raise ValueError(
                f"encoder features on a non-encoder-decoder config: "
                f"{mc.family}/{mc.name} has no cross-attention "
                f"(enc_dec=False) — drop Request.encoder_features, or "
                f"serve an enc-dec config (e.g. whisper)")
        if self.caps.cross_attn:
            if encoder_features is None:
                raise ValueError(
                    f"encoder-decoder config {mc.family}/{mc.name} "
                    f"needs Request.encoder_features (a "
                    f"(frames, {mc.d_model}) array — whisper mel-conv "
                    f"frames per input_specs); bare prompts are "
                    f"decoder-only")
            shape = getattr(encoder_features, "shape", None)
            if shape is None or len(shape) != 2 or shape[1] != mc.d_model:
                raise ValueError(
                    f"encoder_features must be a (frames, d_model="
                    f"{mc.d_model}) array, got shape {shape}")
            if not 1 <= shape[0] <= mc.encoder_len:
                raise ValueError(
                    f"encoder_features frames ({shape[0]}) outside "
                    f"[1, encoder_len={mc.encoder_len}] for "
                    f"{mc.family}/{mc.name}")
        check = getattr(self.backend, "check_request", None)
        if check is not None:            # paged: worst-case pool bound
            check(len(prompt), sampling)

    def add_request(self, prompt,
                    sampling: Optional[SamplingParams] = None,
                    encoder_features=None) -> RequestHandle:
        """Validate and enqueue one request; returns its live handle.
        ``prompt`` is a token-id sequence or a ``Request`` (the latter
        carries sampling and encoder features itself)."""
        if isinstance(prompt, Request):
            if sampling is not None or encoder_features is not None:
                raise ValueError("pass sampling/encoder_features inside "
                                 "the Request, not alongside it")
            sampling = prompt.sampling
            encoder_features = prompt.encoder_features
            prompt = prompt.prompt
        sampling = sampling or SamplingParams()
        prompt = list(prompt)
        self.check_request(prompt, sampling, encoder_features)
        handle = RequestHandle(self._uid, prompt, sampling,
                               encoder_features=encoder_features)
        self._uid += 1
        self.backend.enqueue(handle)
        return handle

    def step(self) -> list[RequestOutput]:
        """Admissions + one device step; streams per-request increments."""
        return self.backend.step()

    @property
    def has_work(self) -> bool:
        """True while any request is waiting or active."""
        return self.backend.has_work

    @property
    def finished(self) -> list[RequestHandle]:
        """Handles retired so far, in completion order."""
        return self.backend.finished

    def stats(self) -> dict:
        """Backend telemetry: occupancy, cache utilization, preemption
        and prefill-compile counters — plus a ``"spec"`` section
        (aggregate and per-request draft counters) when speculative
        decoding is on, and a ``"latency"`` section (TTFT/TPOT
        p50/p95/p99 over finished and in-flight requests, see
        ``latency_stats``). docs/benchmarks.md documents the derived
        bench fields."""
        st = self.backend.stats()
        live = getattr(self.backend, "live_handles", None)
        handles = list(self.backend.finished)
        if live is not None:
            handles += live()
        st["latency"] = latency_stats(handles)
        return st

    @property
    def made_progress(self) -> bool:
        """True when the last ``step()`` admitted, decoded or preempted
        (the stall detector in ``drive`` keys on it)."""
        return self.backend.made_progress

    # -- convenience drivers --------------------------------------------

    def drain(self, max_steps: int = 100_000) -> list[RequestOutput]:
        """Step until idle; returns the concatenated output stream."""
        return drive(self, max_steps,
                     "engine stalled: waiting requests cannot be admitted")

    def generate(self, prompts: Sequence[Sequence[int]],
                 sampling=None, max_steps: int = 100_000,
                 encoder_features=None) -> list[list[int]]:
        """Submit ``prompts`` and drive to completion; returns token ids
        per prompt in submission order. ``sampling`` is one
        SamplingParams for all or a per-prompt sequence;
        ``encoder_features`` a per-prompt sequence of feature arrays
        for enc-dec models (entries may repeat to share arena rows)."""
        return run_generate(self, prompts, sampling, max_steps,
                            encoder_features=encoder_features)


def drive(engine, max_steps: int, stall_msg: str) -> list[RequestOutput]:
    """Drive-to-completion loop shared by every Engine-shaped front-end
    (Engine, ReplicaSet): step until idle, guard the step budget, raise
    on a stall (a step that neither emitted nor progressed)."""
    stream: list[RequestOutput] = []
    steps = 0
    while engine.has_work:
        outs = engine.step()
        stream.extend(outs)
        steps += 1
        if steps > max_steps:
            raise RuntimeError("step budget exceeded")
        if not outs and not engine.made_progress:
            raise RuntimeError(stall_msg)
    return stream


def run_generate(engine, prompts, sampling, max_steps,
                 encoder_features=None) -> list[list[int]]:
    """Shared ``generate`` driver: broadcast/validate sampling params,
    submit everything, drain, collect per-prompt tokens in order."""
    if sampling is None or isinstance(sampling, SamplingParams):
        sampling = [sampling or SamplingParams()] * len(prompts)
    if len(sampling) != len(prompts):
        raise ValueError(f"{len(sampling)} sampling params for "
                         f"{len(prompts)} prompts")
    if encoder_features is None:
        encoder_features = [None] * len(prompts)
    if len(encoder_features) != len(prompts):
        raise ValueError(f"{len(encoder_features)} encoder features for "
                         f"{len(prompts)} prompts")
    handles = [engine.add_request(p, s, encoder_features=f)
               for p, s, f in zip(prompts, sampling, encoder_features)]
    engine.drain(max_steps=max_steps)
    return [list(h.token_ids) for h in handles]
