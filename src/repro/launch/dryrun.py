import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE two lines above must run before any jax import (jax locks the device
count at first init) — hence the unusual module layout.

For each cell this proves the distribution config is coherent end-to-end:
  * the production mesh builds ((16,16) single-pod / (2,16,16) multi-pod),
  * param/opt/batch/cache shardings fit the mesh (divisibility-checked),
  * jit(step).lower(**ShapeDtypeStructs).compile() succeeds under SPMD,
  * memory_analysis / cost_analysis / the collective schedule are recorded
    to JSON for EXPERIMENTS.md §Dry-run and roofline/analysis.py.

Step lowered per cell kind:  train -> train_step (fwd+bwd+optimizer),
prefill -> prefill_step (logits + cache), decode/long -> serve_step
(1 token against a seq_len cache).

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b \
      --cell train_4k --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs import ARCH_IDS, LM_SHAPES, get_cell, get_config
from repro.launch import sharding as shlib
from repro.launch.mesh import make_production_mesh, mesh_summary
from repro.launch.params import active_param_count, total_param_count
from repro.launch.train import init_state, make_train_step, state_specs
from repro.models.model import Model, input_specs
from repro.models.transformer import RunCtx
from repro.optim import OptConfig
from repro.optim.schedule import constant
from repro.roofline import analysis as ra
from repro.roofline.hw import V5E


def default_opt_for(cfg) -> OptConfig:
    """Baseline optimizer per arch. kimi-k2 (1T params) trains with
    factored bf16 state + Kahan bf16 params — full f32 Adam at 512 v5e
    chips is arithmetically impossible (12 TB state vs 8 TB HBM) and
    would be dishonest as a 'fitting' baseline."""
    if cfg.name.startswith("kimi"):
        # kahan=False: the bf16 compensation buffer would double the 8 GB
        # per-device param footprint; at 1T params the fit wins.
        return OptConfig(kind="adafactor", state_dtype="bfloat16",
                         kahan=False, norm_tile="vec")
    return OptConfig(kind="adamw", state_dtype="float32")


def build_lowerable(cfg, cell, mesh, remat="full", kernel_mode="ref",
                    unroll=False, knobs=None):
    """-> (jitted fn, tuple of ShapeDtypeStruct args) for one cell.

    ``knobs`` (optional dict) selects §Perf variants: layout ('2d'|'fsdp'),
    ce_chunk (int), moe_mode ('gather'|'partial'), decode_seq_shard (bool),
    grad_accum (int).
    """
    knobs = knobs or {}
    model = Model(cfg)
    shard = shlib.make_shard_ctx(
        mesh, layout=knobs.get("layout", "2d"),
        cache_seq_shard=knobs.get("decode_seq_shard", False))
    ctx = RunCtx(kernel_mode=kernel_mode,
                 remat=remat if cell.kind == "train" else "none",
                 shard=shard, moe_sharded=cfg.is_moe,
                 scan_unroll=unroll,
                 ce_chunk=knobs.get("ce_chunk", 0),
                 moe_mode=knobs.get("moe_mode", "gather"),
                 decode_seq_shard=knobs.get("decode_seq_shard", False),
                 residual_spec=knobs.get("residual_spec", "none"))
    specs = input_specs(cfg, cell)
    params_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = shlib.param_specs(params_shapes, shard)

    if cell.kind == "train":
        opt_cfg = default_opt_for(cfg)
        if knobs.get("grad_accum"):
            opt_cfg = dataclasses.replace(
                opt_cfg, grad_accum=knobs["grad_accum"],
                accum_dtype=knobs.get("accum_dtype", "float32"))
        step = make_train_step(model, opt_cfg, ctx,
                               functools.partial(constant, peak_lr=1e-4))
        state_shapes = jax.eval_shape(
            lambda: init_state(model, opt_cfg))
        sspecs = state_specs(state_shapes, shard)
        bspecs = shlib.batch_specs(specs, shard)
        metric_shapes = jax.eval_shape(step, state_shapes, specs)[1]
        mspecs = jax.tree.map(lambda _: jax.sharding.PartitionSpec(),
                              metric_shapes)
        # out_shardings pin the new state to the same FSDP x TP layout —
        # without this GSPMD replicates grads/params on the way out
        # (observed: 33 GB all-reduce instead of reduce-scatter).
        fn = jax.jit(step,
                     in_shardings=(shlib.named(mesh, sspecs),
                                   shlib.named(mesh, bspecs)),
                     out_shardings=(shlib.named(mesh, sspecs),
                                    shlib.named(mesh, mspecs)),
                     donate_argnums=(0,))
        return fn, (state_shapes, specs)

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            # Serving prefill: sampler needs only the last position's
            # logits (XLA DCEs the full (B, S, V) logits einsum).
            logits, cache = model.prefill(params, batch, ctx,
                                          max_len=cell.seq_len)
            return logits[:, -1], cache
        bspecs = shlib.batch_specs(specs, shard)
        out_shapes = jax.eval_shape(prefill_step, params_shapes, specs)
        logits_spec = shlib.batch_specs({"tokens": out_shapes[0]}, shard)[
            "tokens"]
        cache_spec = shlib.batch_specs(out_shapes[1], shard)
        fn = jax.jit(prefill_step,
                     in_shardings=(shlib.named(mesh, pspecs),
                                   shlib.named(mesh, bspecs)),
                     out_shardings=(shlib.named(mesh, logits_spec),
                                    shlib.named(mesh, cache_spec)))
        return fn, (params_shapes, specs)

    # decode / long: one token against a seq_len-deep cache
    cache_shapes = specs.pop("cache")
    tokens = specs.pop("tokens")
    pos = specs.pop("pos")
    mrope = specs.pop("mrope_positions", None)

    def serve_step(params, cache, tokens, pos, mrope_positions=None):
        return model.decode_step(params, cache, tokens, pos, ctx,
                                 mrope_positions=mrope_positions)

    cspecs = shlib.batch_specs(cache_shapes, shard)
    tspecs = shlib.batch_specs({"tokens": tokens}, shard)["tokens"]
    args = [params_shapes, cache_shapes, tokens, pos]
    inshard = [shlib.named(mesh, pspecs), shlib.named(mesh, cspecs),
               shlib.named(mesh, tspecs),
               shlib.named(mesh, shlib.batch_specs({"pos": pos}, shard)["pos"])]
    if mrope is not None:
        args.append(mrope)
        inshard.append(shlib.named(
            mesh, shlib.batch_specs({"mrope_positions": mrope}, shard)[
                "mrope_positions"]))
    out_shapes = jax.eval_shape(serve_step, *args)
    logits_spec = shlib.batch_specs({"tokens": out_shapes[0]}, shard)[
        "tokens"]
    fn = jax.jit(serve_step, in_shardings=tuple(inshard),
                 out_shardings=(shlib.named(mesh, logits_spec),
                                shlib.named(mesh, cspecs)),
                 donate_argnums=(1,))
    return fn, tuple(args)


def _cell_costs(cfg, cell, mesh, n_dev, pod_size, remat,
                build=None):
    """Compile one depth variant UNROLLED; return (flops, bytes, colls).

    XLA cost_analysis ignores while-loop trip counts, so the shallow cost
    variants unroll every layer/chunk scan — their bodies then appear as
    inline HLO and are counted exactly. (The sLSTM time-step loop stays a
    loop; its in-loop R-matmul is <3% of an xLSTM layer — noted in
    EXPERIMENTS.md §Roofline.)
    """
    build = build or build_lowerable
    with mesh:
        fn, args = build(cfg, cell, mesh, remat=remat, unroll=True)
        compiled = fn.lower(*args).compile()
        cost = compat.cost_analysis(compiled)
        hlo = compiled.as_text()
    coll = ra.parse_collectives(hlo, pod_size=pod_size, n_devices=n_dev)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def depth_corrected_costs(cfg, cell, mesh, n_dev, pod_size, remat,
                          build=None):
    """XLA cost_analysis counts a scan body ONCE regardless of trip count
    (verified empirically), so layer-scanned models are undercounted. Fit
    f(depth) = a + b*depth from two shallow variants (1 and 2 pattern
    periods) and extrapolate to the true depth. Linear in depth holds for
    flops, bytes and wire-bytes alike (stacked params scale with L too).
    Remainder layers (depth % period) are credited pro-rata.
    """
    p = len(cfg.block_pattern)
    units = cfg.n_layers // p
    rem = cfg.n_layers % p
    enc_per_unit = (cfg.n_encoder_layers // max(units, 1)
                    if cfg.enc_dec else 0)

    def variant(s):
        return dataclasses.replace(
            cfg, n_layers=s * p,
            n_encoder_layers=s * enc_per_unit if cfg.enc_dec else 0)

    f1, b1, c1 = _cell_costs(variant(1), cell, mesh, n_dev, pod_size, remat,
                             build)
    f2, b2, c2 = _cell_costs(variant(2), cell, mesh, n_dev, pod_size, remat,
                             build)
    scale = units + rem / p

    def fit(v1, v2):
        # a + b*s with slope clamped non-negative: XLA occasionally fuses
        # the depth-2 variant harder than depth-1, producing a slightly
        # negative slope that would extrapolate to nonsense at s=61.
        b = max(v2 - v1, 0.0)
        a = max(v1 - b, 0.0)
        return a + b * scale
    flops = fit(f1, f2)
    nbytes = fit(b1, b2)
    wire = {k: fit(c1.wire_bytes.get(k, 0.0), c2.wire_bytes.get(k, 0.0))
            for k in set(c1.wire_bytes) | set(c2.wire_bytes)}
    pod_wire = fit(c1.pod_wire_bytes, c2.pod_wire_bytes)
    coll = ra.CollectiveStats(
        ops=c2.ops,
        operand_bytes={k: fit(c1.operand_bytes.get(k, 0.0),
                              c2.operand_bytes.get(k, 0.0))
                       for k in set(c1.operand_bytes) | set(c2.operand_bytes)},
        wire_bytes=wire, pod_wire_bytes=max(pod_wire, 0.0),
        total_operand_bytes=float(sum(
            max(v, 0.0) for v in (fit(c1.operand_bytes.get(k, 0.0),
                                      c2.operand_bytes.get(k, 0.0))
                                  for k in set(c1.operand_bytes)
                                  | set(c2.operand_bytes)))),
        total_wire_bytes=float(sum(max(v, 0.0) for v in wire.values())))
    return max(flops, 0.0), max(nbytes, 0.0), coll


def run_cell(arch: str, cell_name: str, multi_pod: bool, remat="full",
             build=build_lowerable, cost_scale: float = 1.0):
    """``cost_scale`` multiplies fitted flops/bytes/wire — required for
    grad-accum variants whose microbatch scan body XLA counts once."""
    cfg = get_config(arch)
    cell = get_cell(cell_name)
    if cell_name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch, "cell": cell_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "pure full-attention arch; long_500k requires "
                          "sub-quadratic mixing (DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    pod_size = 256 if multi_pod else None
    t0 = time.time()
    # Full-depth compile: THE dry-run gate (memory fit + compilability).
    with mesh:
        fn, args = build(cfg, cell, mesh, remat=remat)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)                      # proves it fits (spec step 3)
        print({k: v for k, v in compat.cost_analysis(compiled).items()
               if k in ("flops", "bytes accessed")})
    # Depth-corrected roofline inputs (scan trip-count fix).
    flops_dev, bytes_dev, coll = depth_corrected_costs(
        cfg, cell, mesh, n_dev, pod_size, remat, build)
    if cost_scale != 1.0:
        flops_dev *= cost_scale
        bytes_dev *= cost_scale
        coll = ra.CollectiveStats(
            ops=coll.ops,
            operand_bytes={k: v * cost_scale
                           for k, v in coll.operand_bytes.items()},
            wire_bytes={k: v * cost_scale
                        for k, v in coll.wire_bytes.items()},
            pod_wire_bytes=coll.pod_wire_bytes * cost_scale,
            total_operand_bytes=coll.total_operand_bytes * cost_scale,
            total_wire_bytes=coll.total_wire_bytes * cost_scale)
    terms = ra.roofline_terms(flops_dev, bytes_dev, coll)
    mf = ra.model_flops(cfg, cell)
    hlo_flops_global = flops_dev * n_dev
    result = {
        "arch": arch, "cell": cell_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": mesh_summary(mesh),
        "status": "ok",
        "seconds": {"lower": round(t_lower, 1), "compile": round(t_compile, 1)},
        "params_total": total_param_count(cfg),
        "params_active": active_param_count(cfg),
        "memory_per_device": None if mem is None else {
            "arguments_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "total_bytes": int(mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes
                               + mem.output_size_in_bytes),
            "fits_16GB": bool(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + mem.output_size_in_bytes < V5E.hbm_bytes),
        },
        "cost_per_device": {"flops": flops_dev, "bytes_accessed": bytes_dev},
        "collectives": {
            "ops": coll.ops,
            "operand_bytes": {k: int(v) for k, v in coll.operand_bytes.items()},
            "wire_bytes": {k: int(v) for k, v in coll.wire_bytes.items()},
            "pod_wire_bytes": int(coll.pod_wire_bytes),
            "total_wire_bytes": int(coll.total_wire_bytes),
        },
        "roofline": terms,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (mf / hlo_flops_global
                               if hlo_flops_global else None),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(ARCH_IDS))
    ap.add_argument("--cell", nargs="*",
                    default=[c.name for c in LM_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="full")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in args.arch:
        for cell in args.cell:
            for multi in meshes:
                tag = f"{arch}_{cell}_{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip-cached] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    res = run_cell(arch, cell, multi, remat=args.remat)
                except Exception as e:
                    failures += 1
                    res = {"arch": arch, "cell": cell,
                           "mesh": "multi" if multi else "single",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" dominant={r['dominant']} "
                             f"frac={r['roofline_fraction']:.3f}"
                             f" ({time.time()-t0:.0f}s)")
                print(f"[{status}] {tag}{extra}", flush=True)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
