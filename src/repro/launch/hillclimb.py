import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver — hypothesis -> change -> measure -> validate.

Each variant is a knob set over the SAME model/cell (launch/dryrun.py
build_lowerable knobs); results land in experiments/perf/ as JSON with
the hypothesis text attached, and EXPERIMENTS.md §Perf is written from
them. Baselines (knobs={}) are the paper-faithful configuration.

Run: PYTHONPATH=src python -m repro.launch.hillclimb [--only kimi ...]
"""

import argparse
import functools
import json
import time
import traceback

from repro.launch import dryrun as dr

# (cell-tag, arch, cell, variant-name, knobs, hypothesis)
PLAN = [
    # ---- gemma_7b x train_4k: dense-train representative --------------
    ("gemma_train", "gemma_7b", "train_4k", "v1_fsdp",
     {"layout": "fsdp"},
     "H1: baseline is activation-AR bound (346 GB wire/dev, dominated by "
     "Megatron-TP all-reduces that scale with B_loc*S*d per layer). Pure "
     "FSDP over all 256 chips replaces them with weight gathers: "
     "~3x params bf16 = ~51 GB -> expect collective term ~7x down."),
    ("gemma_train", "gemma_7b", "train_4k", "v2_fsdp_cechunk",
     {"layout": "fsdp", "ce_chunk": 512},
     "H2: with d-sharded embeddings the CE logits psum materializes "
     "(B_loc, S, 256k) f32; chunking CE over 512-token slices keeps the "
     "same wire but cuts peak temp by ~8x on the logits buffer."),
    ("gemma_train", "gemma_7b", "train_4k", "v3_bf16_residual",
     {"residual_spec": "batch"},
     "H3 (after H1 refuted): the probe shows the dominant AR is "
     "f32[16,4096,3072] — GSPMD delays the row-parallel reduce into the "
     "next norm's f32 upcast. Constraining the residual stream after "
     "every block forces the reduce in bf16: expect activation AR wire "
     "~2x down and the f32 activation temps to shrink."),
    ("gemma_train", "gemma_7b", "train_4k", "v5_bf16_inblock",
     {"residual_spec": "batch", "ce_chunk": 512},
     "H5 (after v3 near-null): v3 constrained only BETWEEN blocks, so "
     "the attn-out AR still delayed into ln2's f32 upcast inside the "
     "block. Constraining after EVERY residual add (attn and ffn) plus "
     "chunked CE should finally halve the f32 AR wire."),
    ("gemma_train", "gemma_7b", "train_4k", "v4_sp_cechunk",
     {"residual_spec": "seq", "ce_chunk": 512},
     "H4: Megatron-SP — residuals sequence-sharded over tp between "
     "blocks (RS+AG schedule, same bytes as bf16-AR) divides residual "
     "memory by 16 and chunked CE removes the 13 GB logits buffer: "
     "expect fits_16GB to flip with collective term ~= v3."),
    # ---- kimi_k2 x train_4k: most collective-bound + MoE story ---------
    ("kimi_train", "kimi_k2_1t_a32b", "train_4k", "v1_partial",
     {"moe_mode": "partial"},
     "H1: expert-weight FSDP gathers move ~6.3 GB/layer/dev while the "
     "activation partial sums they replace are ~0.8 GB/layer: 'partial' "
     "contraction should cut MoE traffic ~5x (the EPAC uncore lesson: "
     "move the smaller operand through the NoC)."),
    ("kimi_train", "kimi_k2_1t_a32b", "train_4k", "v2_partial_accum",
     {"moe_mode": "partial", "grad_accum": 8},
     "H2: 61 x 940 MB activation residuals (57 GB) are the memory-fit "
     "blocker; 8-way microbatching divides residual memory by 8 at "
     "unchanged total wire (cost_scale=8 corrects the accum-scan count) "
     "-> expect fits_16GB to flip with terms ~= v1."),
    ("kimi_train", "kimi_k2_1t_a32b", "train_4k", "v3_partial_accum_bf16",
     {"moe_mode": "partial", "grad_accum": 8, "residual_spec": "batch"},
     "H3: with MoE traffic fixed, the attention-side activation ARs in "
     "f32 remain (same delayed-reduce pathology as gemma); bf16 residual "
     "constraints should cut the remaining AR wire up to ~2x."),
    ("kimi_train", "kimi_k2_1t_a32b", "train_4k", "v4_accum16_bf16acc",
     {"moe_mode": "partial", "grad_accum": 16, "accum_dtype": "bfloat16"},
     "H4: after v2, temp is dominated by the f32 microbatch grad "
     "accumulators (~16 GB = 1.03T params f32 / 256 chips) plus "
     "transients; bf16 accumulators halve that and accum=16 further "
     "shrinks per-microbatch activation transients -> expect temp "
     "~63 -> ~35 GB (still over 16 GB: kimi-k2 train at 4k x 256 batch "
     "honestly needs >= 1024 v5e chips; record the gap)."),
    # ---- yi_6b x decode_32k: worst-fraction family ----------------------
    ("yi_decode", "yi_6b", "decode_32k", "v1_flashdecode",
     {"decode_seq_shard": True},
     "H1: kv=4 heads don't divide |tp|=16, so the baseline replicates the "
     "cache over tp and GSPMD all-gathers ~37 GB/step. Sequence-sharding "
     "the cache + LSE combine moves only (max,num,den) partials: expect "
     "collective term ~100x down and memory term ~16x (each device scans "
     "1/16th of the cache)."),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="cell-tags to run (gemma_train kimi_train yi_decode)")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for tag, arch, cell, vname, knobs, hypothesis in PLAN:
        if args.only and tag not in args.only:
            continue
        path = os.path.join(args.out, f"{tag}_{vname}.json")
        if os.path.exists(path):
            print(f"[cached] {tag}/{vname}")
            continue
        print(f"[perf] {tag}/{vname}: {hypothesis[:80]}...", flush=True)
        t0 = time.time()
        try:
            build = functools.partial(dr.build_lowerable, knobs=knobs)
            res = dr.run_cell(arch, cell, multi_pod=False, build=build,
                              cost_scale=float(knobs.get("grad_accum", 1)))
            res["variant"] = vname
            res["knobs"] = knobs
            res["hypothesis"] = hypothesis
        except Exception as e:
            res = {"variant": vname, "arch": arch, "cell": cell,
                   "status": "error", "knobs": knobs,
                   "hypothesis": hypothesis,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if res["status"] == "ok":
            r = res["roofline"]
            print(f"[done] {tag}/{vname} comp={r['compute_s']:.3f} "
                  f"mem={r['memory_s']:.3f} coll={r['collective_s']:.3f} "
                  f"frac={r['roofline_fraction']:.3f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        else:
            print(f"[FAIL] {tag}/{vname}: {res.get('error', '')[:200]}",
                  flush=True)


if __name__ == "__main__":
    main()
