"""Elastic scaling: replan the mesh on node count change, reshard state.

Contract for 1000+ node runs:
  * checkpoints hold full logical arrays (checkpoint/checkpoint.py), so a
    restore onto ANY mesh just device_puts with the new shardings;
  * the TP (model) extent is preserved across replans — it is baked into
    per-layer math efficiency — and the DP extent absorbs node loss/gain;
  * data order is preserved by the deterministic pipeline: batch(step) is
    identity-stable, only the shard slicing changes with dp size.

``replan_mesh`` handles the failure arithmetic (e.g. 512 - 16 dead = 496
-> largest (pod, data, model) grid with model=16 that fits: 31x16 over
one merged dp axis).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.launch.mesh import make_mesh


@dataclasses.dataclass(frozen=True)
class Replan:
    shape: tuple
    axes: tuple
    dropped_devices: int


def replan_mesh(n_devices: int, tp: int = 16, prefer_pods: int | None = None
                ) -> Replan:
    """Largest usable (dp, tp) grid with fixed tp from n_devices."""
    assert n_devices >= tp, (n_devices, tp)
    dp = n_devices // tp
    used = dp * tp
    if prefer_pods and dp % prefer_pods == 0:
        return Replan((prefer_pods, dp // prefer_pods, tp),
                      ("pod", "data", "model"), n_devices - used)
    return Replan((dp, tp), ("data", "model"), n_devices - used)


def build_replanned_mesh(plan: Replan):
    return make_mesh(plan.shape, plan.axes)


def reshard_state(state, new_specs_named):
    """Move a (restored or live) state pytree onto new shardings."""
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s),
                        state, new_specs_named)


def survivors_after_failure(n_devices: int, failed: int, tp: int = 16
                            ) -> Replan:
    """Failure arithmetic: drop failed nodes, replan the DP extent."""
    return replan_mesh(n_devices - failed, tp=tp)
