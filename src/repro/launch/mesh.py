"""Mesh construction — the uncore fabric, as functions (never module
state: importing this must not touch jax device initialization).

Production target (TPU v5e):
  single-pod  (16, 16)    axes (data, model)          = 256 chips
  multi-pod   (2, 16, 16) axes (pod, data, model)     = 512 chips
The ``pod`` axis is the EPAC C2C analogue: slower tier, carries only
data-parallel (all-reduce-friendly) traffic.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh over available devices (tests, small runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh(tp: int = 1):
    """Mesh over whatever devices exist locally: (data = n/tp, model = tp).

    Raises ValueError (not a bare assert) on a tp that is < 1 or does
    not divide the local device count — every ``--tp`` CLI funnels here.
    """
    n = len(jax.devices())
    if tp < 1 or n % tp != 0:
        raise ValueError(
            f"--tp {tp} must be >= 1 and divide the local device count "
            f"({n}); fake devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def dp_axes_of(mesh) -> tuple:
    """All non-model axes, in mesh order (pod first if present)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def replica_cli_mesh(dp: int, tp: int):
    """The mesh a ``--dp R --tp T`` CLI request means: exactly R x T
    devices as a (data=R, model=T) mesh, so each replica owns a (1, T)
    TP subgrid — the topology the README table documents and the bench
    measures. ``--tp T`` alone keeps the PR-3 behavior (shard ONE
    engine over ALL local devices, data = n/T). No parallelism
    requested, or dp replicas on a too-small host (tp == 1), returns
    None: plain single-device engines."""
    n = len(jax.devices())
    if dp > 1:
        if n >= dp * tp:
            return make_mesh((dp, tp), ("data", "model"))
        if tp > 1:
            raise ValueError(
                f"--dp {dp} --tp {tp} needs {dp * tp} devices, have {n}; "
                "fake devices with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N")
        return None                      # host too small: plain replicas
    if tp > 1:
        return make_local_mesh(tp)
    return None


def submeshes(mesh, dp: int, axis: str = "data") -> list:
    """Split ``mesh`` into ``dp`` contiguous submeshes along ``axis``.

    Each submesh keeps ALL axis names (the split axis shrinks to
    size/dp), so the 2-D FSDP x TP sharding rules apply unchanged per
    replica: replica r serves the r-th slice of the data axis with its
    own model-axis TP subgrid — the ReplicaSet analogue of EPAC handing
    each tile its own L2 slice behind the shared hub. Raises ValueError
    when ``dp`` does not divide the axis (every ``--dp`` CLI funnels
    here)."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
    size = int(mesh.shape[axis])
    if dp < 1 or size % dp != 0:
        raise ValueError(
            f"--dp {dp} must be >= 1 and divide the {axis!r} axis "
            f"({size}); fake devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ai = list(mesh.axis_names).index(axis)
    per = size // dp
    out = []
    for r in range(dp):
        sl = [slice(None)] * mesh.devices.ndim
        sl[ai] = slice(r * per, (r + 1) * per)
        out.append(Mesh(mesh.devices[tuple(sl)], mesh.axis_names))
    return out


def mesh_summary(mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names,
                             [int(s) for s in mesh.devices.shape])),
            "n_devices": int(np.prod(mesh.devices.shape))}
