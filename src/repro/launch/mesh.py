"""Mesh construction — the uncore fabric, as functions (never module
state: importing this must not touch jax device initialization).

Production target (TPU v5e):
  single-pod  (16, 16)    axes (data, model)          = 256 chips
  multi-pod   (2, 16, 16) axes (pod, data, model)     = 512 chips
The ``pod`` axis is the EPAC C2C analogue: slower tier, carries only
data-parallel (all-reduce-friendly) traffic.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh over available devices (tests, small runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh(tp: int = 1):
    """Mesh over whatever devices exist locally: (data = n/tp, model = tp).

    Raises ValueError (not a bare assert) on a tp that is < 1 or does
    not divide the local device count — every ``--tp`` CLI funnels here.
    """
    n = len(jax.devices())
    if tp < 1 or n % tp != 0:
        raise ValueError(
            f"--tp {tp} must be >= 1 and divide the local device count "
            f"({n}); fake devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def dp_axes_of(mesh) -> tuple:
    """All non-model axes, in mesh order (pod first if present)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_summary(mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names,
                             [int(s) for s in mesh.devices.shape])),
            "n_devices": int(np.prod(mesh.devices.shape))}
