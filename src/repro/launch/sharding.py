"""Sharding layout rules — the tensor-level analogue of EPAC's
"programmable address interleaving" across distributed L2 slices.

Layout policy (2-D FSDP x TP, the baseline recorded in §Roofline):
  * column-parallel weights (wq/wk/wv, w_up/w_gate, ...):  (d -> dp, out -> tp)
  * row-parallel weights    (wo, w_down, w_out):           (in -> tp, d -> dp)
  * expert weights:  E -> tp (EP), d -> dp (FSDP)
  * embeddings:      vocab -> tp, d -> dp;  lm_head: (d -> dp, vocab -> tp)
  * norms/gains:     replicated
Every rule is divisibility-checked against the mesh — a dim that does not
divide its axis is left unsharded (never an error), so the same rules
serve all 10 architectures (e.g. kv_heads < |model| falls back cleanly).

``ShardCtx`` is the static handle threaded into model code (MoE shard_map
needs mesh + axis names).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes_of


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Any                                  # jax.sharding.Mesh
    dp_axes: tuple                             # ("pod", "data") | ("data",)
    tp_axis: str = "model"
    # '2d'   — FSDP over dp_axes x TP over tp_axis (Megatron-style).
    # 'fsdp' — pure FSDP over ALL axes; no tensor parallelism. §Perf
    #          result: dense <=7B models at 256 chips are activation-AR
    #          bound under '2d'; 'fsdp' trades that for weight gathers.
    layout: str = "2d"
    # decode caches: shard kv-sequence over tp (flash-decoding combine)
    # instead of kv-heads (which rarely divide |tp|).
    cache_seq_shard: bool = False

    def __hash__(self):  # Mesh isn't hashable by content across rebuilds
        return hash((self.dp_axes, self.tp_axis, self.layout,
                     self.cache_seq_shard,
                     tuple(self.mesh.axis_names),
                     tuple(int(s) for s in self.mesh.devices.shape)))

    def __eq__(self, other):
        return isinstance(other, ShardCtx) and hash(self) == hash(other)

    @property
    def all_axes(self) -> tuple:
        return tuple(self.mesh.axis_names)

    @property
    def batch_axes(self) -> tuple:
        """Axes the batch dim is sharded over."""
        return self.all_axes if self.layout == "fsdp" else self.dp_axes

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])


def make_shard_ctx(mesh, layout: str = "2d",
                   cache_seq_shard: bool = False,
                   tp_axis: str = "model") -> ShardCtx:
    dp = tuple(a for a in mesh.axis_names if a != tp_axis)
    return ShardCtx(mesh=mesh, dp_axes=dp, tp_axis=tp_axis, layout=layout,
                    cache_seq_shard=cache_seq_shard)


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fit(spec_dims, shape, mesh):
    """Drop sharding on dims that don't divide their mesh axes."""
    out = []
    for dim, axis in zip(shape, spec_dims):
        if axis is None:
            out.append(None)
        elif dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# Rules keyed by the *leaf name* (last path key); dims are right-aligned
# so stacked (L, ...) variants share the rule.
def _param_rule(name: str, ndim: int, shard: ShardCtx):
    if shard.layout == "fsdp":
        return _param_rule_fsdp(name, ndim, shard)
    dp, tp = shard.dp_axes, shard.tp_axis
    col = (dp, tp)            # (..., d_in -> dp, d_out -> tp)
    row = (tp, dp)            # (..., d_in -> tp, d_out -> dp)
    table = {
        "embed": (tp, dp),
        "lm_head": (dp, tp),
        "wq": col, "wk": col, "wv": col, "w_up": col, "w_gate": col,
        "w_x": col, "w_a": col, "w_i": col, "w_zifo": col, "w_if": col,
        "wo": row, "w_down": row, "w_out": row,
        "router": (dp, None),
        "w1": (tp, dp, None), "w3": (tp, dp, None),   # experts (E, d, ff)
        "w2": (tp, None, dp),                          # experts (E, ff, d)
    }
    dims = table.get(name)
    if dims is None:
        return None  # replicate (norms, biases, conv, lam, r_zifo, ...)
    pad = (None,) * (ndim - len(dims))
    return pad + tuple(dims)


def _param_rule_fsdp(name: str, ndim: int, shard: ShardCtx):
    """Pure-FSDP layout: every weight sharded over ALL mesh axes on its
    input dim, gathered on use by GSPMD; embeddings sharded on d so the
    token gather stays local (no vocab-parallelism needed)."""
    ax = shard.all_axes
    table = {
        "embed": (None, ax),               # (V, d -> all)
        "lm_head": (ax, None),             # (d -> all, V)
        "wq": (ax, None), "wk": (ax, None), "wv": (ax, None),
        "w_up": (ax, None), "w_gate": (ax, None),
        "w_x": (ax, None), "w_a": (ax, None), "w_i": (ax, None),
        "w_zifo": (ax, None), "w_if": (ax, None),
        "wo": (ax, None), "w_down": (ax, None), "w_out": (ax, None),
        "router": (ax, None),
        "w1": (None, ax, None), "w3": (None, ax, None),
        "w2": (None, ax, None),
    }
    dims = table.get(name)
    if dims is None:
        return None
    pad = (None,) * (ndim - len(dims))
    return pad + tuple(dims)


def param_specs(params, shard: ShardCtx):
    """Pytree of PartitionSpecs for a param pytree (divisibility-checked)."""
    def spec_of(path, leaf):
        # Leaf name = last path key ('wq' under .../attn/, 'w1' under moe/).
        name = getattr(path[-1], "key", None)
        dims = _param_rule(name, leaf.ndim, shard)
        if dims is None:
            return P()
        return _fit(dims, leaf.shape, shard.mesh)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def _batch_rule(path, leaf, shard: ShardCtx):
    """Spec for one batch-like leaf (shared by ``batch_specs`` and the
    paged-cache spec builder)."""
    dp = shard.batch_axes
    tp = shard.tp_axis

    # Cache layout: batch over DP; the head/state-width dim over TP
    # (kv-heads for attention caches, heads for mLSTM/sLSTM state, the
    # recurrent width for RG-LRU). _fit drops TP when not divisible
    # (e.g. kv=8 < |model|=16), which is the honest fallback recorded
    # in §Roofline.
    if shard.cache_seq_shard:
        kv_rule = (None, dp, tp, None, None)  # (L, B, S -> tp, Hkv, hd)
    else:
        kv_rule = (None, dp, None, tp, None)  # (L, B, S, Hkv -> tp, hd)
    cache_rules = {
        "k": kv_rule,
        "v": kv_rule,
        "C": (None, dp, tp, None, None),      # (L, B, H, hd, hd)
        "n": (None, dp, tp, None),            # (L, B, H, hd)
        "m": (None, dp, tp),                  # (L, B, H)
        "h": (None, dp, tp),                  # rglru (L, B, dr) / slstm 4D
        "c": (None, dp, tp, None),            # slstm (L, B, H, hd)
        "conv": (None, dp, None, tp),         # (L, B, w-1, d)
    }

    last = getattr(path[-1], "key", "")
    nd = len(leaf.shape)
    if last in ("tokens", "targets"):
        return _fit((dp, None), leaf.shape, shard.mesh)
    if last in ("frames", "visual_embeds"):
        return _fit((dp, None, None), leaf.shape, shard.mesh)
    if last == "mrope_positions":
        return _fit((None, dp, None), leaf.shape, shard.mesh)
    if last == "pos" or nd == 0:
        return P()
    if last in cache_rules:
        dims = cache_rules[last]
        ancestors = {getattr(p, "key", None) for p in path[:-1]}
        if last in ("h", "m") and nd == 4:   # slstm h/m: (L, B, H, hd)
            dims = (None, dp, tp, None)
        if last in ("k", "v") and "cross" in ancestors:
            dims = (None, dp, tp, None, None)  # (L, B, Hkv, Senc, hd)
        elif last in ("k", "v") and nd == 4:  # unstacked (B, S, Hkv, hd)
            dims = (dp, None, tp, None)
        dims = dims[:nd] if len(dims) >= nd else dims + (None,) * (
            nd - len(dims))
        return _fit(dims, leaf.shape, shard.mesh)
    # generic batch-like: (L, B, ...) -> B over dp
    if nd >= 2:
        return _fit((None, dp) + (None,) * (nd - 2), leaf.shape,
                    shard.mesh)
    return P()


def batch_specs(batch, shard: ShardCtx):
    """Shard batch-like inputs over the batch axes on their batch dim."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _batch_rule(path, leaf, shard), batch)


def paged_pool_spec(leaf, shard: ShardCtx):
    """HEAD-sharded layout for one full-attention block-pool leaf
    (L, NB, BS, Hkv, D): every device owns its kv-head shard of EVERY
    physical block, replicated over the data axes, so block tables and
    lengths stay replicated host integers and a sequence's blocks never
    migrate as it grows (the ``decode_seq_shard`` idea applied to the
    pool — EPAC's interleaved L2 slices, sliced by head instead of
    address). ``_fit`` drops the head sharding when Hkv does not divide
    |tp|. The caller (``transformer.paged_cache_specs``) selects pool
    leaves BY LAYER KIND, never by shape, so ring buffers can never be
    misclassified."""
    return _fit((None, None, None, shard.tp_axis, None), leaf.shape,
                shard.mesh)


def opt_state_specs(pspecs, opt_state_shapes, shard: ShardCtx):
    """Optimizer state mirrors param sharding; scalars replicated."""
    def mirror(path, leaf):
        # walk: state['m']/<param path...>  -> look up param spec by subpath
        keys = [getattr(p, "key", None) for p in path]
        if keys and keys[0] in ("m", "v", "comp", "fac"):
            sub = pspecs
            try:
                for k in keys[1:]:
                    if k in ("row", "col", "v"):
                        # factored stats drop trailing dims
                        base = sub
                        spec = tuple(base)
                        if k == "row":
                            return P(*spec[:-1]) if len(spec) else P()
                        if k == "col":
                            return P(*(spec[:-2] + spec[-1:])) if len(spec) >= 2 else P()
                        return base
                    sub = sub[k]
                return sub
            except (KeyError, TypeError):
                return P()
        return P()

    return jax.tree_util.tree_map_with_path(mirror, opt_state_shapes)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def place_params(params, shard: ShardCtx):
    """Commit a param tree to its NamedShardings (the layout rules
    above). One-time placement; jit then reads the committed shardings."""
    return jax.device_put(
        params, named(shard.mesh, param_specs(params, shard)))


def replicated(shard: ShardCtx):
    """The fully-replicated NamedSharding on this mesh."""
    return NamedSharding(shard.mesh, P())


def jit_step(fn, shard: Optional[ShardCtx], state_shardings, *,
             donate=()):
    """jit a ``(logits, device_state)``-returning serving step. Under a
    mesh, pin the outputs — logits replicated (they are fetched to host
    every step anyway), state on its NamedShardings — so device
    placement is stable step-to-step and state donation stays exact.
    Without a mesh this is a plain jit. ONE helper so every backend
    step site stays on the same placement policy."""
    if shard is None:
        return jax.jit(fn, donate_argnums=donate)
    return jax.jit(fn, donate_argnums=donate,
                   out_shardings=(replicated(shard), state_shardings))


def constrain(x, shard: Optional[ShardCtx], *dims):
    """with_sharding_constraint helper that no-ops without a mesh."""
    if shard is None:
        return x
    spec = _fit(dims, x.shape, shard.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(shard.mesh, spec))
