"""Pipeline parallelism (GPipe-style) over a named ``pipe`` mesh axis.

Each pipeline device holds ONE stage's parameters (stage = contiguous run
of layers, stacked on a leading axis and sharded over ``pipe``). The
schedule runs M + S - 1 ticks; at every tick each device applies its stage
to the microbatch in flight and collective-permutes activations to the
next stage — the EPAC analogy is the NoC's credit-based point-to-point
channels (collective-permute IS the point-to-point primitive).

This axis composes with the DP/TP meshes: a production layout would be
(pipe, data, model). The dry-run matrix keeps the assigned 2-D/3-D meshes,
so PP ships as a tested feature (tests/test_pipeline.py) rather than a
dry-run default — recorded in DESIGN.md.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import pvary, shard_map


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, mesh,
                   axis: str = "pipe"):
    """Run microbatches through S pipeline stages.

    stage_fn:     (params_slice, activation) -> activation (one stage).
    stage_params: pytree with leading dim S (sharded over ``axis``).
    x_micro:      (M, B_micro, ...) microbatches, replicated over ``axis``.
    Returns (M, B_micro, ...) outputs of the LAST stage.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]

    def local(params_l, xs):
        # params_l: (1, ...) my stage's params; xs: (M, B, ...) replicated
        me = jax.lax.axis_index(axis)
        p_mine = jax.tree.map(lambda t: t[0], params_l)
        n_ticks = M + S - 1

        def tick(carry, t):
            inflight, outputs = carry
            # which microbatch is at my stage this tick (GPipe diagonal)
            mb = t - me
            active = jnp.logical_and(mb >= 0, mb < M)
            # stage 0 injects from xs; others consume the permuted input
            inj = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(mb, 0, M - 1), axis=0, keepdims=False)
            x_in = jnp.where(me == 0, inj, inflight)
            y = stage_fn(p_mine, x_in)
            y = jnp.where(active, y, inflight)
            # last stage records finished microbatches
            outputs = jnp.where(
                jnp.logical_and(me == S - 1, active),
                jax.lax.dynamic_update_index_in_dim(
                    outputs, y, jnp.clip(mb, 0, M - 1), axis=0),
                outputs)
            # hand activations to the next stage (ring permute; the wrap
            # edge S-1 -> 0 carries garbage that stage 0 ignores)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outputs), None

        # pvary: the carry becomes device-varying after the first tick
        # (jax >= 0.8 checks manual-axis variance of scan carries;
        # identity on older jax — see core/compat.py)
        zero = pvary(jnp.zeros_like(xs[0]), axis)
        outs0 = pvary(jnp.zeros_like(xs), axis)
        (_, outputs), _ = jax.lax.scan(
            tick, (zero, outs0), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        outputs = jnp.where(me == S - 1, outputs, jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, x_micro)


def stack_stages(layer_params_list, n_stages: int):
    """Group a list of per-layer param pytrees into S stacked stages."""
    L = len(layer_params_list)
    assert L % n_stages == 0, (L, n_stages)
    per = L // n_stages
    stages = []
    for s in range(n_stages):
        chunk = layer_params_list[s * per:(s + 1) * per]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *chunk))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stages)


def make_stage_fn(layer_fn: Callable):
    """Lift a single-layer fn into a stage fn over stacked layer params."""

    def stage_fn(stage_params, x):
        def body(xc, lp):
            return layer_fn(lp, xc), None

        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    return stage_fn
