"""Parameter accounting (analytic, via eval_shape — no allocation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import Model


def param_tree_shapes(cfg):
    m = Model(cfg)
    return jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))


def total_param_count(cfg) -> int:
    tree = param_tree_shapes(cfg)
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for s in leaf.shape:   # python ints — no int32 overflow at 1T params
            n *= int(s)
        total += n
    return total


def active_param_count(cfg) -> int:
    """Matmul-active params per token: excludes the embedding *gather*,
    includes the logits matmul, and counts only top_k/E of expert FFNs."""
    tree = param_tree_shapes(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    total = 0
    for path, leaf in flat:
        name = getattr(path[-1], "key", "")
        n = 1
        for s in leaf.shape:
            n *= int(s)
        if name == "embed" and not cfg.tie_embeddings:
            continue  # pure gather; logits use lm_head
        if name in ("w1", "w2", "w3") and cfg.is_moe:
            n = int(n * cfg.moe_top_k / cfg.n_experts)
        total += n
    return total
