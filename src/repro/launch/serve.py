"""Serving CLI + deprecated PR-1 shims. The engine moved to
``repro.launch.engine`` — one ``Engine`` front-end
(``add_request``/``step``/``generate``) over the ``paged`` (continuous
batching, optimistic admission + preemption, bucketed prefill) and
``static`` (lockstep) backends. Import from there for new code:

    from repro.launch.engine import Engine, EngineConfig, SamplingParams

This module keeps the old entry points alive through one deprecation
cycle:

* ``Server`` / ``ServeConfig``   -> Engine(backend="static"). The old
  left-pad-and-attend-the-pads prefill is gone; ragged prompts now match
  the unbatched reference exactly.
* ``Scheduler`` / ``SchedulerConfig`` -> Engine(backend="paged") with
  ``submit``/``run``/``stats`` adapters (request handles still expose
  ``.out``/``.done``).

Run: PYTHONPATH=src python -m repro.launch.serve --arch olmo_1b --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.models.model import Model
from repro.models.transformer import RunCtx


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 8
    max_len: int = 256


class Server:
    """DEPRECATED: thin adapter over Engine(backend="static").

    Narrower than the PR-1 Server in one way only: decoder-only text LMs
    (enc-dec raises NotImplementedError from the Engine). ``mesh=`` is
    wired through again — the Engine backends now shard params/caches
    over the mesh natively (EngineConfig.mesh), so the PR-1 call shape
    ``Server(model, params, cfg, mesh=mesh)`` works and emits a
    DeprecationWarning pointing at the Engine API."""

    def __init__(self, model: Model, params, serve_cfg: ServeConfig,
                 ctx: Optional[RunCtx] = None, mesh=None):
        if mesh is not None:
            import warnings

            warnings.warn(
                "Server(mesh=...) is deprecated; use "
                "Engine(model, params, EngineConfig(mesh=...)) — the "
                "backends shard natively now", DeprecationWarning,
                stacklevel=2)
        self.engine = Engine(model, params,
                             EngineConfig(backend="static",
                                          num_slots=serve_cfg.batch_size,
                                          max_len=serve_cfg.max_len,
                                          mesh=mesh),
                             ctx=ctx)

    def generate(self, prompts: list[list[int]], n_new: int,
                 greedy: bool = True, seed: int = 0):
        # per-row derived seeds: requests sharing a SamplingParams.seed
        # share an RNG stream by design (identical prompts would sample
        # identically); the old Server drew independent per-row noise,
        # so the shim preserves that
        sps = [SamplingParams(max_tokens=n_new,
                              temperature=0.0 if greedy else 1.0,
                              seed=seed * 100_003 + i)
               for i in range(len(prompts))]
        return self.engine.generate(prompts, sps)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    num_slots: int = 8
    block_size: int = 16
    num_blocks: int = 512
    max_len: int = 256
    eos_id: int = -1
    greedy: bool = True
    seed: int = 0


class Scheduler:
    """DEPRECATED: thin adapter over Engine(backend="paged")."""

    def __init__(self, model: Model, params, cfg: SchedulerConfig,
                 ctx: Optional[RunCtx] = None):
        self.cfg = cfg
        self._n_submitted = 0
        self.engine = Engine(model, params,
                             EngineConfig(backend="paged",
                                          num_slots=cfg.num_slots,
                                          block_size=cfg.block_size,
                                          num_blocks=cfg.num_blocks,
                                          max_len=cfg.max_len,
                                          eos_id=cfg.eos_id),
                             ctx=ctx)

    def submit(self, prompt: list[int], max_new: int):
        # per-request derived seeds, as in Server.generate: the PR-1
        # Scheduler drew independent noise per request, so sharing one
        # stream (identical prompts -> identical samples) would be a
        # silent semantics change for non-greedy callers
        seed = self.cfg.seed * 100_003 + self._n_submitted
        self._n_submitted += 1
        sp = SamplingParams(
            max_tokens=max_new,
            temperature=0.0 if self.cfg.greedy else 1.0,
            seed=seed)
        return self.engine.add_request(prompt, sp)

    def step(self):
        return self.engine.step()

    def run(self, max_steps: int = 100_000):
        self.engine.drain(max_steps=max_steps)
        return self.engine.finished

    def stats(self) -> dict:
        return self.engine.stats()

    @property
    def finished(self):
        return self.engine.finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", choices=("static", "paged"),
                    default="paged")
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard the backend over "
                         "a (data, model) mesh of the local devices")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(args.tp)
    engine = Engine(model, params,
                    EngineConfig(backend=args.backend,
                                 num_slots=args.slots, max_len=128,
                                 mesh=mesh))
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 16))))
               for _ in range(args.requests)]
    sp = [SamplingParams(max_tokens=int(rng.integers(4, args.n_new + 1)),
                         temperature=args.temperature, seed=i)
          for i in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, sp)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"[{args.backend}] {total} tokens over {len(outs)} reqs "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)  stats={engine.stats()}")
    for i, o in enumerate(outs[:2]):
        print(f"req{i}: {o[:12]}...")


if __name__ == "__main__":
    main()
