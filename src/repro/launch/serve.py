"""Serving CLI. The engine lives in ``repro.launch.engine`` — one
``Engine`` front-end (``add_request``/``step``/``generate``) over the
``paged`` (continuous batching, optimistic admission + preemption,
batched bucketed prefill) and ``static`` (lockstep) backends, and a
``ReplicaSet`` that runs R data-parallel engine replicas behind one
shared admission queue. Import from there:

    from repro.launch.engine import Engine, EngineConfig, SamplingParams
    from repro.launch.engine import ReplicaSet

The PR-1 ``Server``/``Scheduler`` adapters finished their deprecation
cycle in PR 4 and are gone; this module is now only the CLI.

Run: PYTHONPATH=src python -m repro.launch.serve --arch olmo_1b --smoke
     PYTHONPATH=src python -m repro.launch.serve --dp 2 --tp 2  # mesh
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.engine import (DisaggregatedEngine, Engine, EngineConfig,
                                 ReplicaSet, SamplingParams)
from repro.launch.mesh import replica_cli_mesh
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", choices=("static", "paged"),
                    default="paged")
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard each engine over "
                         "a (data, model) mesh of the local devices")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replicas behind one shared "
                         "admission queue (ReplicaSet); each replica "
                         "gets its own KV pool and TP subgrid")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative decoding: ngram-drafted tokens "
                         "per step (paged backend; bit-identical "
                         "outputs)")
    ap.add_argument("--roles", default=None,
                    help="prefill/decode disaggregation over the dp "
                         "replicas: comma-separated roles (e.g. "
                         "'prefill,decode') or 'auto'; requires dp >= 2 "
                         "and the paged backend (bit-identical outputs, "
                         "KV blocks migrate between pools)")
    ap.add_argument("--kv-dtype", choices=("bf16", "int8", "fp8"),
                    default="bf16",
                    help="paged KV pool storage precision: int8/fp8 "
                         "store quantized blocks + per-(token, head) "
                         "scales with dequant fused into the kernels "
                         "(paged backend only)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mesh = replica_cli_mesh(args.dp, args.tp)
    ecfg = EngineConfig(backend=args.backend, num_slots=args.slots,
                        max_len=128, spec_tokens=args.spec_tokens,
                        kv_dtype=args.kv_dtype)
    if args.roles is not None:
        roles = args.roles if args.roles == "auto" \
            else tuple(args.roles.split(","))
        engine = DisaggregatedEngine(model, params, ecfg, dp=args.dp,
                                     mesh=mesh, roles=roles)
    elif args.dp > 1:
        engine = ReplicaSet(model, params, ecfg, dp=args.dp, mesh=mesh)
    else:
        engine = Engine(model, params,
                        dataclasses.replace(ecfg, mesh=mesh))
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 16))))
               for _ in range(args.requests)]
    sp = [SamplingParams(max_tokens=int(rng.integers(4, args.n_new + 1)),
                         temperature=args.temperature, seed=i)
          for i in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, sp)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"[{args.backend} dp={args.dp}] {total} tokens over "
          f"{len(outs)} reqs in {dt:.2f}s ({total / dt:.1f} tok/s)  "
          f"stats={engine.stats()}")
    for i, o in enumerate(outs[:2]):
        print(f"req{i}: {o[:12]}...")


if __name__ == "__main__":
    main()
