"""Serving driver: batched prefill + decode (host-device mode).

EPAC's dual execution model: accelerators serve offloaded work from a
host *or* run standalone. launch/train.py is the standalone mode; this is
the host-device mode — a host-side batcher packs requests (VLA strip-mine
padding, core/vec.py discipline) and drives jit'd prefill/serve steps.

Run: PYTHONPATH=src python -m repro.launch.serve --arch olmo_1b --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as shlib
from repro.models.model import Model
from repro.models.transformer import RunCtx


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 8
    max_len: int = 256


class Server:
    def __init__(self, model: Model, params, serve_cfg: ServeConfig,
                 ctx: Optional[RunCtx] = None, mesh=None):
        self.model = model
        self.cfg = model.cfg
        self.serve_cfg = serve_cfg
        self.ctx = ctx or RunCtx(kernel_mode="ref")
        self.params = params
        ml = serve_cfg.max_len

        def prefill_step(params, batch):
            return model.prefill(params, batch, self.ctx, max_len=ml)

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos, self.ctx)

        if mesh is not None:
            shard = shlib.make_shard_ctx(mesh)
            pspecs = shlib.named(mesh, shlib.param_specs(
                jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
                shard))
            self.params = jax.device_put(params, pspecs)
            self.prefill_step = jax.jit(prefill_step)
            self.serve_step = jax.jit(serve_step, donate_argnums=(1,))
        else:
            self.prefill_step = jax.jit(prefill_step)
            self.serve_step = jax.jit(serve_step, donate_argnums=(1,))

    def generate(self, prompts: list[list[int]], n_new: int,
                 greedy: bool = True, seed: int = 0):
        """Pack ragged prompts into one batch; decode n_new tokens each."""
        B = self.serve_cfg.batch_size
        assert len(prompts) <= B
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p          # left-pad (aligned decode)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.enc_dec:
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_len, self.cfg.d_model), jnp.float32)
        logits, cache = self.prefill_step(self.params, batch)
        out = [[] for _ in range(B)]
        last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        key = jax.random.PRNGKey(seed)
        for t in range(n_new):
            tok = last[:, None]
            for i in range(len(prompts)):
                out[i].append(int(last[i]))
            logits_t, cache = self.serve_step(self.params, cache, tok,
                                              jnp.int32(plen + t))
            if greedy:
                last = jnp.argmax(logits_t, -1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                last = jax.random.categorical(sub, logits_t).astype(jnp.int32)
        return out[: len(prompts)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, ServeConfig(batch_size=args.batch,
                                               max_len=128))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, rng.integers(4, 16)))
               for _ in range(args.batch)]
    t0 = time.time()
    outs = server.generate(prompts, args.n_new)
    dt = time.time() - t0
    tps = args.batch * args.n_new / dt
    print(f"generated {args.n_new} tokens x {args.batch} reqs "
          f"in {dt:.2f}s ({tps:.1f} tok/s)")
    for i, o in enumerate(outs[:2]):
        print(f"req{i}: {o[:12]}...")


if __name__ == "__main__":
    main()
