"""Serving drivers: static batcher + continuous-batching engine.

EPAC's dual execution model: accelerators serve offloaded work from a
host *or* run standalone. launch/train.py is the standalone mode; this is
the host-device mode — the host packs offloaded work and drives jit'd
device steps.

Two engines live here:

* ``Server`` — the original static batcher: prefill a fixed batch, decode
  all sequences in lockstep. Simple, but finished/short requests keep
  burning cache memory and decode FLOPs until the longest one ends.
* ``Scheduler`` — continuous batching over a block-paged KV cache
  (models/paged_kv.py): a fixed set of decode *slots*, per-slot positions,
  EOS/length-based retirement that frees cache blocks immediately, and
  admission of waiting requests into freed slots mid-flight. The jit'd
  decode step is shape-stable — (B, 1) tokens, (B,) lengths, (B, NBMAX)
  block table — so continuous batching costs zero recompiles. Prefill
  runs per-admission at the request's exact prompt length (one compile
  per distinct length; callers wanting fewer compiles quantize prompt
  lengths themselves).

Admission policy: a request is admitted only if the pool can cover its
full worst-case footprint (prompt + max_new tokens). Conservative — no
preemption/swap path is needed, the engine cannot deadlock mid-sequence —
at the cost of some admission headroom. vLLM-style optimistic admission
with preemption is future work.

Run: PYTHONPATH=src python -m repro.launch.serve --arch olmo_1b --smoke
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as shlib
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 8
    max_len: int = 256


class Server:
    def __init__(self, model: Model, params, serve_cfg: ServeConfig,
                 ctx: Optional[RunCtx] = None, mesh=None):
        self.model = model
        self.cfg = model.cfg
        self.serve_cfg = serve_cfg
        self.ctx = ctx or RunCtx(kernel_mode="ref")
        self.params = params
        ml = serve_cfg.max_len

        def prefill_step(params, batch):
            return model.prefill(params, batch, self.ctx, max_len=ml)

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos, self.ctx)

        if mesh is not None:
            shard = shlib.make_shard_ctx(mesh)
            pspecs = shlib.named(mesh, shlib.param_specs(
                jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
                shard))
            self.params = jax.device_put(params, pspecs)
            self.prefill_step = jax.jit(prefill_step)
            self.serve_step = jax.jit(serve_step, donate_argnums=(1,))
        else:
            self.prefill_step = jax.jit(prefill_step)
            self.serve_step = jax.jit(serve_step, donate_argnums=(1,))

    def generate(self, prompts: list[list[int]], n_new: int,
                 greedy: bool = True, seed: int = 0):
        """Pack ragged prompts into one batch; decode n_new tokens each."""
        B = self.serve_cfg.batch_size
        assert len(prompts) <= B
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p          # left-pad (aligned decode)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.enc_dec:
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encoder_len, self.cfg.d_model), jnp.float32)
        logits, cache = self.prefill_step(self.params, batch)
        out = [[] for _ in range(B)]
        last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        key = jax.random.PRNGKey(seed)
        for t in range(n_new):
            tok = last[:, None]
            for i in range(len(prompts)):
                out[i].append(int(last[i]))
            logits_t, cache = self.serve_step(self.params, cache, tok,
                                              jnp.int32(plen + t))
            if greedy:
                last = jnp.argmax(logits_t, -1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                last = jax.random.categorical(sub, logits_t).astype(jnp.int32)
        return out[: len(prompts)]


# ---------------------------------------------------------------------------
# Continuous batching over the paged KV cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    num_slots: int = 8          # decode batch width
    block_size: int = 16        # tokens per cache block
    num_blocks: int = 512       # pool size (block 0 reserved)
    max_len: int = 256          # per-sequence position cap
    eos_id: int = -1            # -1: length-based retirement only
    greedy: bool = True
    seed: int = 0


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    blocks: list[int] = dataclasses.field(default_factory=list)
    last_token: int = 0
    reserve: int = 0       # worst-case blocks this request may ever hold


class Scheduler:
    """Continuous-batching serve engine over a paged KV cache.

    Host-side state (this object) owns the block allocator, the waiting
    queue and the numpy mirrors of the block table / lengths; device-side
    state is the paged pool pytree threaded through the jit'd step. One
    ``step()`` = admissions + one shape-stable decode step + retirements.
    """

    def __init__(self, model: Model, params, cfg: SchedulerConfig,
                 ctx: Optional[RunCtx] = None):
        mc = model.cfg
        if mc.enc_dec or mc.rope_style == "mrope" or mc.visual_prefix:
            raise NotImplementedError(
                "continuous batching targets decoder-only text LMs")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.ctx = ctx or RunCtx(kernel_mode="ref")
        self.layout = paged_kv.PagedLayout(
            num_slots=cfg.num_slots, num_blocks=cfg.num_blocks,
            block_size=cfg.block_size, max_len=cfg.max_len)
        self.alloc = paged_kv.BlockAllocator(self.layout)
        self.pools = model.init_paged_cache(self.layout)
        self.table = np.full(
            (cfg.num_slots, self.layout.max_blocks_per_seq),
            paged_kv.NULL_BLOCK, np.int32)
        self.lengths = np.zeros((cfg.num_slots,), np.int32)
        self.slots = [_Slot() for _ in range(cfg.num_slots)]
        self.waiting: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self._rng = np.random.default_rng(cfg.seed)
        self._uid = 0
        # telemetry for bench_serve
        self.steps = 0
        self.slot_steps = 0          # active slots summed over steps
        self.block_token_steps = 0   # allocated token capacity x steps
        self.live_token_steps = 0    # live tokens x steps

        def decode_fn(params, pools, table, lengths, tokens):
            return model.decode_step_paged(params, pools, table, lengths,
                                           tokens, self.ctx)

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._prefill_cache = {}

    # -- public API -----------------------------------------------------

    def submit(self, prompt: list[int], max_new: int) -> Request:
        assert len(prompt) >= 1 and max_new >= 1
        assert len(prompt) + max_new <= self.cfg.max_len, "request too long"
        assert paged_kv.blocks_for(len(prompt) + max_new,
                                   self.cfg.block_size) \
            <= self.layout.usable_blocks, "request exceeds pool capacity"
        req = Request(self._uid, list(prompt), max_new)
        self._uid += 1
        self.waiting.append(req)
        return req

    @property
    def num_active(self) -> int:
        return sum(s.req is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0

    def run(self, max_steps: int = 100_000) -> list[Request]:
        """Drive until every submitted request finished; return them."""
        while self.has_work:
            before = (self.steps, len(self.finished))
            self.step()
            # progress = a decode step ran, or an admission finished a
            # request outright (EOS straight out of prefill)
            if before == (self.steps, len(self.finished)) \
                    and self.num_active == 0:
                raise RuntimeError(
                    "scheduler stalled: waiting requests cannot be admitted")
            if self.steps > max_steps:
                raise RuntimeError("step budget exceeded")
        return self.finished

    def step(self):
        """Admissions, then one decode step over all slots, retirements."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return
        tokens = np.zeros((self.cfg.num_slots, 1), np.int32)
        for i in active:
            # grow into a fresh block when the next write crosses a
            # block boundary (admission reserved the worst case)
            L = int(self.lengths[i])
            if L % self.cfg.block_size == 0 and \
                    L // self.cfg.block_size >= len(self.slots[i].blocks):
                (nb,) = self.alloc.alloc(1)
                self.slots[i].blocks.append(nb)
                self.table[i, len(self.slots[i].blocks) - 1] = nb
            tokens[i, 0] = self.slots[i].last_token
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(self.table),
            jnp.asarray(self.lengths), jnp.asarray(tokens))
        logits = np.asarray(logits)
        self.steps += 1
        self.slot_steps += len(active)
        self.block_token_steps += self.alloc.used_count * self.cfg.block_size
        for i in active:
            slot = self.slots[i]
            req = slot.req
            req.out.append(slot.last_token)
            self.lengths[i] += 1
            self.live_token_steps += int(self.lengths[i])
            nxt = self._sample(logits[i])
            hit_eos = self.cfg.eos_id >= 0 and nxt == self.cfg.eos_id
            if len(req.out) >= req.max_new or hit_eos:
                self._retire(i)
            else:
                slot.last_token = nxt

    # -- internals ------------------------------------------------------

    def _sample(self, logits_row) -> int:
        if self.cfg.greedy:
            return int(np.argmax(logits_row))
        z = logits_row - logits_row.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    def _admit(self):
        while self.waiting:
            req = self.waiting[0]
            free_slots = [i for i, s in enumerate(self.slots)
                          if s.req is None]
            if not free_slots:
                return
            worst = paged_kv.blocks_for(len(req.prompt) + req.max_new,
                                        self.cfg.block_size)
            # blocks already promised to active sequences' future growth
            outstanding = sum(s.reserve - len(s.blocks) for s in self.slots
                              if s.req is not None)
            if self.alloc.free_count - outstanding < worst:
                return                      # FCFS: no skipping ahead
            self.waiting.popleft()
            self._place(free_slots[0], req)

    def _place(self, i: int, req: Request):
        S = len(req.prompt)
        nbp = paged_kv.blocks_for(S, self.cfg.block_size)
        block_ids = self.alloc.alloc(nbp)
        slot = self.slots[i]
        slot.req = req
        slot.blocks = block_ids
        slot.reserve = paged_kv.blocks_for(S + req.max_new,
                                           self.cfg.block_size)
        logits, self.pools = self._prefill(S)(
            self.params, self.pools,
            jnp.asarray([req.prompt], jnp.int32),
            jnp.asarray(block_ids, jnp.int32), jnp.int32(i))
        self.table[i, :] = paged_kv.NULL_BLOCK
        self.table[i, :nbp] = block_ids
        self.lengths[i] = S
        slot.last_token = self._sample(np.asarray(logits)[0, S - 1])
        # EOS straight out of prefill: retire with zero emitted tokens,
        # matching the mid-decode convention (EOS is stripped, not sent)
        if self.cfg.eos_id >= 0 and slot.last_token == self.cfg.eos_id:
            self._retire(i)

    def _prefill(self, S: int):
        """Exact-length prefill+pack, jit-cached per prompt length."""
        fn = self._prefill_cache.get(S)
        if fn is None:
            nbp = paged_kv.blocks_for(S, self.cfg.block_size)
            Sb = nbp * self.cfg.block_size
            model, layout, ctx = self.model, self.layout, self.ctx

            def prefill_fn(params, pools, tokens, block_ids, slot):
                logits, dense = model.prefill(params, {"tokens": tokens},
                                              ctx, max_len=Sb)
                pools = model.pack_prefill_into_paged(layout, pools, dense,
                                                      slot, block_ids)
                return logits, pools

            fn = jax.jit(prefill_fn, donate_argnums=(1,))
            self._prefill_cache[S] = fn
        return fn

    def _retire(self, i: int):
        slot = self.slots[i]
        slot.req.done = True
        self.finished.append(slot.req)
        self.alloc.free(slot.blocks)
        slot.blocks = []
        slot.req = None
        slot.last_token = 0
        slot.reserve = 0
        self.table[i, :] = paged_kv.NULL_BLOCK
        self.lengths[i] = 0

    # -- reporting ------------------------------------------------------

    def stats(self) -> dict:
        """Cache/occupancy telemetry averaged over the run so far."""
        cap = self.block_token_steps or 1
        return {
            "steps": self.steps,
            "mean_active_slots": self.slot_steps / max(self.steps, 1),
            "cache_utilization": self.live_token_steps / cap,
            "blocks_free": self.alloc.free_count,
            "blocks_used": self.alloc.used_count,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="continuous")
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if args.engine == "static":
        server = Server(model, params, ServeConfig(batch_size=args.batch,
                                                   max_len=128))
        prompts = [list(rng.integers(0, cfg.vocab_size, rng.integers(4, 16)))
                   for _ in range(args.batch)]
        t0 = time.time()
        outs = server.generate(prompts, args.n_new)
        dt = time.time() - t0
        tps = args.batch * args.n_new / dt
        print(f"[static] {args.n_new} tokens x {args.batch} reqs "
              f"in {dt:.2f}s ({tps:.1f} tok/s)")
        for i, o in enumerate(outs[:2]):
            print(f"req{i}: {o[:12]}...")
        return
    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=args.batch, max_len=128))
    for _ in range(args.requests):
        prompt = list(rng.integers(0, cfg.vocab_size,
                                   int(rng.integers(4, 16))))
        sched.submit(prompt, int(rng.integers(4, args.n_new + 1)))
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"[continuous] {total} tokens over {len(done)} reqs "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)  stats={sched.stats()}")
    for r in done[:2]:
        print(f"req{r.uid}: {r.out[:12]}...")


if __name__ == "__main__":
    main()
