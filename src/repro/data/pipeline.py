"""Data pipeline: deterministic, sharded, resumable token streams.

Fault-tolerance property: batch(step, shard) is a pure function of
(seed, step, shard), so any rank can reconstruct any batch — elastic
restarts and straggler-skip need no data-state checkpointing beyond the
step counter. (The EPAC analogue: the SDV flow's reproducible benchmark
harness — same inputs on every bring-up run.)

Two sources:
  * SyntheticLM  — threefry-derived tokens (markov-ish structure so loss
    actually decreases; used by examples + tests).
  * FileTokens   — memory-mapped flat .bin of token ids (production path).
Ragged tails are strip-mined VLA-style (core/vec.py): the final partial
batch is masked, never dropped and never a special case.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None       # None -> synthetic


class SyntheticLM:
    """Deterministic synthetic LM stream with learnable structure.

    Tokens follow x_{t+1} = (a * x_t + b) mod V with per-sequence (a, b)
    — trivially learnable, so quickstart loss curves are meaningful.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        cfg = self.cfg
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard]))
        a = rng.integers(1, 17, (local, 1))
        b = rng.integers(0, cfg.vocab_size, (local, 1))
        x0 = rng.integers(0, cfg.vocab_size, (local, 1))
        t = np.arange(cfg.seq_len + 1)
        # closed form of the affine recurrence mod V
        seq = (x0 * np.power.outer(np.ones(local, dtype=np.int64),
                                   t)).astype(np.int64)
        seqs = np.empty((local, cfg.seq_len + 1), np.int64)
        seqs[:, 0] = x0[:, 0]
        for i in range(1, cfg.seq_len + 1):
            seqs[:, i] = (a[:, 0] * seqs[:, i - 1] + b[:, 0]) % cfg.vocab_size
        return {"tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
                "targets": jnp.asarray(seqs[:, 1:], jnp.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileTokens:
    """Flat uint16/uint32 .bin of token ids, memory-mapped."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_tokens = len(self.data)

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        cfg = self.cfg
        local = cfg.global_batch // n_shards
        span = cfg.seq_len + 1
        per_step = cfg.global_batch * span
        base = (step * per_step + shard * local * span) % max(
            self.n_tokens - per_step, 1)
        rows = [np.asarray(self.data[base + i * span: base + (i + 1) * span],
                           np.int64) % cfg.vocab_size
                for i in range(local)]
        seqs = np.stack(rows)
        return {"tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
                "targets": jnp.asarray(seqs[:, 1:], jnp.int32)}


def make_source(cfg: DataConfig):
    return FileTokens(cfg) if cfg.path else SyntheticLM(cfg)
