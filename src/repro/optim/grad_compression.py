"""Gradient compression for the slow (pod / C2C-analogue) axis.

EPAC's C2C link is 25 GB/s against 64 GB/s NoC ports — cross-pod traffic
is the scarce resource, exactly as on multi-pod TPU (DCN/pod links vs
ICI). This module implements int8-quantized gradient all-reduce with
error feedback (residual carried locally so compression error does not
bias the descent direction), to be applied to the data-parallel gradient
sum over the ``pod`` axis only.

Usage is shard_map-based (manual DP): see launch/train.py
``make_compressed_dp_allreduce`` and tests/test_grad_compression.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_residual(g, residual):
    """Error feedback: quantize (g + residual), carry the new residual."""
    target = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    return q, scale, target - deq


def compressed_psum(g, residual, axis_name):
    """int8 all-reduce with error feedback over ``axis_name``.

    Inside shard_map: agree on ONE scale (pmax of local amax — summed
    int8 payloads are only meaningful under a shared scale), quantize the
    error-fed gradient with it, psum the int8 payload, dequantize. The
    modeling win is the 4x smaller wire payload on the pod (C2C) tier.
    """
    target = g.astype(jnp.float32) + residual
    amax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_name) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_residual = target - dequantize_int8(q, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return dequantize_int8(total, scale), new_residual, n
