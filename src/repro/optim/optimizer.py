"""Optimizers: AdamW and Adafactor-lite, with VRP compensated accumulation.

The VRP tie-in for training: at 1000-node scale, parameters are kept in
bf16 for memory/bandwidth and the *accumulation* p += lr*delta loses low
bits every step. EPAC's answer — dedicated extended-precision accumulation
hardware — becomes **Kahan-compensated parameter updates**: a bf16
compensation buffer per parameter recovers ~f32-master-quality updates at
half the optimizer-state memory (2+2 vs 4+... bytes). Enabled with
``kahan=True``; tests/test_optim.py shows bf16+Kahan tracks the f32 master
run where plain bf16 diverges.

All functions are pure pytree -> pytree; state mirrors param sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"              # adamw | adafactor
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # m/v dtype (bfloat16 halves memory)
    kahan: bool = False              # compensated parameter accumulation
    grad_accum: int = 1              # microbatch accumulation steps
    accum_dtype: str = "float32"     # microbatch grad accumulator dtype
    # 'vrp' computes the global grad norm with compensated reduction.
    norm_tile: str = "vec"


def init_opt_state(params, cfg: OptConfig):
    sd = jnp.dtype(cfg.state_dtype)
    zeros_like = lambda p: jnp.zeros(p.shape, sd)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "adamw":
        state["m"] = jax.tree.map(zeros_like, params)
        state["v"] = jax.tree.map(zeros_like, params)
    elif cfg.kind == "adafactor":
        def fact(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], sd),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], sd)}
            return {"v": jnp.zeros(p.shape, sd)}
        state["fac"] = jax.tree.map(fact, params)
    else:
        raise ValueError(cfg.kind)
    if cfg.kahan:
        state["comp"] = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return state


def global_norm(tree, tile: str = "vec"):
    """Global L2 norm; 'vrp' uses compensated (double-word) accumulation."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    if tile == "vrp":
        from repro.kernels import ops as kops
        total = kops.vrp_sum(jnp.stack(leaves))
        return jnp.sqrt(total[0] + total[1])
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float, tile: str = "vec"):
    norm = global_norm(grads, tile)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _kahan_add(p, delta, comp):
    """p + delta with compensation carried in ``comp`` (same dtype as p)."""
    pf = p.astype(jnp.float32)
    y = delta - comp.astype(jnp.float32)
    t = (pf + y).astype(p.dtype)
    new_comp = ((t.astype(jnp.float32) - pf) - y).astype(p.dtype)
    return t, new_comp


def apply_updates(params, grads, state, cfg: OptConfig, lr):
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip, cfg.norm_tile)
    step = state["step"] + 1
    new_state = {"step": step}
    sd = jnp.dtype(cfg.state_dtype)

    if cfg.kind == "adamw":
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            delta = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return -lr * delta, mf.astype(sd), vf.astype(sd)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        deltas = tdef.unflatten([o[0] for o in out])
        new_state["m"] = tdef.unflatten([o[1] for o in out])
        new_state["v"] = tdef.unflatten([o[2] for o in out])
    else:  # adafactor (factored second moment; memory ~ O(n+m) per matrix)
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** -0.8

        def upd_fac(p, g, f):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + 1e-30
            if p.ndim >= 2:
                row = beta2 * f["row"].astype(jnp.float32) + (1 - beta2) * jnp.mean(g2, -1)
                col = beta2 * f["col"].astype(jnp.float32) + (1 - beta2) * jnp.mean(g2, -2)
                rm = jnp.mean(row, -1, keepdims=True)
                vhat = (row / (rm + 1e-30))[..., None] * col[..., None, :]
                newf = {"row": row.astype(sd), "col": col.astype(sd)}
            else:
                vhat = beta2 * f["v"].astype(jnp.float32) + (1 - beta2) * g2
                newf = {"v": vhat.astype(sd)}
            delta = gf / (jnp.sqrt(vhat) + 1e-30)
            # update clipping (Adafactor's d=1.0 RMS rule)
            rms = jnp.sqrt(jnp.mean(delta * delta) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return -lr * delta, newf

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_f = tdef.flatten_up_to(state["fac"])
        out = [upd_fac(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
        deltas = tdef.unflatten([o[0] for o in out])
        new_state["fac"] = tdef.unflatten([o[1] for o in out])

    if cfg.kahan:
        pairs = jax.tree.map(_kahan_add, params, deltas, state["comp"])
        new_params = jax.tree.map(lambda pr: pr[0], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_state["comp"] = jax.tree.map(lambda pr: pr[1], pairs,
                                         is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            params, deltas)
    return new_params, new_state, {"grad_norm": gnorm}
