from .optimizer import OptConfig, apply_updates, clip_by_global_norm, global_norm, init_opt_state
from .schedule import constant, warmup_cosine

__all__ = ["OptConfig", "apply_updates", "clip_by_global_norm",
           "global_norm", "init_opt_state", "warmup_cosine", "constant"]
