"""Roofline term derivation from compiled dry-run artifacts.

collective_bytes is NOT in cost_analysis — we parse the post-SPMD HLO
(``compiled.as_text()``) and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, applying
ring-algorithm factors with the group size parsed from replica_groups.
Axis attribution (pod tier vs ICI tier) follows group *stride* against
the mesh shape: groups whose members differ in the leading (pod) mesh
coordinate are charged to the slow tier.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

from repro.roofline.hw import V5E, HwSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=(?P<res>.*?)"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all"
    r"|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"\b(pred|[a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    ops: dict                   # kind -> count
    operand_bytes: dict         # kind -> total operand bytes (per device)
    wire_bytes: dict            # kind -> ring-model bytes over links
    pod_wire_bytes: float       # portion attributed to the pod tier
    total_operand_bytes: float
    total_wire_bytes: float


def _wire_from_result(kind: str, result_bytes: float, group: int) -> float:
    """Ring-model bytes over links per device, from the RESULT buffer size.

    Post-optimization HLO prints operands as bare ids, so sizes come from
    the result shape; per-kind algebra recovers the ring traffic:
      all-reduce:        result == operand; 2(g-1)/g x operand
      all-gather:        operand = result/g; (g-1) x operand = (g-1)/g x res
      reduce-scatter:    operand = result*g; (g-1)/g x operand = (g-1) x res
      all-to-all:        operand == result; (g-1)/g x operand
      collective-permute: 1 x result
    """
    if group <= 1:
        return 0.0
    g = group
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes
    if kind == "all-gather":
        return (g - 1) / g * result_bytes
    if kind == "reduce-scatter":
        return float(g - 1) * result_bytes
    if kind == "all-to-all":
        return (g - 1) / g * result_bytes
    if kind == "collective-permute":
        return result_bytes
    return result_bytes


def _operand_from_result(kind: str, result_bytes: float, group: int) -> float:
    if kind == "all-gather":
        return result_bytes / max(group, 1)
    if kind == "reduce-scatter":
        return result_bytes * max(group, 1)
    return result_bytes


def parse_collectives(hlo_text: str, pod_size: Optional[int] = None,
                      n_devices: Optional[int] = None) -> CollectiveStats:
    """Scan post-optimization HLO for collectives.

    ``pod_size`` = number of devices per pod (devices/pod count); a
    replica group that spans across pod boundaries (member ids in
    different pods) gets its wire bytes charged to the pod tier.
    """
    ops, obytes, wbytes = {}, {}, {}
    pod_wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group("kind").lower()
        shapes = _SHAPE_RE.findall(m.group("res"))
        result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        spans_pod = False
        g = _GROUPS_RE.search(line)
        if g:
            members = [int(x) for x in g.group(1).split(",")]
            group = len(members)
            if pod_size:
                spans_pod = len({mm // pod_size for mm in members}) > 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                # iota format [G, S] <= [d0, d1, ...] T(perm): decode exactly.
                G, S = int(gi.group(1)), int(gi.group(2))
                dims = [int(x) for x in gi.group(3).split(",")]
                import numpy as _np
                ids = _np.arange(int(_np.prod(dims))).reshape(dims)
                if gi.group(4):
                    perm = [int(x) for x in gi.group(4).split(",")]
                    ids = ids.transpose(perm)
                groups = ids.reshape(G, S)
                group = S
                if pod_size:
                    pods = groups // pod_size
                    spans_pod = bool((pods != pods[:, :1]).any())
            else:
                group = n_devices or 1
        ops[kind] = ops.get(kind, 0) + 1
        obytes[kind] = obytes.get(kind, 0) + _operand_from_result(
            kind, result_bytes, group)
        wire = _wire_from_result(kind, result_bytes, group)
        wbytes[kind] = wbytes.get(kind, 0) + wire
        if spans_pod:
            pod_wire += wire
    return CollectiveStats(
        ops=ops, operand_bytes=obytes, wire_bytes=wbytes,
        pod_wire_bytes=pod_wire,
        total_operand_bytes=float(sum(obytes.values())),
        total_wire_bytes=float(sum(wbytes.values())))


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------


def model_flops(cfg, cell) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) — N excl. embeddings."""
    from repro.launch.params import active_param_count

    n_active = active_param_count(cfg)
    tokens = cell.seq_len * cell.global_batch if cell.kind == "train" else (
        cell.seq_len * cell.global_batch if cell.kind == "prefill"
        else cell.global_batch)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active * tokens


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   coll: CollectiveStats, hw: HwSpec = V5E) -> dict:
    ici_wire = coll.total_wire_bytes - coll.pod_wire_bytes
    t_compute = flops_per_device / hw.peak_flops_bf16
    t_memory = hbm_bytes_per_device / hw.hbm_bw
    t_coll = ici_wire / hw.ici_bw + coll.pod_wire_bytes / hw.pod_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {**terms,
            "dominant": dominant,
            "step_time_lower_bound_s": bound,
            "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0}
