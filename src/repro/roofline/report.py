"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSON artifacts written by launch/dryrun.py.

Run: PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load_all(dirpath: str):
    cells = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def _fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n / 1e9:.2f}"


def roofline_table(cells, mesh="single") -> str:
    rows = ["| arch | cell | compute_s | memory_s | collective_s | dominant "
            "| frac | useful | fits16GB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['cell']} | — | — | — | skipped |"
                        f" — | — | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['cell']} | ERROR | | | | | | |")
            continue
        r = c["roofline"]
        m = c.get("memory_per_device") or {}
        rows.append(
            f"| {c['arch']} | {c['cell']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{c.get('useful_flops_ratio') or 0:.2f} | "
            f"{m.get('fits_16GB', '-')} |")
    return "\n".join(rows)


def dryrun_table(cells) -> str:
    rows = ["| arch | cell | mesh | status | params | args GB/dev | "
            "temp GB/dev | flops/dev | wire GB/dev | pod GB/dev | colls |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['cell']} | {c['mesh']} | "
                        f"skipped (full-attn) | | | | | | | |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['cell']} | {c['mesh']} | ERROR: "
                        f"{c.get('error', '')[:60]} | | | | | | | |")
            continue
        m = c.get("memory_per_device") or {}
        coll = c["collectives"]
        nops = sum(coll["ops"].values())
        rows.append(
            f"| {c['arch']} | {c['cell']} | {c['mesh']} | ok | "
            f"{c['params_total'] / 1e9:.2f}B | "
            f"{_fmt_bytes(m.get('arguments_bytes'))} | "
            f"{_fmt_bytes(m.get('temp_bytes'))} | "
            f"{c['cost_per_device']['flops']:.2e} | "
            f"{coll['total_wire_bytes'] / 1e9:.2f} | "
            f"{coll['pod_wire_bytes'] / 1e9:.2f} | {nops} |")
    return "\n".join(rows)


def summary(cells) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    worst = sorted((c for c in ok if c["mesh"] == "single"),
                   key=lambda c: c["roofline"]["roofline_fraction"])
    coll_bound = [c for c in ok if c["mesh"] == "single"
                  and c["roofline"]["dominant"] == "collective_s"]
    coll_bound.sort(key=lambda c: -c["roofline"]["collective_s"])
    return {"ok": len(ok), "skipped": len(skipped), "errors": len(err),
            "worst_fraction": [(c["arch"], c["cell"],
                                round(c["roofline"]["roofline_fraction"], 4))
                               for c in worst[:5]],
            "most_collective_bound": [
                (c["arch"], c["cell"], round(c["roofline"]["collective_s"], 2))
                for c in coll_bound[:5]]}


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    cells = load_all(d)
    print("## Dry-run matrix\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(cells, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(cells, "multi"))
    print("\n## Summary\n")
    print(json.dumps(summary(cells), indent=1))
