"""Fill EXPERIMENTS.md placeholders from dryrun/perf JSON artifacts.

Run: PYTHONPATH=src python -m repro.roofline.assemble
"""

from __future__ import annotations

import glob
import json
import os

from repro.roofline.report import dryrun_table, load_all, roofline_table, summary


def perf_section(perf_dir="experiments/perf",
                 base_dir="experiments/dryrun") -> str:
    cells = {}
    for f in sorted(glob.glob(os.path.join(perf_dir, "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        tag = os.path.basename(f).rsplit("_v", 1)[0]
        cells.setdefault(tag, []).append(d)

    out = []
    for tag, variants in cells.items():
        arch = variants[0]["arch"]
        cell = variants[0]["cell"]
        base_path = os.path.join(base_dir, f"{arch}_{cell}_single.json")
        base = None
        if os.path.exists(base_path):
            with open(base_path) as fh:
                base = json.load(fh)
        out.append(f"### {arch} × {cell}\n")
        rows = ["| variant | hypothesis (abridged) | compute_s | memory_s "
                "| collective_s | frac | fits16GB | verdict |",
                "|---|---|---|---|---|---|---|---|"]

        def row(name, d, hypo, verdict=""):
            if d.get("status") != "ok":
                return (f"| {name} | {hypo[:70]}… | ERROR | | | | | "
                        f"{d.get('error', '')[:60]} |")
            r = d["roofline"]
            m = d.get("memory_per_device") or {}
            return (f"| {name} | {hypo[:70]}… | {r['compute_s']:.3f} | "
                    f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                    f"{r['roofline_fraction']:.3f} | "
                    f"{m.get('fits_16GB', '-')} | {verdict} |")

        if base is not None:
            rows.append(row("baseline (paper-faithful)", base, "—", "—"))
        best = None
        for v in sorted(variants, key=lambda d: d.get("variant", "")):
            verdict = ""
            if v.get("status") == "ok" and base and base["status"] == "ok":
                b = base["roofline"]["step_time_lower_bound_s"]
                n = v["roofline"]["step_time_lower_bound_s"]
                speedup = b / n if n > 0 else float("inf")
                verdict = (f"{'CONFIRMED' if speedup > 1.05 else 'REFUTED'} "
                           f"({speedup:.2f}x bound)")
                if best is None or n < best[0]:
                    best = (n, v)
            rows.append(row(v.get("variant", "?"), v,
                            v.get("hypothesis", ""), verdict))
        out.append("\n".join(rows))
        if best and base and base["status"] == "ok":
            b = base["roofline"]
            n = best[1]["roofline"]
            out.append(
                f"\n**Net**: step-time lower bound "
                f"{b['step_time_lower_bound_s']:.2f}s → "
                f"{n['step_time_lower_bound_s']:.2f}s "
                f"({b['step_time_lower_bound_s'] / max(n['step_time_lower_bound_s'], 1e-9):.1f}×); "
                f"roofline fraction {b['roofline_fraction']:.3f} → "
                f"{n['roofline_fraction']:.3f} "
                f"(best variant: {best[1]['variant']}).\n")
        out.append("")
    return "\n".join(out)


def main():
    cells = load_all("experiments/dryrun")
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    dr = dryrun_table(cells) + "\n\n```json\n" + json.dumps(
        summary(cells), indent=1) + "\n```"
    rf = ("### single-pod (16×16 = 256 chips)\n\n"
          + roofline_table(cells, "single")
          + "\n\n### multi-pod (2×16×16 = 512 chips)\n\n"
          + roofline_table(cells, "multi"))
    text = text.replace("<!-- DRYRUN_TABLE -->", dr)
    text = text.replace("<!-- ROOFLINE_TABLE -->", rf)
    text = text.replace("<!-- PERF_SECTION -->", perf_section())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
