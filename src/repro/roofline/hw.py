"""Hardware constants for the roofline target (TPU v5e) + EPAC references.

Terms (per §Roofline of the task):
  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = Σ collective_bytes_per_device x algo_factor / link_bw
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float      # per chip
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link (on-pod axes)
    pod_bw: float               # bytes/s pod-to-pod tier
    hbm_bytes: float            # capacity per chip


V5E = HwSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    pod_bw=25e9,
    hbm_bytes=16e9,
)

# EPAC's own fabric numbers (§4), used by benchmarks/bench_noc.py.
EPAC_NOC_PORT_BW = 64e9
EPAC_C2C_BW = 25e9
