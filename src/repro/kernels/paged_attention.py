"""Paged (ragged) decode-attention kernel — block-pool KV gather on-chip.

The serving engine's KV cache is a shared pool of fixed-size token blocks
with a per-sequence block table (models/paged_kv.py) — the software
analogue of EPAC's distributed L2 slices under programmable address
interleaving: a sequence's logical positions are scattered over physical
slices, and the *index map* (here the prefetched block table) is the
hardware address-generation step.

One grid step = one (sequence, logical block) pair; the kv axis is
innermost-sequential and carries the online-softmax (m, l, acc) scratch,
exactly like kernels/flash_attention.py. The block table and per-sequence
lengths arrive via PrefetchScalarGridSpec so the BlockSpec index map can
route each grid step's DMA to the right physical block — fully-masked
blocks (past a sequence's length, or entirely outside its sliding window)
are predicated off before touching the MXU.

Ragged batches therefore cost O(sum(ceil(len_i / BS))) block fetches, not
O(B * max_len) — the whole point of continuous batching.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import TPUCompilerParams

from .flash_attention import MASK_VALUE

# Megacore work split: the batch axis is embarrassingly parallel (each
# sequence owns its own online-softmax scratch); the kv-block axis is
# sequential by construction. Interpret mode ignores compiler params.
_MEGACORE = TPUCompilerParams(
    dimension_semantics=("parallel", "arbitrary"))


def _dequant(ref, scale_ref):
    """Load one (BS, Hkv, D) block in f32, fusing the per-(token, head)
    dequant multiply when the pool carries int8/fp8 payload + scales —
    the fp copy of the block exists only in VMEM registers, never in
    HBM."""
    x = ref[0].astype(jnp.float32)
    if scale_ref is not None:
        x = x * scale_ref[0][..., None]                 # (BS, Hkv, 1)
    return x


def _pa_kernel(bt_ref, len_ref, *refs, scale, window, block_size,
               hkv, group, nb, quantized):
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    k_lo = i * block_size
    needed = k_lo < length
    if window is not None:
        needed = jnp.logical_and(needed,
                                 k_lo + block_size > length - window)

    @pl.when(needed)
    def _block():
        hq = hkv * group
        q = q_ref[0].astype(jnp.float32)                # (Hq, D)
        k = _dequant(k_ref, ks_ref)                     # (BS, Hkv, D)
        v = _dequant(v_ref, vs_ref)
        d = q.shape[-1]
        qg = q.reshape(hkv, group, d)
        kt = k.transpose(1, 0, 2)                       # (Hkv, BS, D)
        vt = v.transpose(1, 0, 2)
        s = jax.lax.dot_general(qg, kt, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32) * scale
        s = s.reshape(hq, block_size)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (hq, block_size), 1)
        mask = kpos < length
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= length - window)
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_ref[...]                             # (Hq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pg = p.reshape(hkv, group, block_size)
        pv = jax.lax.dot_general(pg, vt, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(hq, d)
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _store():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = out.astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pool, v_pool, block_table, lengths, *,
                                  window=None, scale=None, k_scale=None,
                                  v_scale=None, interpret=False):
    """q: (B, Hq, D); pools: (NB, BS, Hkv, D); block_table: (B, NBMAX);
    lengths: (B,) valid tokens incl. the current one; ``k_scale`` /
    ``v_scale``: (NB, BS, Hkv) f32 dequant scales for int8/fp8 pools
    (None = fp pool), DMA'd per block through the same prefetched index
    map as the payload and applied in VMEM. -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, BS, Hkv, _ = k_pool.shape
    group = Hq // Hkv
    nbmax = block_table.shape[1]
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(D))
    quantized = k_scale is not None

    def kv_map(b, i, bt, lens):
        return (bt[b, i], 0, 0, 0)

    def scale_map(b, i, bt, lens):
        return (bt[b, i], 0, 0)

    in_specs = [
        pl.BlockSpec((1, Hq, D), lambda b, i, bt, lens: (b, 0, 0)),
        pl.BlockSpec((1, BS, Hkv, D), kv_map),
        pl.BlockSpec((1, BS, Hkv, D), kv_map),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, BS, Hkv), scale_map)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nbmax),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, i, bt, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),    # running max
            pltpu.VMEM((Hq, 1), jnp.float32),    # running sum
            pltpu.VMEM((Hq, D), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_pa_kernel, scale=scale, window=window,
                          block_size=BS, hkv=Hkv, group=group, nb=nbmax,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        compiler_params=_MEGACORE,
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)


def _pv_kernel(bt_ref, len_ref, *refs, scale, window, block_size,
               hkv, group, nb, k1, quantized):
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]                 # cached BEFORE the verify window
    k_lo = i * block_size
    # the block is needed if ANY of the K+1 rows can see it: the last
    # row has the highest upper bound (length + k1), the first row the
    # lowest window floor (length + 1 - window)
    needed = k_lo < length + k1
    if window is not None:
        needed = jnp.logical_and(needed,
                                 k_lo + block_size > length + 1 - window)

    @pl.when(needed)
    def _block():
        hq = hkv * group
        q = q_ref[0].astype(jnp.float32)                # (K1, Hq, D)
        k = _dequant(k_ref, ks_ref)                     # (BS, Hkv, D)
        v = _dequant(v_ref, vs_ref)
        d = q.shape[-1]
        # group the query rows under their kv heads: (Hkv, K1*group, D)
        qg = q.reshape(k1, hkv, group, d).transpose(1, 0, 2, 3) \
              .reshape(hkv, k1 * group, d)
        kt = k.transpose(1, 0, 2)                       # (Hkv, BS, D)
        vt = v.transpose(1, 0, 2)
        s = jax.lax.dot_general(qg, kt, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32) * scale
        # -> per-query-row layout (K1*Hq, BS), row-major in (K1, Hq)
        s = s.reshape(hkv, k1, group, block_size).transpose(1, 0, 2, 3) \
             .reshape(k1 * hq, block_size)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (k1 * hq, block_size), 1)
        # row j of the q-block attends positions < length + 1 + j
        j = jax.lax.broadcasted_iota(jnp.int32,
                                     (k1 * hq, block_size), 0) // hq
        limit = length + 1 + j
        mask = kpos < limit
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= limit - window)
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_ref[...]                             # (K1*Hq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pg = p.reshape(k1, hkv, group, block_size).transpose(1, 0, 2, 3) \
              .reshape(hkv, k1 * group, block_size)
        pv = jax.lax.dot_general(pg, vt, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        pv = pv.reshape(hkv, k1, group, d).transpose(1, 0, 2, 3) \
               .reshape(k1 * hq, d)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _store():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = out.reshape(k1, hkv * group, -1).astype(o_ref.dtype)


def paged_verify_attention_pallas(q, k_pool, v_pool, block_table, lengths,
                                  *, window=None, scale=None, k_scale=None,
                                  v_scale=None, interpret=False):
    """Multi-query-per-slot paged decode attention (speculative verify).

    q: (B, K1, Hq, D) — K+1 query rows per sequence for positions
    ``lengths[b] + j``; pools: (NB, BS, Hkv, D); block_table: (B, NBMAX);
    lengths: (B,) tokens cached BEFORE the window (the window's own K/V
    must already be written to the pool). Row j attends positions
    < ``lengths[b] + 1 + j``. ``k_scale``/``v_scale``: (NB, BS, Hkv)
    f32 dequant scales for int8/fp8 pools, fused in VMEM like the
    decode kernel. -> (B, K1, Hq, D).

    Same grid walk as ``paged_decode_attention_pallas`` — one step per
    (sequence, logical block), kv innermost-sequential carrying the
    online-softmax scratch — but the q-block is K+1 rows, so a verify
    step fetches each block ONCE for all K+1 queries instead of K+1
    times across sequential decode steps (the whole point: the decode
    loop's memory traffic amortizes over the speculative window).
    """
    B, K1, Hq, D = q.shape
    _, BS, Hkv, _ = k_pool.shape
    group = Hq // Hkv
    nbmax = block_table.shape[1]
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(D))
    quantized = k_scale is not None

    def kv_map(b, i, bt, lens):
        return (bt[b, i], 0, 0, 0)

    def scale_map(b, i, bt, lens):
        return (bt[b, i], 0, 0)

    in_specs = [
        pl.BlockSpec((1, K1, Hq, D), lambda b, i, bt, lens: (b, 0, 0, 0)),
        pl.BlockSpec((1, BS, Hkv, D), kv_map),
        pl.BlockSpec((1, BS, Hkv, D), kv_map),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, BS, Hkv), scale_map)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nbmax),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, K1, Hq, D),
                               lambda b, i, bt, lens: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K1 * Hq, 1), jnp.float32),    # running max
            pltpu.VMEM((K1 * Hq, 1), jnp.float32),    # running sum
            pltpu.VMEM((K1 * Hq, D), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_pv_kernel, scale=scale, window=window,
                          block_size=BS, hkv=Hkv, group=group, nb=nbmax,
                          k1=K1, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K1, Hq, D), q.dtype),
        compiler_params=_MEGACORE,
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)


def paged_verify_attention_headshard(q, k_pool, v_pool, block_table,
                                     lengths, *, mesh, tp_axis="model",
                                     window=None, scale=None, attend=None,
                                     k_scale=None, v_scale=None,
                                     interpret=False):
    """Multi-device multi-query verify attention over a HEAD-sharded
    pool: the ``paged_decode_attention_headshard`` layout with a K+1
    q-block per sequence. Each device of ``tp_axis`` runs the stock
    verify kernel over its kv-head shard of every block — kv-head
    groups attend independently, so the sharded output needs NO
    collective and no pool byte crosses the interconnect.

    q: (B, K1, Hq, D) sharded over Hq; pools: (NB, BS, Hkv, D) sharded
    over Hkv; ``k_scale``/``v_scale``: (NB, BS, Hkv) f32 dequant scales
    for quantized pools, sharded over Hkv alongside the payload;
    requires ``paged_kv.head_shard_ok`` (head counts divide |tp|).
    ``attend`` is the per-shard op; defaults to the Pallas kernel.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    if attend is None:
        attend = functools.partial(paged_verify_attention_pallas,
                                   interpret=interpret)
    tp = tp_axis
    in_specs = (P(None, None, tp, None), P(None, None, tp, None),
                P(None, None, tp, None), P(None, None), P(None))
    operands = (q, k_pool, v_pool, block_table.astype(jnp.int32),
                lengths.astype(jnp.int32))

    if k_scale is None:
        def local(qv, kp, vp, bt, ln):
            return attend(qv, kp, vp, bt, ln, window=window, scale=scale)
    else:
        in_specs += (P(None, None, tp), P(None, None, tp))
        operands += (k_scale, v_scale)

        def local(qv, kp, vp, bt, ln, ks, vs):
            return attend(qv, kp, vp, bt, ln, window=window, scale=scale,
                          k_scale=ks, v_scale=vs)

    return shard_map(
        local, mesh=mesh, in_specs=in_specs,
        out_specs=P(None, None, tp, None),
    )(*operands)


def paged_decode_attention_headshard(q, k_pool, v_pool, block_table,
                                     lengths, *, mesh, tp_axis="model",
                                     window=None, scale=None, attend=None,
                                     k_scale=None, v_scale=None,
                                     interpret=False):
    """Multi-device paged decode attention over a HEAD-sharded pool.

    The ``decode_seq_shard`` idea applied to the pool layout (the
    ROADMAP multi-device variant over the block pool): every device on
    the ``tp_axis`` owns its kv-head shard of EVERY physical block —
    the software analogue of slicing EPAC's distributed L2 by way
    rather than by address — while block tables and lengths stay
    replicated scalars. Because kv-head groups attend independently,
    each shard runs the stock single-device kernel over its local heads
    and the sharded output needs NO collective; no pool byte ever
    crosses the interconnect.

    q: (B, Hq, D) sharded over Hq; pools: (NB, BS, Hkv, D) sharded over
    Hkv; ``k_scale``/``v_scale``: (NB, BS, Hkv) f32 dequant scales for
    quantized pools, sharded over Hkv alongside the payload; requires
    Hq % |tp| == 0 and Hkv % |tp| == 0 (group alignment then holds
    automatically — see ``paged_kv.head_shard_ok``). ``attend`` is the
    per-shard op; defaults to the Pallas kernel.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    if attend is None:
        attend = functools.partial(paged_decode_attention_pallas,
                                   interpret=interpret)
    tp = tp_axis
    in_specs = (P(None, tp, None), P(None, None, tp, None),
                P(None, None, tp, None), P(None, None), P(None))
    operands = (q, k_pool, v_pool, block_table.astype(jnp.int32),
                lengths.astype(jnp.int32))

    if k_scale is None:
        def local(qv, kp, vp, bt, ln):
            return attend(qv, kp, vp, bt, ln, window=window, scale=scale)
    else:
        in_specs += (P(None, None, tp), P(None, None, tp))
        operands += (k_scale, v_scale)

        def local(qv, kp, vp, bt, ln, ks, vs):
            return attend(qv, kp, vp, bt, ln, window=window, scale=scale,
                          k_scale=ks, v_scale=vs)

    return shard_map(
        local, mesh=mesh, in_specs=in_specs,
        out_specs=P(None, tp, None),
    )(*operands)
