"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package must match its oracle here (assert_allclose in
tests/test_kernels_*.py across shape/dtype sweeps). The oracles are also
the CPU lowering path for the dry-run: identical math, so HLO FLOP/byte
counts stay representative of the kernelized TPU build.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# STX matmul
# ---------------------------------------------------------------------------


def matmul(x, w, out_dtype=None):
    """(..., K) @ (K, N), f32 accumulation."""
    out = jnp.einsum("...k,kn->...n", x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


# ---------------------------------------------------------------------------
# STX stencil (the SPU workload: structured-grid, fixed pattern)
# ---------------------------------------------------------------------------


def stencil2d(x, weights):
    """3x3 weighted stencil on (..., M, N); zero boundary (halo = 0)."""
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(1, 1), (1, 1)])
    out = jnp.zeros_like(x)
    for di in range(3):
        for dj in range(3):
            out = out + weights[di, dj] * jax.lax.slice_in_dim(
                jax.lax.slice_in_dim(xp, di, di + x.shape[-2], axis=-2),
                dj, dj + x.shape[-1], axis=-1)
    return out


def stencil3d(x, weights):
    """3x3x3 weighted stencil on (..., D, M, N); zero boundary."""
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 3) + [(1, 1)] * 3)
    out = jnp.zeros_like(x)
    for dd in range(3):
        for di in range(3):
            for dj in range(3):
                sl = xp[..., dd:dd + x.shape[-3], di:di + x.shape[-2],
                        dj:dj + x.shape[-1]]
                out = out + weights[dd, di, dj] * sl
    return out


def seven_point_weights(dtype=jnp.float32):
    """Classic 7-point Laplacian weights as a 3x3x3 mask."""
    w = np.zeros((3, 3, 3), dtype=np.float64)
    w[1, 1, 1] = -6.0
    for d in ((0, 1, 1), (2, 1, 1), (1, 0, 1), (1, 2, 1), (1, 1, 0), (1, 1, 2)):
        w[d] = 1.0
    return jnp.asarray(w, dtype)


def five_point_weights(dtype=jnp.float32):
    w = np.zeros((3, 3), dtype=np.float64)
    w[1, 1] = -4.0
    w[0, 1] = w[2, 1] = w[1, 0] = w[1, 2] = 1.0
    return jnp.asarray(w, dtype)


# ---------------------------------------------------------------------------
# Flash attention (GQA / causal / sliding-window)
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    q_offset=0):
    """Oracle attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). GQA maps query head h to
    kv head h // (Hq // Hkv). ``window`` (if set) restricts attention to
    the last ``window`` positions (SWA). ``q_offset`` positions queries at
    absolute position q_offset + i (decode: Sq=1, q_offset=pos).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(D))
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (can happen with tiny windows) -> zeros, not NaN.
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged decode attention (block-pool KV cache)
# ---------------------------------------------------------------------------


def _gather_dequant(pool, scale_pool, block_table, B, S, Hkv, D):
    """Gather pool blocks into (B, S, Hkv, D) f32 sequences, applying the
    per-(token, head) dequant scales when the pool is quantized."""
    x = pool[block_table].reshape(B, S, Hkv, D).astype(jnp.float32)
    if scale_pool is not None:
        x = x * scale_pool[block_table].reshape(B, S, Hkv)[..., None]
    return x


def paged_decode_attention(q, k_pool, v_pool, block_table, lengths, *,
                           window=None, scale=None, k_scale=None,
                           v_scale=None):
    """Oracle single-token decode attention over a block-paged KV cache.

    q: (B, Hq, D) — the query for the token at position ``lengths[b] - 1``.
    k_pool, v_pool: (NB, BS, Hkv, D) — shared pool of BS-token blocks.
    block_table: (B, NBMAX) int32 — per-sequence logical->physical block map
    (entries past a sequence's last block may hold any in-range id).
    lengths: (B,) int32 — valid tokens per sequence (including the current
    token, whose K/V must already be written to the pool).
    ``window`` restricts attention to the last ``window`` positions (SWA).
    ``k_scale``/``v_scale``: (NB, BS, Hkv) f32 dequant scales when the
    pool stores int8/fp8 payloads (None = fp pool, historical math).
    Returns (B, Hq, D) in q.dtype.
    """
    B, Hq, D = q.shape
    _, BS, Hkv, _ = k_pool.shape
    group = Hq // Hkv
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(D))
    S = block_table.shape[1] * BS
    k = _gather_dequant(k_pool, k_scale, block_table, B, S, Hkv, D)
    v = _gather_dequant(v_pool, v_scale, block_table, B, S, Hkv, D)
    kx = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1)  # (B, Hq, S, D)
    vx = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    kpos = jnp.arange(S)[None, :]
    valid = kpos < lengths[:, None]
    if window is not None:
        valid = valid & (kpos >= lengths[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.any(valid, -1)[:, None, None], probs, 0.0)
    return jnp.einsum("bhs,bhsd->bhd", probs,
                      vx.astype(jnp.float32)).astype(q.dtype)


def paged_verify_attention(q, k_pool, v_pool, block_table, lengths, *,
                           window=None, scale=None, k_scale=None,
                           v_scale=None):
    """Oracle multi-query decode attention over a block-paged KV cache.

    The speculative-decode verify step: each sequence contributes a
    q-block of K+1 query rows for the positions ``lengths[b] + j``
    (j = 0..K), whose K/V must already be written to the pool. Row j
    attends positions < ``lengths[b] + j + 1`` — causal within the
    window, so the block-row j result is bit-equal to the single-query
    ``paged_decode_attention`` at length ``lengths[b] + j + 1``.

    q: (B, K1, Hq, D); pools: (NB, BS, Hkv, D); block_table: (B, NBMAX);
    lengths: (B,) int32 tokens cached BEFORE the verify window. ``window``
    restricts each row to its last ``window`` positions.
    ``k_scale``/``v_scale``: (NB, BS, Hkv) f32 dequant scales when the
    pool stores int8/fp8 payloads (None = fp pool). -> (B, K1, Hq, D).
    """
    B, K1, Hq, D = q.shape
    _, BS, Hkv, _ = k_pool.shape
    group = Hq // Hkv
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(D))
    S = block_table.shape[1] * BS
    k = _gather_dequant(k_pool, k_scale, block_table, B, S, Hkv, D)
    v = _gather_dequant(v_pool, v_scale, block_table, B, S, Hkv, D)
    kx = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1)  # (B, Hq, S, D)
    vx = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1)
    logits = jnp.einsum("bjhd,bhsd->bjhs", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    kpos = jnp.arange(S)[None, None, :]                      # (1, 1, S)
    limit = lengths[:, None, None] + 1 + jnp.arange(K1)[None, :, None]
    valid = kpos < limit                                     # (B, K1, S)
    if window is not None:
        valid = valid & (kpos >= limit - window)
    logits = jnp.where(valid[:, :, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.any(valid, -1)[:, :, None, None], probs, 0.0)
    return jnp.einsum("bjhs,bhsd->bjhd", probs,
                      vx.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# VRP compensated reductions (double-word = 2-term expansion)
# ---------------------------------------------------------------------------


def vrp_dot(x, y):
    """Double-word dot oracle via core.vrp at K=2 in the input dtype."""
    from repro.core import vrp
    from repro.core.precision import PrecisionEnv

    env = PrecisionEnv(compute_terms=2, base_dtype=str(x.dtype))
    e = vrp.dot(x, y, env)
    return e  # (2,) expansion [hi, lo]


def vrp_sum(x):
    from repro.core import vrp
    from repro.core.precision import PrecisionEnv

    env = PrecisionEnv(compute_terms=2, base_dtype=str(x.dtype))
    return vrp.sum_floats(x.reshape(-1), env)


# ---------------------------------------------------------------------------
# RG-LRU / diagonal linear recurrence scan
# ---------------------------------------------------------------------------


def linear_scan(a, x, h0=None):
    """h_t = a_t * h_{t-1} + x_t along axis 1. a, x: (B, T, D).

    Implemented as an associative scan (log-depth; the XLA-native form a
    TPU would run when not using the Pallas kernel; also makes its FLOPs
    visible to cost_analysis, unlike a while-loop scan).
    """
    if h0 is not None:
        x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        aL, bL = left
        aR, bR = right
        return aL * aR, bL * aR + bR

    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h
