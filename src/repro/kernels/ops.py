"""Public kernel API — jit'd wrappers with backend dispatch + VLA padding.

Every op takes ``mode``:
  'auto'      — Pallas on TPU, jnp reference on CPU (dry-run lowering path;
                identical math so HLO FLOP/byte counts stay representative)
  'pallas'    — force pallas_call (real TPU execution)
  'interpret' — pallas_call(interpret=True): kernel body runs in Python on
                CPU — the per-kernel correctness gate used by tests/
  'ref'       — force the pure-jnp oracle

Inputs of arbitrary size are padded to block multiples and sliced back —
the VEC tile's vector-length-agnostic discipline (no scalar tails, no
shape-specialized kernels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .flash_attention import flash_attention_pallas
from .paged_attention import (paged_decode_attention_headshard as
                              _pa_headshard)
from .paged_attention import (paged_verify_attention_headshard as
                              _pv_headshard)
from .paged_attention import (paged_decode_attention_pallas,
                              paged_verify_attention_pallas)
from .rglru_scan import rglru_scan_pallas
from .stx_matmul import stx_matmul_pallas
from .stx_stencil import stencil2d_pallas, stencil3d_pallas
from .vrp_dot import vrp_dot_pallas, vrp_sum_pallas


def _use_pallas(mode: str) -> tuple[bool, bool]:
    """-> (use pallas, interpret flag)."""
    if mode == "auto":
        return (jax.default_backend() == "tpu", False)
    if mode == "pallas":
        return True, False
    if mode == "interpret":
        return True, True
    if mode == "ref":
        return False, False
    raise ValueError(f"unknown kernel mode {mode!r}")


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# ---------------------------------------------------------------------------


def stx_matmul(x, w, *, block_m=128, block_n=128, block_k=128, mode="auto",
               interpret=False, out_dtype=None):
    """(..., K) @ (K, N) through the STX tile."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.matmul(x, w, out_dtype=out_dtype)
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    xm, m0 = _pad_to(xm, 0, block_m)
    xm, k0 = _pad_to(xm, 1, block_k)
    wp, _ = _pad_to(w, 0, block_k)
    wp, n0 = _pad_to(wp, 1, block_n)
    out = stx_matmul_pallas(xm, wp, block_m=block_m, block_n=block_n,
                            block_k=block_k, out_dtype=out_dtype,
                            interpret=interp)
    return out[:m0, :n0].reshape(*lead, n0)


def stencil2d(x, weights, *, block_m=128, block_n=128, mode="auto",
              interpret=False):
    """3x3 weighted stencil, zero boundary; x: (M, N)."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.stencil2d(x, weights)
    xp, m0 = _pad_to(x, 0, block_m)
    xp, n0 = _pad_to(xp, 1, block_n)
    out = stencil2d_pallas(xp, weights, block_m=block_m, block_n=block_n,
                           interpret=interp)
    return out[:m0, :n0]


def stencil3d(x, weights, *, block_d=8, block_m=32, block_n=128, mode="auto",
              interpret=False):
    """3x3x3 weighted stencil, zero boundary; x: (D, M, N)."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.stencil3d(x, weights)
    xp, d0 = _pad_to(x, 0, block_d)
    xp, m0 = _pad_to(xp, 1, block_m)
    xp, n0 = _pad_to(xp, 2, block_n)
    out = stencil3d_pallas(xp, weights, block_d=block_d, block_m=block_m,
                           block_n=block_n, interpret=interp)
    return out[:d0, :m0, :n0]


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=128, block_k=128, mode="auto", interpret=False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D)."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.flash_attention(q, k, v, causal=causal, window=window,
                                    scale=scale)
    qp, sq0 = _pad_to(q, 2, block_q)
    kp, skv0 = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 scale=scale, kv_len=skv0, block_q=block_q,
                                 block_k=block_k, interpret=interp)
    return out[:, :, :sq0]


def paged_attention(q, pool, block_table, lengths, *, mode="decode",
                    window=None, scale=None, kernel_mode="auto",
                    sharding=None, tp_axis="model", kv_format=None,
                    interpret=False):
    """ONE entry point for paged attention over a pool dict.

    Unifies what used to be four call sites (decode / verify, plain /
    head-sharded) behind a single dispatcher, so quantized pools and
    lane-padded layouts plug in without new entry points:

    * ``mode="decode"`` — q: (B, Hq, D), one query row per slot at
      position ``lengths[b] - 1``; ``mode="verify"`` — q: (B, K1, Hq, D)
      speculative K+1 query rows, ``lengths`` counting tokens cached
      BEFORE the window.
    * ``pool`` — the per-layer pool dict ``{"k", "v"}``; a quantized
      pool also carries ``k_scale``/``v_scale`` (NB, BS, Hkv) f32
      leaves, detected here and fused into whichever backend runs.
    * ``sharding`` — None for single-device, or an object with ``mesh``
      / ``tp_axis`` attributes (e.g. ``ShardCtx``) for the head-sharded
      shard_map path (scales shard over Hkv with the payload).
    * ``kv_format`` — the pool's ``paged_kv.PoolSpec`` (or None). Used
      for the lane-padding contract: when blocks are physically wider
      than the model head dim (``padded_head_dim``), q is zero-padded to
      the block width and the output sliced back; the softmax scale
      ALWAYS derives from the logical head dim. The spec is advisory —
      quantization is detected from the pool leaves — so bf16 callers
      may pass None.
    * ``kernel_mode`` — the usual backend switch ('auto' / 'pallas' /
      'interpret' / 'ref'), oracle and Pallas paths taking identical
      arguments.
    """
    if mode not in ("decode", "verify"):
        raise ValueError(f"mode must be 'decode' or 'verify', got {mode!r}")
    k_pool, v_pool = pool["k"], pool["v"]
    k_scale, v_scale = pool.get("k_scale"), pool.get("v_scale")
    D = q.shape[-1]
    Dp = k_pool.shape[-1]
    if scale is None:
        scale = float(1.0 / np.sqrt(D))   # logical head dim, pre-padding
    if Dp != D:
        widths = [(0, 0)] * (q.ndim - 1) + [(0, Dp - D)]
        q = jnp.pad(q, widths)
    use, interp = _use_pallas(kernel_mode)
    interp = interp or interpret
    decode = mode == "decode"
    if sharding is not None:
        if not use and not interp:
            attend = _ref.paged_decode_attention if decode \
                else _ref.paged_verify_attention
        else:
            attend = functools.partial(
                paged_decode_attention_pallas if decode
                else paged_verify_attention_pallas, interpret=interp)
        fn = _pa_headshard if decode else _pv_headshard
        out = fn(q, k_pool, v_pool, block_table, lengths,
                 mesh=sharding.mesh,
                 tp_axis=getattr(sharding, "tp_axis", tp_axis),
                 window=window, scale=scale, attend=attend,
                 k_scale=k_scale, v_scale=v_scale)
    elif not use and not interp:
        fn = _ref.paged_decode_attention if decode \
            else _ref.paged_verify_attention
        out = fn(q, k_pool, v_pool, block_table, lengths, window=window,
                 scale=scale, k_scale=k_scale, v_scale=v_scale)
    else:
        fn = paged_decode_attention_pallas if decode \
            else paged_verify_attention_pallas
        out = fn(q, k_pool, v_pool, block_table, lengths, window=window,
                 scale=scale, k_scale=k_scale, v_scale=v_scale,
                 interpret=interp)
    return out[..., :D] if Dp != D else out


# -- thin deprecated aliases (one-PR deprecation window) --------------------
# The four historical entry points forward to ``paged_attention``; new
# call sites should use the dispatcher directly.


def paged_decode_attention(q, k_pool, v_pool, block_table, lengths, *,
                           window=None, scale=None, mode="auto",
                           interpret=False):
    """Deprecated alias: ``paged_attention(..., mode="decode")``.

    q: (B, Hq, D); k_pool/v_pool: (NB, BS, Hkv, D); block_table:
    (B, NBMAX) int32; lengths: (B,) int32 valid tokens per sequence
    (including the current token). No padding pass is needed: the pool is
    block-shaped by construction and raggedness is masked in-kernel.
    """
    return paged_attention(q, {"k": k_pool, "v": v_pool}, block_table,
                           lengths, mode="decode", window=window,
                           scale=scale, kernel_mode=mode,
                           interpret=interpret)


def paged_verify_attention(q, k_pool, v_pool, block_table, lengths, *,
                           window=None, scale=None, mode="auto",
                           interpret=False):
    """Deprecated alias: ``paged_attention(..., mode="verify")``.

    q: (B, K1, Hq, D) — K+1 query rows per sequence at positions
    ``lengths[b] + j``, whose K/V are already written to the pool;
    lengths: (B,) int32 tokens cached BEFORE the verify window. Row j
    attends positions < ``lengths[b] + 1 + j`` (causal within the
    window), so each row is equivalent to ``paged_decode_attention`` at
    its own length while every pool block is fetched once for all rows.
    """
    return paged_attention(q, {"k": k_pool, "v": v_pool}, block_table,
                           lengths, mode="verify", window=window,
                           scale=scale, kernel_mode=mode,
                           interpret=interpret)


class _MeshSharding:
    """Minimal ``sharding`` adapter for the deprecated headshard aliases
    (the dispatcher wants an object with ``mesh``/``tp_axis``)."""

    def __init__(self, mesh, tp_axis):
        self.mesh = mesh
        self.tp_axis = tp_axis


def paged_decode_attention_headshard(q, k_pool, v_pool, block_table,
                                     lengths, *, mesh, tp_axis="model",
                                     window=None, scale=None, mode="auto",
                                     interpret=False):
    """Deprecated alias: ``paged_attention(..., mode="decode",
    sharding=...)`` — head-sharded multi-device paged decode attention
    (see kernels/paged_attention.py for the layout argument)."""
    return paged_attention(q, {"k": k_pool, "v": v_pool}, block_table,
                           lengths, mode="decode", window=window,
                           scale=scale, kernel_mode=mode,
                           sharding=_MeshSharding(mesh, tp_axis),
                           interpret=interpret)


def paged_verify_attention_headshard(q, k_pool, v_pool, block_table,
                                     lengths, *, mesh, tp_axis="model",
                                     window=None, scale=None, mode="auto",
                                     interpret=False):
    """Deprecated alias: ``paged_attention(..., mode="verify",
    sharding=...)`` — head-sharded multi-device multi-query verify
    attention over the speculative window."""
    return paged_attention(q, {"k": k_pool, "v": v_pool}, block_table,
                           lengths, mode="verify", window=window,
                           scale=scale, kernel_mode=mode,
                           sharding=_MeshSharding(mesh, tp_axis),
                           interpret=interpret)


def _finalize_expansion(lanes):
    """Compensated tree over per-lane (8, 128, 2) partials -> (2,)."""
    from repro.core import vrp
    from repro.core.precision import PrecisionEnv

    env = PrecisionEnv(compute_terms=2, base_dtype=str(lanes.dtype))
    return vrp.tree_sum(lanes.reshape(-1, 2), env)


def vrp_dot(x, y, *, mode="auto", interpret=False):
    """Double-word dot of flat vectors -> (2,) expansion [hi, lo]."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.vrp_dot(x, y)
    xp, _ = _pad_to(x.reshape(-1), 0, 1024)
    yp, _ = _pad_to(y.reshape(-1), 0, 1024)
    lanes = vrp_dot_pallas(xp, yp, interpret=interp)
    return _finalize_expansion(lanes)


def vrp_sum(x, *, mode="auto", interpret=False):
    """Double-word sum of a flat vector -> (2,) expansion [hi, lo]."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.vrp_sum(x)
    xp, _ = _pad_to(x.reshape(-1), 0, 1024)
    lanes = vrp_sum_pallas(xp, interpret=interp)
    return _finalize_expansion(lanes)


def rglru_scan(a, x, h0=None, *, block_b=8, block_t=128, block_d=128,
               mode="auto", interpret=False):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + x_t; (B, T, D)."""
    use, interp = _use_pallas(mode)
    interp = interp or interpret
    if not use and not interp:
        return _ref.linear_scan(a, x, h0)
    B, T, D = x.shape
    ap, b0 = _pad_to(a, 0, block_b)
    xp, _ = _pad_to(x, 0, block_b)
    ap, t0 = _pad_to(ap, 1, block_t)
    xp, _ = _pad_to(xp, 1, block_t)
    ap, d0 = _pad_to(ap, 2, block_d)
    xp, _ = _pad_to(xp, 2, block_d)
    h0p = None
    if h0 is not None:
        h0p, _ = _pad_to(h0, 0, block_b)
        h0p, _ = _pad_to(h0p, 1, block_d)
    out = rglru_scan_pallas(ap, xp, h0p, block_b=block_b, block_t=block_t,
                            block_d=block_d, interpret=interp)
    return out[:b0, :t0, :d0]
