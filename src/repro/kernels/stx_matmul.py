"""STX tensor op — MXU-tiled matmul with explicit VMEM accumulation.

The STX tile computes tensor ops (matmul/conv) on Snitch clusters whose
defining features map 1:1 onto this kernel:

  SSR (stream semantic registers)  -> BlockSpec index_maps stream HBM
                                      blocks into VMEM without "core" code
  FREP (HW loop, no refetch)       -> the (i, j, k) Pallas grid
  TCDM scratchpad                  -> the f32 VMEM accumulator scratch
  DMA-core double buffering        -> Pallas's automatic block pipelining
                                      (Gazillion-style outstanding copies)

Block shapes default to (128, 128, 128): MXU-aligned, and a working set of
3 * 128*128*4 B = 192 kB — inside the paper's 64-256 kB TCDM budget per
cluster, deliberately (see DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import TPUCompilerParams


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def stx_matmul_pallas(x, w, *, block_m=128, block_n=128, block_k=128,
                      out_dtype=None, interpret=False):
    """(M, K) @ (K, N) -> (M, N). M, N, K must be multiples of the blocks
    (ops.py pads — VLA masked-tail discipline, see core/vec.py)."""
    m, kdim = x.shape
    _, n = w.shape
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0
    grid = (m // block_m, n // block_n, kdim // block_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
