"""Blocked (flash) attention — the LM hot path on the STX execution tile.

Online-softmax attention with VMEM-resident running (max, sum, acc) state,
GQA head mapping, causal and sliding-window (SWA) masking with block-level
FLOP skipping. Grid = (batch*heads, q_blocks, kv_blocks), kv innermost
sequential; the (m, l, acc) scratch plays the TCDM role and the kv-block
skip predicate plays the SPU's static-access-pattern pruning.

Working set at defaults (bq=bk=128, D<=256, f32 acc):
  q 128x256x4 + k/v 2x128x256x4 + acc 128x256x4 = ~0.5 MB << 16 MB VMEM,
leaving headroom for Pallas's double buffering (Gazillion-style outstanding
block fetches).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import TPUCompilerParams

MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, causal, window, kv_len, block_q, block_k, nk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level skip: never spend MXU cycles on fully-masked kv blocks.
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_k
    needed = k_lo < kv_len
    if causal:
        needed = jnp.logical_and(needed, k_lo <= q_hi)
    if window is not None:
        k_hi = k_lo + block_k - 1
        needed = jnp.logical_and(needed, k_hi > q_lo - window)

    @pl.when(needed)
    def _block():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_ref[...]                      # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                    # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)            # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _store():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           kv_len=None, block_q=128, block_k=128,
                           interpret=False):
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D); Sq % bq == Skv % bk == 0.

    ``kv_len`` masks out tail padding of the kv sequence (ops.py pads).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    # python float (weak type): np.float64 would promote f32 math to f64
    # when x64 is enabled.
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(D))
    kv_len = Skv if kv_len is None else kv_len
    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)
    grid = (B * Hq, Sq // block_q, Skv // block_k)

    def kv_map(bh, qi, kj):
        b, h = bh // Hq, bh % Hq
        return b * Hkv + h // group, kj, 0

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, kv_len=kv_len, block_q=block_q,
                          block_k=block_k, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
