"""STX/SPU stencil kernel — halo-blocked structured-grid update.

The SPU co-processors accelerate "stencil workloads with static access
patterns and local data dependencies" (7-point / 27-point stencils,
diffusion/wave time-stepping). TPU adaptation: each grid cell computes an
output tile from an input tile *plus halo*, streamed HBM->VMEM via
element-indexed BlockSpecs (`pl.Element`) over a once-padded input — the
static access pattern is entirely in the index maps, exactly the SPU's
hardware address generation.

General 3x3 (2-D) and 3x3x3 (3-D) weighted stencils cover the paper's
5/9-point and 7/27-point cases (zero weights prune FLOPs at trace time).
Weights arrive via SMEM — the SPU's configuration registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _halo_spec(block_shape, index_map):
    """Element-indexed BlockSpec across jax generations: newer pallas
    spells it per-dimension (`pl.Element`); 0.4.x spells it as an
    ``Unblocked`` indexing mode on the whole spec."""
    if hasattr(pl, "Element"):
        return pl.BlockSpec(tuple(pl.Element(b) for b in block_shape),
                            index_map)
    return pl.BlockSpec(tuple(block_shape), index_map,
                        indexing_mode=pl.unblocked)


def _st2d_kernel(w_ref, x_ref, o_ref):
    xb = x_ref[...]  # (bm + 2, bn + 2) with halo
    acc = jnp.zeros_like(o_ref)
    bm, bn = o_ref.shape
    for di in range(3):
        for dj in range(3):
            acc = acc + w_ref[di, dj] * jax.lax.dynamic_slice(xb, (di, dj), (bm, bn))
    o_ref[...] = acc


def stencil2d_pallas(x, weights, *, block_m=128, block_n=128, interpret=False):
    """3x3 stencil on (M, N), zero boundary. M, N multiples of block."""
    m, n = x.shape
    assert m % block_m == 0 and n % block_n == 0
    xp = jnp.pad(x, 1)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _st2d_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # weights (3, 3)
            _halo_spec((block_m + 2, block_n + 2),
                       lambda i, j: (i * block_m, j * block_n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(weights, xp)


def _st3d_kernel(w_ref, x_ref, o_ref):
    xb = x_ref[...]  # (bd + 2, bm + 2, bn + 2)
    acc = jnp.zeros_like(o_ref)
    bd, bm, bn = o_ref.shape
    for dd in range(3):
        for di in range(3):
            for dj in range(3):
                acc = acc + w_ref[dd, di, dj] * jax.lax.dynamic_slice(
                    xb, (dd, di, dj), (bd, bm, bn))
    o_ref[...] = acc


def stencil3d_pallas(x, weights, *, block_d=8, block_m=128, block_n=128,
                     interpret=False):
    """3x3x3 stencil on (D, M, N), zero boundary."""
    d, m, n = x.shape
    assert d % block_d == 0 and m % block_m == 0 and n % block_n == 0
    xp = jnp.pad(x, 1)
    grid = (d // block_d, m // block_m, n // block_n)
    return pl.pallas_call(
        _st3d_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _halo_spec((block_d + 2, block_m + 2, block_n + 2),
                       lambda i, j, k: (i * block_d, j * block_m,
                                        k * block_n)),
        ],
        out_specs=pl.BlockSpec((block_d, block_m, block_n),
                               lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((d, m, n), x.dtype),
        interpret=interpret,
    )(weights, xp)
