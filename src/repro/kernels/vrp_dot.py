"""VRP compensated-reduction kernel — double-word dot/sum on the fly.

The VRP tile's HPDcache streams operands to the VPFPU at 16 B/cycle and the
tile is "typically limited by memory bandwidth rather than compute" — i.e.
extended precision is nearly free when fused into the streaming reduction.
This kernel is the TPU version of that claim: a single pass over HBM
accumulating a **two-term (double-word) expansion per vector lane** using
error-free transforms, so the extra precision costs only VPU flops (the
memory roofline term is unchanged vs a naive dot).

TPU has no f64, so the base dtype is f32 (2 x 24-bit significands ~ 48
bits, the TPU-native extended format; see DESIGN.md §2 item 4). Lane
partials (8, 128, 2) are finalized by ops.py with a compensated tree —
the same split the silicon makes between the streaming pipelines and the
full-width normalization stage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import TPUCompilerParams

from repro.core.vrp import two_prod, two_sum

_F32_SPLITTER = float(2**12 + 1)


def _accum(s_ref, c_ref, val):
    """Neumaier accumulation of ``val`` into (s, c) per lane."""
    s, err = two_sum(s_ref[...], val)
    s_ref[...] = s
    c_ref[...] = c_ref[...] + err


def _dot_kernel(x_ref, y_ref, o_ref, s_ref, c_ref, *, nb):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    p, e = two_prod(x_ref[0], y_ref[0], splitter=_F32_SPLITTER)
    _accum(s_ref, c_ref, p)
    c_ref[...] = c_ref[...] + e  # product error is already second-order

    @pl.when(j == nb - 1)
    def _store():
        o_ref[0, :, :, 0] = s_ref[...]
        o_ref[0, :, :, 1] = c_ref[...]


def vrp_dot_pallas(x, y, *, interpret=False):
    """Compensated dot of flat f32 vectors; n % 1024 == 0 (ops.py pads).

    Returns per-lane expansions (8, 128, 2); finalize with ops.finalize.
    """
    n = x.shape[0]
    assert n % 1024 == 0, "pad to lane multiple (VLA discipline) in ops.py"
    nb = n // 1024
    xr = x.reshape(nb, 8, 128)
    yr = y.reshape(nb, 8, 128)
    return pl.pallas_call(
        functools.partial(_dot_kernel, nb=nb),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 8, 128), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, 8, 128), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 8, 128, 2), lambda j: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 8, 128, 2), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xr, yr)[0]


def _sum_kernel(x_ref, o_ref, s_ref, c_ref, *, nb):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    _accum(s_ref, c_ref, x_ref[0])

    @pl.when(j == nb - 1)
    def _store():
        o_ref[0, :, :, 0] = s_ref[...]
        o_ref[0, :, :, 1] = c_ref[...]


def vrp_sum_pallas(x, *, interpret=False):
    """Compensated sum of a flat f32 vector; n % 1024 == 0."""
    n = x.shape[0]
    assert n % 1024 == 0
    nb = n // 1024
    return pl.pallas_call(
        functools.partial(_sum_kernel, nb=nb),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, 8, 128), lambda j: (j, 0, 0))],
        out_specs=pl.BlockSpec((1, 8, 128, 2), lambda j: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 8, 128, 2), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x.reshape(nb, 8, 128))[0]
