"""Chunked diagonal linear-recurrence kernel (RG-LRU / SSM state update).

h_t = a_t * h_{t-1} + x_t  — the core of RecurrentGemma's RG-LRU and the
normalizer updates of xLSTM. The access pattern is exactly an STX stencil
in time: static, local, streaming — so the same VMEM discipline applies.
Time is blocked; the carry h lives in a VMEM scratch across sequential
time blocks (grid dim 2, "arbitrary"), batch and feature dims are
parallel. Within a block the recurrence is a lax.fori_loop over VMEM-
resident data (FREP: repeated FP op sequence, no refetch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import TPUCompilerParams


def _scan_kernel(a_ref, x_ref, h0_ref, o_ref, h_ref, *, block_t: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        at = a_ref[:, t, :].astype(jnp.float32)
        xt = x_ref[:, t, :].astype(jnp.float32)
        h = at * h + xt
        o_ref[:, pl.ds(t, 1), :] = h[:, None, :].astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_t, step, h_ref[...])


def rglru_scan_pallas(a, x, h0=None, *, block_b=8, block_t=128, block_d=128,
                      interpret=False):
    """a, x: (B, T, D) -> h: (B, T, D); h_t = a_t h_{t-1} + x_t.

    B % block_b == 0, T % block_t == 0, D % block_d == 0 (ops.py pads).
    """
    B, T, D = x.shape
    assert B % block_b == 0 and T % block_t == 0 and D % block_d == 0
    if h0 is None:
        h0 = jnp.zeros((B, D), x.dtype)
    grid = (B // block_b, D // block_d, T // block_t)
    return pl.pallas_call(
        functools.partial(_scan_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_t, block_d),
                         lambda i, j, t: (i, t, j)),
            pl.BlockSpec((block_b, block_t, block_d),
                         lambda i, j, t: (i, t, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, t: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t, block_d),
                               lambda i, j, t: (i, t, j)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_d), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, x, h0)
