"""Paged KV cache: a shared pool of token blocks + per-sequence block tables.

The software analogue of EPAC's distributed L2 under programmable address
interleaving: physical storage is a pool of fixed-size blocks shared by
all decode slots, and a per-sequence *block table* maps logical token
positions to physical blocks. Sequences grow block-by-block and release
blocks on retirement, so cache memory scales with ``sum(len_i)`` instead
of ``num_slots * max_len``.

Layout per full-attention layer stack (count = layers in the scan group):

    k_pool, v_pool: (count, num_blocks, block_size, n_kv_heads, head_dim)

All layers of a sequence share ONE block table (same logical->physical
map, per-layer pools), the standard paged-attention arrangement.

Physical block 0 is reserved as the *null block*: retired/empty slots
point their table entries at it, so the shape-stable decode step can
scatter their (discarded) K/V writes somewhere harmless and the kernel's
prefetch index map never sees an out-of-range id. The allocator never
hands block 0 to a live sequence.

Device-side state is a pure pytree (functional updates under jit); the
``BlockAllocator`` is host-side bookkeeping owned by the scheduler.
"""

from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib

NULL_BLOCK = 0
NULL_ARENA = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


def rollback_tail(blocks: list, n_tokens: int, block_size: int) -> list:
    """Split off the blocks a sequence no longer needs after a rewind.

    The speculative verify step appends up to K+1 tokens to a slot's
    blocks and then rewinds the length pointer over the rejected tail —
    the paged cache's rollback is *just that pointer move* (rejected
    K/V stay in place, invisible past the length, overwritten in place
    when the sequence genuinely reaches those positions). What remains
    is returning surplus whole blocks: mutates ``blocks`` down to
    ``blocks_for(n_tokens)`` entries and returns the cut tail for
    ``BlockAllocator.free`` — no block contents are copied, ever.
    """
    keep = blocks_for(n_tokens, block_size)
    tail = blocks[keep:]
    del blocks[keep:]
    return tail


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of the paged cache (jit-static, hashable)."""

    num_slots: int           # decode batch width B
    num_blocks: int          # pool size incl. reserved null block 0
    block_size: int          # tokens per block
    max_len: int             # per-sequence position cap

    def __post_init__(self):
        assert self.num_blocks >= 2, "need >= 1 allocatable block + null"

    @property
    def max_blocks_per_seq(self) -> int:
        return blocks_for(self.max_len, self.block_size)

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1        # block 0 is the null block


class BlockAllocator:
    """Refcounting allocator over physical blocks 1..num_blocks-1.

    Every block is in exactly ONE of four states, and the partition is
    asserted after every transition (``check_invariant``):

    * **owned** — refcount >= 1: referenced by live slot tables. A block
      shared by N slots (prefix caching) carries refcount N; ``free``
      decrements and only the last reference releases the block.
    * **cached** LRU — refcount 0 but registered in a prefix index
      (``register``): kept resident so a future admission can re-hit it
      (``share`` revives it), reclaimed oldest-first ONLY when the free
      list runs dry (``on_evict`` tells the index to unlink it).
    * **free** — a plain FIFO: ``free`` appends to the tail, ``alloc``
      pops from the head, so a preempted victim's blocks are the LAST
      ones recycled and a resumed request can still re-hit its own
      just-evicted prefix (the old LIFO stack handed them straight to
      the preemptor, in reverse order).
    * the reserved null block 0 — never allocated, never freed.

    ``can_admit`` applies a free-block *watermark* so new sequences
    leave growth headroom, and ``select_victim`` encodes the preemption
    order (LIFO — the most recently admitted sequence is evicted first,
    so the oldest admission always runs to completion and the engine
    cannot livelock)."""

    def __init__(self, layout: PagedLayout, watermark: int = 0,
                 on_evict=None):
        self.layout = layout
        self.watermark = watermark
        self.on_evict = on_evict           # called with each reclaimed
        self._free = collections.deque(range(1, layout.num_blocks))
        self._refs: dict[int, int] = {}    # block -> live reference count
        self._cached: set[int] = set()     # registered in a prefix index
        # refcount-0 cached blocks, insertion-ordered: oldest first
        self._lru: collections.OrderedDict[int, None] = \
            collections.OrderedDict()

    @property
    def free_count(self) -> int:
        """Blocks allocatable right now (the plain free list plus the
        reclaimable cached LRU)."""
        return len(self._free) + len(self._lru)

    @property
    def used_count(self) -> int:
        """Blocks with at least one live reference."""
        return len(self._refs)

    @property
    def lru_count(self) -> int:
        """Unreferenced cached blocks awaiting re-hit or reclaim."""
        return len(self._lru)

    def refcount(self, b: int) -> int:
        return self._refs.get(b, 0)

    def can_alloc(self, n: int) -> bool:
        return n <= self.free_count

    def can_admit(self, n: int, *, strict: bool = True) -> bool:
        """Admission check for a NEW sequence needing ``n`` blocks now.

        ``strict`` keeps ``watermark`` blocks free as growth headroom for
        already-running sequences; callers pass ``strict=False`` when
        nothing else is running (the watermark must never starve a sole
        request — progress beats headroom)."""
        if not strict:
            return n <= self.free_count
        return n + self.watermark <= self.free_count

    @staticmethod
    def select_victim(candidates: list[tuple[int, int]]) -> int:
        """Pick the preemption victim from ``(slot, admission_ticket)``
        pairs: LIFO — highest ticket (latest admission) loses."""
        if not candidates:
            raise ValueError("no preemption candidates")
        return max(candidates, key=lambda c: c[1])[0]

    def alloc(self, n: int) -> list[int]:
        """Claim ``n`` exclusively-owned blocks (refcount 1 each),
        reclaiming the oldest unreferenced cached blocks only after the
        plain free list is exhausted."""
        if n > self.free_count:
            raise MemoryError(f"paged pool exhausted: want {n}, "
                              f"free {self.free_count}")
        out = []
        for _ in range(n):
            if self._free:
                b = self._free.popleft()
            else:                          # reclaim the oldest cached
                b, _ = self._lru.popitem(last=False)
                self._cached.discard(b)
                if self.on_evict is not None:
                    self.on_evict(b)
            self._refs[b] = 1
            out.append(b)
        self.check_invariant()
        return out

    def free(self, blocks: list[int]):
        """Drop one reference per block. The LAST reference releases the
        block: to the cached LRU when a prefix index registered it, else
        to the tail of the FIFO free list."""
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("freeing the reserved null block")
            r = self._refs.get(b, 0)
            if r <= 0:
                raise ValueError(f"double-free of block {b}")
            if r > 1:
                self._refs[b] = r - 1
            else:
                del self._refs[b]
                if b in self._cached:
                    self._lru[b] = None    # most recent at the tail
                else:
                    self._free.append(b)
        self.check_invariant()

    def share(self, b: int):
        """Take one more reference on a resident block: bump a live
        block's refcount, or revive an unreferenced cached block out of
        the LRU (a prefix-cache hit). Raises on free/unknown blocks."""
        if b in self._refs:
            self._refs[b] += 1
        elif b in self._lru:
            del self._lru[b]
            self._refs[b] = 1
        else:
            raise ValueError(f"sharing unreferenced block {b}")
        self.check_invariant()

    def register(self, b: int):
        """Mark a LIVE block as indexed by a prefix cache: when its last
        reference drops it parks in the LRU instead of the free list."""
        if b not in self._refs:
            raise ValueError(f"registering non-live block {b}")
        self._cached.add(b)

    def must_cow(self, b: int) -> bool:
        """True when an in-place write to ``b`` would be observable
        outside the writer: another slot holds a reference, or a prefix
        index could hand the block to a future admission."""
        return self._refs.get(b, 0) > 1 or b in self._cached

    def check_invariant(self):
        """owned ⊎ cached-LRU ⊎ free must partition blocks 1..N-1 (and
        the cached set may only mark resident blocks)."""
        owned, lru, free = set(self._refs), set(self._lru), set(self._free)
        if (owned & lru) or (owned & free) or (lru & free):
            raise AssertionError(
                f"allocator states overlap: owned∩lru={owned & lru} "
                f"owned∩free={owned & free} lru∩free={lru & free}")
        universe = set(range(1, self.layout.num_blocks))
        if (owned | lru | free) != universe:
            raise AssertionError(
                f"allocator lost blocks: missing "
                f"{universe - (owned | lru | free)}, "
                f"foreign {(owned | lru | free) - universe}")
        if not self._cached <= (owned | lru):
            raise AssertionError(
                f"cached marks non-resident blocks: "
                f"{self._cached - (owned | lru)}")
        if any(r < 1 for r in self._refs.values()):
            raise AssertionError("non-positive refcount")


class _PrefixNode:
    __slots__ = ("chunk", "block", "parent", "children")

    def __init__(self, chunk, block, parent):
        self.chunk = chunk
        self.block = block
        self.parent = parent              # None for root-level nodes
        self.children: dict = {}


class PrefixIndex:
    """Host-side trie mapping block-size token chunks to pool blocks.

    Each node keys one FULL block of token ids on the path from the
    sequence start and names the physical block whose K/V holds exactly
    those positions — K/V content for an attention layer depends only on
    the token ids and absolute positions of the prefix, so two requests
    sharing a prompt prefix can share the physical blocks (the serving
    analogue of EPAC's interleaved L2: one physical pool, many tiles'
    address maps pointing into it).

    The index is pure host bookkeeping and holds NO references of its
    own: the ``BlockAllocator`` keeps indexed blocks resident (cached
    LRU) and calls ``evict_block`` when it reclaims one. Insertion is
    first-wins — a chunk already indexed keeps its original block, and
    later copies of the same content stay private to their slot (they
    free normally). Evicting a node orphans its descendants: they can
    no longer be matched (matching walks from the root) and age out of
    the allocator's LRU like any other cold block.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self.children: dict = {}          # root: chunk tuple -> node
        self._by_block: dict[int, _PrefixNode] = {}

    def __len__(self) -> int:
        return len(self._by_block)

    def match(self, tokens) -> list[int]:
        """Physical blocks of the longest indexed chain of FULL
        block-size chunks prefixing ``tokens`` (possibly empty)."""
        bs = self.block_size
        out: list[int] = []
        kids = self.children
        for c in range(len(tokens) // bs):
            node = kids.get(tuple(tokens[c * bs:(c + 1) * bs]))
            if node is None:
                break
            out.append(node.block)
            kids = node.children
        return out

    def insert(self, tokens, blocks) -> list[int]:
        """Index ``blocks[c]`` under the c-th full chunk of ``tokens``
        (first-wins). Returns the block ids newly indexed — the caller
        must ``register`` exactly those with the allocator."""
        bs = self.block_size
        new: list[int] = []
        kids = self.children
        parent = None
        for c in range(min(len(tokens) // bs, len(blocks))):
            chunk = tuple(tokens[c * bs:(c + 1) * bs])
            node = kids.get(chunk)
            if node is None:
                node = _PrefixNode(chunk, blocks[c], parent)
                kids[chunk] = node
                self._by_block[blocks[c]] = node
                new.append(blocks[c])
            parent = node
            kids = node.children
        return new

    def evict_block(self, b: int):
        """Unlink the node indexing block ``b`` (allocator reclaim
        callback). Descendants become unmatchable orphans and are
        unlinked the same way when their blocks are reclaimed."""
        node = self._by_block.pop(b, None)
        if node is None:
            return
        kids = self.children if node.parent is None \
            else node.parent.children
        if kids.get(node.chunk) is node:
            del kids[node.chunk]


class CrossArena:
    """Refcounting allocator over cross-KV arena rows 1..num_arenas.

    Encoder-decoder requests carry STATIC per-request cross-attention
    K/V (a pure function of the encoder features, written once at
    admission by the encoder forward and read-only for the request's
    whole decode life). That state lives in a fixed *arena*: one row of
    ``(L, A+1, Hkv, enc_len, hd)`` per resident request, with row 0
    reserved as the null row (retired slots point at it; its contents
    are never read). This class is the host-side bookkeeping — the
    cross-pool analogue of ``BlockAllocator``, with the same refcount
    discipline so rows are SHAREABLE like prefix blocks: two live
    requests built from the *same* encoder-feature array (``key`` is the
    caller's identity key, e.g. ``id(features)``) share one row, because
    the encoder is deterministic and the row is write-once.

    States partition rows 1..A (asserted by ``check_invariant``):
    **owned** (refcount >= 1, keyed) ⊎ **free** (FIFO). There is no LRU
    tier — a row's content is recomputable from the request's features,
    so an unreferenced row is returned immediately.
    """

    def __init__(self, num_arenas: int):
        self.num_arenas = num_arenas
        self._free = collections.deque(range(1, num_arenas + 1))
        self._refs: dict[int, int] = {}      # row -> live reference count
        self._key_of: dict[int, object] = {}  # row -> identity key
        self._by_key: dict[object, int] = {}  # identity key -> row

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        """Arena rows with at least one live reference."""
        return len(self._refs)

    def refcount(self, a: int) -> int:
        return self._refs.get(a, 0)

    def can_admit(self, n: int) -> bool:
        """True when ``n`` fresh (non-shared) rows are allocatable."""
        return n <= len(self._free)

    def lookup(self, key) -> int:
        """Row currently holding ``key``'s cross-KV, or ``NULL_ARENA``."""
        return self._by_key.get(key, NULL_ARENA)

    def alloc(self, key=None) -> int:
        """Claim one exclusively-owned row (refcount 1), keyed for later
        ``lookup`` sharing when ``key`` is given."""
        if not self._free:
            raise MemoryError("cross-KV arena exhausted")
        a = self._free.popleft()
        self._refs[a] = 1
        if key is not None:
            self._key_of[a] = key
            self._by_key[key] = a
        self.check_invariant()
        return a

    def share(self, a: int) -> int:
        """Take one more reference on a live row (same-features request
        admitted while the original is resident). Raises on free rows —
        unlike pool blocks there is no LRU to revive from."""
        if a not in self._refs:
            raise ValueError(f"sharing unreferenced arena row {a}")
        self._refs[a] += 1
        return a

    def free(self, a: int):
        """Drop one reference; the LAST reference returns the row to the
        FIFO free list and unlinks its identity key."""
        if a == NULL_ARENA:
            raise ValueError("freeing the reserved null arena row")
        r = self._refs.get(a, 0)
        if r <= 0:
            raise ValueError(f"double-free of arena row {a}")
        if r > 1:
            self._refs[a] = r - 1
        else:
            del self._refs[a]
            key = self._key_of.pop(a, None)
            if key is not None:
                self._by_key.pop(key, None)
            self._free.append(a)
        self.check_invariant()

    def check_invariant(self):
        """owned ⊎ free must partition rows 1..A; key maps must mirror
        each other and only name owned rows."""
        owned, free = set(self._refs), set(self._free)
        if owned & free:
            raise AssertionError(f"arena states overlap: {owned & free}")
        universe = set(range(1, self.num_arenas + 1))
        if (owned | free) != universe:
            raise AssertionError(
                f"arena lost rows: missing {universe - (owned | free)}, "
                f"foreign {(owned | free) - universe}")
        if not set(self._key_of) <= owned:
            raise AssertionError("keys on non-owned arena rows")
        if {self._by_key[k]: k for k in self._by_key} != self._key_of:
            raise AssertionError("arena key maps out of sync")
        if any(r < 1 for r in self._refs.values()):
            raise AssertionError("non-positive arena refcount")


def head_shard_ok(cfg, tp_size: int) -> bool:
    """True when the head-sharded pool layout is exact for this model:
    each device of the TP axis owns a whole kv-head shard of every block
    (and the matching query-head groups), so the per-device paged
    attention needs no collective. GQA group alignment follows from both
    divisibilities: device i's query heads [i*Hq/t, (i+1)*Hq/t) map onto
    exactly its kv heads [i*Hkv/t, (i+1)*Hkv/t)."""
    return (tp_size > 1 and cfg.n_heads % tp_size == 0
            and cfg.n_kv_heads % tp_size == 0)


# ---------------------------------------------------------------------------
# Pool format: PoolSpec + KV quantization
# ---------------------------------------------------------------------------

KV_DTYPES = ("bf16", "int8", "fp8")

# fp8 e4m3 saturates at +-448; values past it cast to NaN, not inf, so
# the quantizer must clip BEFORE the dtype cast.
_FP8_MAX = 448.0


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Static description of one paged pool's physical block format.

    The single source of truth for how K/V blocks are stored: the
    payload dtype (``bf16`` keeps the model compute dtype; ``int8`` /
    ``fp8`` store a low-precision payload plus per-(token-row, kv-head)
    f32 scales as extra ``k_scale``/``v_scale`` pool leaves), the block
    geometry, the physical head dim (``padded_head_dim`` pads blocks to
    the TPU lane width so real-hardware tiling is honest — 0 means
    unpadded), and whether the pool is head-sharded over TP. Frozen and
    hashable, so it rides through jit as a static argument and through
    ``transport.MigrationPacket`` as the format tag both ends must
    agree on. ``kv_dtype="bf16"`` with no padding reproduces today's
    pool tree byte-for-byte (no scale leaves, same shapes) — the
    bit-identity contract for the fp path.
    """

    kv_dtype: str = "bf16"                # "bf16" | "int8" | "fp8"
    scale_layout: str = "token_head"      # scales per (token row, kv head)
    block_size: int = 16
    n_kv_heads: int = 1
    head_dim: int = 64
    padded_head_dim: int = 0              # 0 = no lane padding
    head_sharded: bool = False

    def __post_init__(self):
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                             f"got {self.kv_dtype!r}")
        if self.padded_head_dim and self.padded_head_dim < self.head_dim:
            raise ValueError("padded_head_dim < head_dim")

    @property
    def quantized(self) -> bool:
        return self.kv_dtype != "bf16"

    @property
    def store_dtype(self):
        """Payload dtype blocks are stored in (None = the cache dtype)."""
        if self.kv_dtype == "int8":
            return jnp.int8
        if self.kv_dtype == "fp8":
            return jnp.float8_e4m3fn
        return None

    @property
    def qmax(self) -> float:
        """Largest representable payload magnitude (scale denominator)."""
        return 127.0 if self.kv_dtype == "int8" else _FP8_MAX

    @property
    def pool_head_dim(self) -> int:
        """Physical last-axis width of pool blocks (lane-padded or not)."""
        return self.padded_head_dim or self.head_dim


def make_pool_spec(cfg, layout: PagedLayout, *, kv_dtype: str = "bf16",
                   padded_head_dim: int = 0,
                   head_sharded: bool = False) -> PoolSpec:
    """Build the ``PoolSpec`` for a model config + paged layout."""
    return PoolSpec(kv_dtype=kv_dtype, block_size=layout.block_size,
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                    padded_head_dim=padded_head_dim,
                    head_sharded=head_sharded)


def quantize_kv(x, spec: PoolSpec):
    """Quantize K or V rows to the spec's payload dtype + scales.

    x: (..., Hkv, D) fp rows. Returns ``(payload, scale)`` with payload
    shaped like x in ``spec.store_dtype`` and scale ``(..., Hkv)`` f32 —
    one absmax scale per (token row, kv head), so a one-token decode
    append is self-contained and never requantizes its block. Zero rows
    keep scale 0 with a divide guard (payload 0, dequant exact). int8
    rounds to nearest; fp8 clips to +-448 before the cast (overflow
    would produce NaN, not saturation)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / spec.qmax
    q = xf / jnp.where(scale > 0, scale, 1.0)[..., None]
    q = jnp.clip(q, -spec.qmax, spec.qmax)
    if spec.kv_dtype == "int8":
        q = jnp.round(q)
    return q.astype(spec.store_dtype), scale


def dequantize_kv(payload, scale):
    """Inverse of ``quantize_kv``: f32 rows from payload + scales."""
    return payload.astype(jnp.float32) * scale[..., None]


def _pad_head_dim(x, hd_pool: int):
    """Zero-pad the last axis of K/V rows to the pool's physical width."""
    pad = hd_pool - x.shape[-1]
    if pad <= 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def write_kv_rows(pool, phys, off, k, v, spec: PoolSpec = None):
    """Scatter new K/V rows at the decode/verify append frontier.

    pool: a pool dict ({"k","v"} plus ``k_scale``/``v_scale`` when
    quantized); phys/off: integer index arrays selecting (block, slot-
    in-block) per row; k/v: (..., Hkv, D) new rows, index arrays
    broadcasting over the leading dims. With a quantized spec the rows
    are quantized per (row, head) and the scales land at the same
    (phys, off) coordinates; with ``spec=None`` / bf16 this is exactly
    the historical two-scatter update."""
    if spec is not None:
        k = _pad_head_dim(k, spec.pool_head_dim)
        v = _pad_head_dim(v, spec.pool_head_dim)
    if spec is None or not spec.quantized:
        return dict(pool, k=pool["k"].at[phys, off].set(
                        k.astype(pool["k"].dtype)),
                    v=pool["v"].at[phys, off].set(
                        v.astype(pool["v"].dtype)))
    kq, ks = quantize_kv(k, spec)
    vq, vs = quantize_kv(v, spec)
    return dict(pool,
                k=pool["k"].at[phys, off].set(kq),
                v=pool["v"].at[phys, off].set(vq),
                k_scale=pool["k_scale"].at[phys, off].set(ks),
                v_scale=pool["v_scale"].at[phys, off].set(vs))


# ---------------------------------------------------------------------------
# Device-side pytree init / prefill packing
# ---------------------------------------------------------------------------


def init_layer_pool(cfg, layout: PagedLayout, dtype, *, window=None,
                    spec: PoolSpec = None):
    """Per-layer cache for the paged engine. Full-attention layers get a
    block pool; windowed layers keep a per-slot ring buffer (their state
    is bounded at ``window`` tokens — paging buys nothing); callers route
    SSM kinds to their existing per-slot state inits. ``spec`` selects
    the pool block format: a quantized ``PoolSpec`` stores low-precision
    payloads plus per-(row, head) f32 ``k_scale``/``v_scale`` leaves
    ``(NB, BS, Hkv)``; ``None`` (or a bf16 spec without padding) yields
    the identical tree to before the spec existed."""
    if window:
        return attn_lib.init_kv_cache(cfg, layout.num_slots, layout.max_len,
                                      dtype, window=window)
    hd = spec.pool_head_dim if spec is not None else cfg.head_dim
    shape = (layout.num_blocks, layout.block_size, cfg.n_kv_heads, hd)
    if spec is None or not spec.quantized:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    sshape = shape[:-1]
    return {"k": jnp.zeros(shape, spec.store_dtype),
            "v": jnp.zeros(shape, spec.store_dtype),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32)}


def init_cross_arena(cfg, layout: PagedLayout, dtype):
    """Cross-attention K/V arena for encoder-decoder serving.

    One row per resident request plus the reserved null row 0:
    ``{"k","v"}`` of ``(n_layers, num_slots + 1, Hkv, encoder_len, hd)``.
    Rows are written ONCE at admission (the encoder forward runs inside
    the prefill jit and scatters each layer's cross K/V, right-padded
    from the frame bucket to ``encoder_len``) and read every decode step
    by the cross-attention layers, masked to the request's true encoder
    length. Host bookkeeping lives in ``CrossArena``.
    """
    shape = (cfg.n_layers, layout.num_slots + 1, cfg.n_kv_heads,
             cfg.encoder_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def pack_cross_arena(arena, cross_kv, arena_ids):
    """Scatter freshly encoded cross-KV rows into the arena.

    arena: {"k","v"} of (L, A+1, Hkv, enc_len, hd); cross_kv: {"k","v"}
    of (L, N, Hkv, Fb, hd) with the frame bucket Fb <= enc_len
    (right-padded here with zeros — reads are masked to the true
    length); arena_ids: (N,) int32 destination rows. Prefill-batch
    filler rows point at the reserved null row 0, where their writes
    collide harmlessly (the ``pack_prefill_kv`` argument); duplicate
    REAL ids only occur for identity-shared features, whose rows are
    bit-identical (deterministic encoder), so collision order there is
    unobservable too."""
    # .at[:, ids] indexes axis 1 with (N,) ids and expects the update
    # shaped (L, N, Hkv, enc_len, hd) — cross_kv already matches.
    def put(a, c):
        pad = a.shape[3] - c.shape[3]
        if pad:
            widths = [(0, 0)] * c.ndim
            widths[3] = (0, pad)
            c = jnp.pad(c, widths)
        return a.at[:, arena_ids].set(c)

    return {"k": put(arena["k"], cross_kv["k"]),
            "v": put(arena["v"], cross_kv["v"])}


def init_slot_tables(layout: PagedLayout):
    """(block_table, lengths) device arrays, all slots empty/null."""
    table = jnp.full((layout.num_slots, layout.max_blocks_per_seq),
                     NULL_BLOCK, jnp.int32)
    lengths = jnp.zeros((layout.num_slots,), jnp.int32)
    return table, lengths


def pack_prefill_kv(pool, dense_kv, block_ids, block_size,
                    spec: PoolSpec = None):
    """Scatter a batch of prefilled dense caches into pool blocks.

    pool: {"k","v"} of (..., NB, BS, Hkv, D); dense_kv: {"k","v"} of
    (..., N, S, Hkv, D) with S == block_ids.shape[1] * BS (kernels/ops
    pads prefill caches with zeros past each row's true length);
    block_ids: (N, nbp) int32 physical destinations, one row per
    prefilled sequence. Leading dims (stacked layers) broadcast. With a
    quantized ``spec`` the dense rows are quantized per (token, head)
    and the scales scatter into the pool's ``k_scale``/``v_scale``
    leaves through the same flat block indices.

    Rows' REAL blocks are disjoint (the allocator hands each sequence its
    own); pad-tail and batch-filler entries all point at the reserved
    null block, so their writes collide there in unspecified order —
    harmless, because null-block contents are only ever read masked.
    """
    if block_ids.ndim == 1:               # single-sequence convenience
        block_ids = block_ids[None]       # dense rows already carry N=1
    n, nbp = block_ids.shape
    flat = block_ids.reshape(-1)

    def put(p, d):
        lead = p.shape[:-4]
        hkv, hd = p.shape[-2:]
        d = d.reshape(lead + (n * nbp, block_size, hkv, hd))
        return p.at[..., flat, :, :, :].set(d.astype(p.dtype))

    if spec is not None and spec.pool_head_dim != dense_kv["k"].shape[-1]:
        dense_kv = {"k": _pad_head_dim(dense_kv["k"], spec.pool_head_dim),
                    "v": _pad_head_dim(dense_kv["v"], spec.pool_head_dim)}
    if spec is None or not spec.quantized:
        return dict(pool, k=put(pool["k"], dense_kv["k"]),
                    v=put(pool["v"], dense_kv["v"]))

    def put_scale(p, s):
        lead = p.shape[:-3]
        hkv = p.shape[-1]
        s = s.reshape(lead + (n * nbp, block_size, hkv))
        return p.at[..., flat, :, :].set(s)

    kq, ks = quantize_kv(dense_kv["k"], spec)
    vq, vs = quantize_kv(dense_kv["v"], spec)
    return dict(pool, k=put(pool["k"], kq), v=put(pool["v"], vq),
                k_scale=put_scale(pool["k_scale"], ks),
                v_scale=put_scale(pool["v_scale"], vs))


def _select_slots(state, dense, row_of_slot, valid, batch_axis):
    """Gather-select install of per-slot decode state: slot s takes
    ``dense`` row ``row_of_slot[s]`` where ``valid[s]``, else keeps its
    current state. A gather + where instead of a scatter because scatter
    with duplicate indices applies updates in unspecified order, while
    this is exact for any (row_of_slot, valid)."""
    g = jnp.take(dense, row_of_slot, axis=batch_axis)
    shape = [1] * state.ndim
    shape[batch_axis] = -1
    return jnp.where(valid.reshape(shape), g, state)


def pack_prefill_ring(ring, dense_ring, row_of_slot, valid):
    """Install a batch of prefilled ring caches into per-slot storage.

    ring: (..., B, size_e, Hkv, D); dense_ring: (..., N, size_p, Hkv, D)
    with size_p <= size_e. When the prompt is shorter than the ring the
    prefill cache is zero-padded at the tail — those slots are masked by
    the position-validity predicate until decode overwrites them. When the
    prompt wrapped, size_p == size_e and ring order (slot = pos % size)
    already matches the decode discipline, so a direct copy is exact.
    """
    size_p = dense_ring.shape[-3]
    size_e = ring.shape[-3]
    pad = size_e - size_p
    if pad:
        widths = [(0, 0)] * dense_ring.ndim
        widths[-3] = (0, pad)
        dense_ring = jnp.pad(dense_ring, widths)
    return _select_slots(ring, dense_ring, row_of_slot, valid,
                         batch_axis=ring.ndim - 4)


def pack_prefill_state(state, dense_state, row_of_slot, valid):
    """Install a batch of SSM/conv decode states into per-slot storage.

    Both sides come from ``init_*_cache``-shaped stacked trees: a leading
    layer-count axis, then the batch axis — so the slot/batch axis is
    axis 1 on every leaf (rglru h (L, B, dr), conv (L, B, w-1, d),
    mlstm C (L, B, H, hd, hd), slstm c (L, B, H, hd), ...)."""
    return jax.tree.map(
        lambda s, d: _select_slots(s, d, row_of_slot, valid, batch_axis=1),
        state, dense_state)


def extract_blocks(pools, kinds, block_ids, slot, arena=NULL_ARENA):
    """Gather ONE slot's migratable cache out of a paged tree.

    ``kinds`` is a same-structure tree of kind strings (built by
    ``transformer.paged_pool_mask`` / the encdec equivalent — classified
    by LAYER KIND, never by shape): ``"pool"`` leaves
    ``(L, NB, BS, Hkv, D)`` gather the ``block_ids`` rows along the
    block axis (axis 1, after the stacked layer-count axis — the same
    convention ``pack_prefill_kv`` and the COW copy write through);
    ``"slot"`` leaves (rings, SSM carries, conv tails — slot axis also
    at axis 1) take the slot's own row; ``"cross"`` leaves (the cross-KV
    arena, arena-row axis at axis 1) take row ``arena`` instead — a
    slot's arena row is an indirection through the scheduler's
    ``arena_ids``, not the slot index. Single rows are kept at size 1 so
    every leaf preserves its rank (and therefore its PartitionSpec)
    across the migration. ``block_ids`` is padded to a fixed width with
    the null block so the jit traces ONCE per engine; pad rows carry
    null-block content and land back in the destination's null block on
    insert. Pure function of its inputs — the source pool is never
    mutated, so the caller may free the source blocks in any order
    relative to this gather."""
    def one(leaf, kind):
        if kind == "pool":
            return jnp.take(leaf, block_ids, axis=1)
        row = arena if kind == "cross" else slot
        return jax.lax.dynamic_slice_in_dim(leaf, row, 1, axis=1)

    return jax.tree.map(one, pools, kinds)


def insert_blocks(pools, kinds, packet, block_ids, slot, arena=NULL_ARENA):
    """Scatter an ``extract_blocks`` packet into a destination tree.

    The inverse of ``extract_blocks`` against a DIFFERENT pool: pool
    leaves scatter the packet's block rows into freshly allocated
    ``block_ids`` (pad entries point at the null block, where their
    null-content writes collide harmlessly — the ``pack_prefill_kv``
    argument); ``"slot"`` leaves overwrite the destination slot's row
    and ``"cross"`` leaves the destination's freshly allocated ``arena``
    row. Donatable: the caller's jit donates ``pools``."""
    def one(leaf, kind, pk):
        if kind == "pool":
            return leaf.at[:, block_ids].set(pk)
        row = arena if kind == "cross" else slot
        return jax.lax.dynamic_update_slice_in_dim(leaf, pk, row, axis=1)

    return jax.tree.map(one, pools, kinds, packet)


__all__ = [
    "KV_DTYPES", "NULL_ARENA", "NULL_BLOCK", "CrossArena", "PagedLayout",
    "BlockAllocator", "PoolSpec", "PrefixIndex", "blocks_for",
    "dequantize_kv", "extract_blocks", "head_shard_ok",
    "init_cross_arena", "init_layer_pool", "init_slot_tables",
    "insert_blocks", "make_pool_spec", "pack_cross_arena",
    "pack_prefill_kv", "pack_prefill_ring", "pack_prefill_state",
    "quantize_kv", "rollback_tail", "write_kv_rows",
]
