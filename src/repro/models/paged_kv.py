"""Paged KV cache: a shared pool of token blocks + per-sequence block tables.

The software analogue of EPAC's distributed L2 under programmable address
interleaving: physical storage is a pool of fixed-size blocks shared by
all decode slots, and a per-sequence *block table* maps logical token
positions to physical blocks. Sequences grow block-by-block and release
blocks on retirement, so cache memory scales with ``sum(len_i)`` instead
of ``num_slots * max_len``.

Layout per full-attention layer stack (count = layers in the scan group):

    k_pool, v_pool: (count, num_blocks, block_size, n_kv_heads, head_dim)

All layers of a sequence share ONE block table (same logical->physical
map, per-layer pools), the standard paged-attention arrangement.

Physical block 0 is reserved as the *null block*: retired/empty slots
point their table entries at it, so the shape-stable decode step can
scatter their (discarded) K/V writes somewhere harmless and the kernel's
prefetch index map never sees an out-of-range id. The allocator never
hands block 0 to a live sequence.

Device-side state is a pure pytree (functional updates under jit); the
``BlockAllocator`` is host-side bookkeeping owned by the scheduler.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib

NULL_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


def rollback_tail(blocks: list, n_tokens: int, block_size: int) -> list:
    """Split off the blocks a sequence no longer needs after a rewind.

    The speculative verify step appends up to K+1 tokens to a slot's
    blocks and then rewinds the length pointer over the rejected tail —
    the paged cache's rollback is *just that pointer move* (rejected
    K/V stay in place, invisible past the length, overwritten in place
    when the sequence genuinely reaches those positions). What remains
    is returning surplus whole blocks: mutates ``blocks`` down to
    ``blocks_for(n_tokens)`` entries and returns the cut tail for
    ``BlockAllocator.free`` — no block contents are copied, ever.
    """
    keep = blocks_for(n_tokens, block_size)
    tail = blocks[keep:]
    del blocks[keep:]
    return tail


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of the paged cache (jit-static, hashable)."""

    num_slots: int           # decode batch width B
    num_blocks: int          # pool size incl. reserved null block 0
    block_size: int          # tokens per block
    max_len: int             # per-sequence position cap

    def __post_init__(self):
        assert self.num_blocks >= 2, "need >= 1 allocatable block + null"

    @property
    def max_blocks_per_seq(self) -> int:
        return blocks_for(self.max_len, self.block_size)

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1        # block 0 is the null block


class BlockAllocator:
    """Host-side free-list allocator over physical blocks 1..num_blocks-1.

    Tracks ownership so double-frees and leaks are detectable (the
    scheduler invariant tests rely on this). Supports the optimistic
    admission policy of the serving engine: ``can_admit`` applies a
    free-block *watermark* so new sequences leave headroom for the
    in-flight ones to grow, and ``select_victim`` encodes the preemption
    order (LIFO — the most recently admitted sequence is evicted first,
    so the oldest admission always runs to completion and the engine
    cannot livelock)."""

    def __init__(self, layout: PagedLayout, watermark: int = 0):
        self.layout = layout
        self.watermark = watermark
        self._free = list(range(layout.num_blocks - 1, 0, -1))  # pop -> 1,2,..
        self._owned: set[int] = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._owned)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def can_admit(self, n: int, *, strict: bool = True) -> bool:
        """Admission check for a NEW sequence needing ``n`` blocks now.

        ``strict`` keeps ``watermark`` blocks free as growth headroom for
        already-running sequences; callers pass ``strict=False`` when
        nothing else is running (the watermark must never starve a sole
        request — progress beats headroom)."""
        if not strict:
            return n <= len(self._free)
        return n + self.watermark <= len(self._free)

    @staticmethod
    def select_victim(candidates: list[tuple[int, int]]) -> int:
        """Pick the preemption victim from ``(slot, admission_ticket)``
        pairs: LIFO — highest ticket (latest admission) loses."""
        if not candidates:
            raise ValueError("no preemption candidates")
        return max(candidates, key=lambda c: c[1])[0]

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise MemoryError(f"paged pool exhausted: want {n}, "
                              f"free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._owned.update(out)
        return out

    def free(self, blocks: list[int]):
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("freeing the reserved null block")
            if b not in self._owned:
                raise ValueError(f"double-free of block {b}")
            self._owned.discard(b)
            self._free.append(b)


def head_shard_ok(cfg, tp_size: int) -> bool:
    """True when the head-sharded pool layout is exact for this model:
    each device of the TP axis owns a whole kv-head shard of every block
    (and the matching query-head groups), so the per-device paged
    attention needs no collective. GQA group alignment follows from both
    divisibilities: device i's query heads [i*Hq/t, (i+1)*Hq/t) map onto
    exactly its kv heads [i*Hkv/t, (i+1)*Hkv/t)."""
    return (tp_size > 1 and cfg.n_heads % tp_size == 0
            and cfg.n_kv_heads % tp_size == 0)


# ---------------------------------------------------------------------------
# Device-side pytree init / prefill packing
# ---------------------------------------------------------------------------


def init_layer_pool(cfg, layout: PagedLayout, dtype, *, window=None):
    """Per-layer cache for the paged engine. Full-attention layers get a
    block pool; windowed layers keep a per-slot ring buffer (their state
    is bounded at ``window`` tokens — paging buys nothing); callers route
    SSM kinds to their existing per-slot state inits."""
    if window:
        return attn_lib.init_kv_cache(cfg, layout.num_slots, layout.max_len,
                                      dtype, window=window)
    shape = (layout.num_blocks, layout.block_size, cfg.n_kv_heads,
             cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_slot_tables(layout: PagedLayout):
    """(block_table, lengths) device arrays, all slots empty/null."""
    table = jnp.full((layout.num_slots, layout.max_blocks_per_seq),
                     NULL_BLOCK, jnp.int32)
    lengths = jnp.zeros((layout.num_slots,), jnp.int32)
    return table, lengths


def pack_prefill_kv(pool, dense_kv, block_ids, block_size):
    """Scatter a batch of prefilled dense caches into pool blocks.

    pool: {"k","v"} of (..., NB, BS, Hkv, D); dense_kv: {"k","v"} of
    (..., N, S, Hkv, D) with S == block_ids.shape[1] * BS (kernels/ops
    pads prefill caches with zeros past each row's true length);
    block_ids: (N, nbp) int32 physical destinations, one row per
    prefilled sequence. Leading dims (stacked layers) broadcast.

    Rows' REAL blocks are disjoint (the allocator hands each sequence its
    own); pad-tail and batch-filler entries all point at the reserved
    null block, so their writes collide there in unspecified order —
    harmless, because null-block contents are only ever read masked.
    """
    if block_ids.ndim == 1:               # single-sequence convenience
        block_ids = block_ids[None]       # dense rows already carry N=1
    n, nbp = block_ids.shape
    flat = block_ids.reshape(-1)

    def put(p, d):
        lead = p.shape[:-4]
        hkv, hd = p.shape[-2:]
        d = d.reshape(lead + (n * nbp, block_size, hkv, hd))
        return p.at[..., flat, :, :, :].set(d)

    return {"k": put(pool["k"], dense_kv["k"]),
            "v": put(pool["v"], dense_kv["v"])}


def _select_slots(state, dense, row_of_slot, valid, batch_axis):
    """Gather-select install of per-slot decode state: slot s takes
    ``dense`` row ``row_of_slot[s]`` where ``valid[s]``, else keeps its
    current state. A gather + where instead of a scatter because scatter
    with duplicate indices applies updates in unspecified order, while
    this is exact for any (row_of_slot, valid)."""
    g = jnp.take(dense, row_of_slot, axis=batch_axis)
    shape = [1] * state.ndim
    shape[batch_axis] = -1
    return jnp.where(valid.reshape(shape), g, state)


def pack_prefill_ring(ring, dense_ring, row_of_slot, valid):
    """Install a batch of prefilled ring caches into per-slot storage.

    ring: (..., B, size_e, Hkv, D); dense_ring: (..., N, size_p, Hkv, D)
    with size_p <= size_e. When the prompt is shorter than the ring the
    prefill cache is zero-padded at the tail — those slots are masked by
    the position-validity predicate until decode overwrites them. When the
    prompt wrapped, size_p == size_e and ring order (slot = pos % size)
    already matches the decode discipline, so a direct copy is exact.
    """
    size_p = dense_ring.shape[-3]
    size_e = ring.shape[-3]
    pad = size_e - size_p
    if pad:
        widths = [(0, 0)] * dense_ring.ndim
        widths[-3] = (0, pad)
        dense_ring = jnp.pad(dense_ring, widths)
    return _select_slots(ring, dense_ring, row_of_slot, valid,
                         batch_axis=ring.ndim - 4)


def pack_prefill_state(state, dense_state, row_of_slot, valid):
    """Install a batch of SSM/conv decode states into per-slot storage.

    Both sides come from ``init_*_cache``-shaped stacked trees: a leading
    layer-count axis, then the batch axis — so the slot/batch axis is
    axis 1 on every leaf (rglru h (L, B, dr), conv (L, B, w-1, d),
    mlstm C (L, B, H, hd, hd), slstm c (L, B, H, hd), ...)."""
    return jax.tree.map(
        lambda s, d: _select_slots(s, d, row_of_slot, valid, batch_axis=1),
        state, dense_state)


__all__ = [
    "NULL_BLOCK", "PagedLayout", "BlockAllocator", "blocks_for",
    "head_shard_ok", "init_layer_pool", "init_slot_tables",
    "pack_prefill_kv", "pack_prefill_ring", "pack_prefill_state",
    "rollback_tail",
]
