"""Mixture-of-Experts with expert parallelism (EP) over the model axis.

Dispatch strategy (the uncore analogy): tokens are the NoC "flits" and
experts the distributed L2 slices — the router computes the interleaving.
We use a sort-based capacity dispatch (no (T, E, C) one-hot tensor, which
is O(T*E*C) memory and infeasible at kimi-k2 scale):

  1. route: top-k expert ids + weights per token (router replicated),
  2. sort assignments by expert id; position-within-expert via cumsum,
  3. gather up to C tokens per *local* expert into (E_local, C, d),
  4. three grouped einsums (gated FFN),
  5. scatter-add back with routing weights; psum over the model axis.

Two code paths with identical math: ``apply_moe`` (single-device: all
experts local) and ``apply_moe_sharded`` (shard_map: experts sharded over
the TP axis, expert weights FSDP-gathered over the DP axes on use).
tests/test_moe.py checks local == sharded on a multi-device CPU mesh.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.compat import shard_map
from repro.models import layers


def init_moe(key, cfg, dtype):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    return {
        "router": layers.truncated_normal_init(ks[0], (d, E), jnp.float32),
        "w1": (std * jax.random.truncated_normal(ks[1], -2, 2, (E, d, ff))).astype(dtype),
        "w3": (std * jax.random.truncated_normal(ks[2], -2, 2, (E, d, ff))).astype(dtype),
        "w2": (1.0 / math.sqrt(ff) * jax.random.truncated_normal(
            ks[3], -2, 2, (E, ff, d))).astype(dtype),
    }


def route(x2d, router_w, top_k: int, *, normalize=True):
    """x2d: (T, d) -> (ids (T,k), weights (T,k) f32, load (E,), imp (E,)).

    The Switch aux loss E*sum(load*imp) is computed by the CALLER so that
    sharded paths can pmean load/imp across shards BEFORE the (nonlinear)
    product — per-shard aux values do not average to the global aux.
    """
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)
    if normalize:
        topw = topw / jnp.sum(topw, -1, keepdims=True)
    E = router_w.shape[-1]
    load = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return topi, topw, load, imp


def aux_loss(load, imp):
    return load.shape[-1] * jnp.sum(load * imp)


def _dispatch_indices(topi, top_k: int, n_experts: int, capacity: int):
    """Sorted assignment bookkeeping shared by both paths.

    Returns (sorted expert id, sorted token id, sorted weight index,
    position-within-expert) — all (T*k,).
    """
    T = topi.shape[0]
    flat_e = topi.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    return se, st, order, pos


def _expert_ffn(xg, w1, w3, w2, activation="silu"):
    """xg: (E, C, d) through per-expert gated FFN."""
    act = {"silu": jax.nn.silu, "gelu": lambda v: jax.nn.gelu(v, approximate=True)}[activation]
    h = act(jnp.einsum("ecd,edf->ecf", xg, w1)) * jnp.einsum("ecd,edf->ecf", xg, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _capacity(cfg, T: int, dropless: bool) -> int:
    """Tokens each expert can take. ``dropless`` sizes the buffers so NO
    assignment ever overflows (an expert holds at most T tokens — top-k
    ids are distinct per token): per-token math then depends only on
    that token's own hidden state, which is what the serving engine's
    identity contract needs — outputs independent of right-padding,
    co-batched traffic and batch width. Training keeps the
    capacity-factor drop semantics (the Switch efficiency/auxiliary
    story needs over-capacity tokens to actually drop)."""
    if dropless:
        return T
    return max(1, int(math.ceil(T * cfg.moe_top_k / cfg.n_experts
                                * cfg.moe_capacity_factor)))


def _moe_math(x2d, params_router, w1, w3, w2, cfg, e_lo: int, e_local: int,
              dropless: bool = False):
    """Shared dispatch->compute->combine on one device's experts.

    x2d: (T, d). Experts [e_lo, e_lo + e_local) live here. Returns the
    *partial* output (T, d) (sum over local experts only) plus aux loss.
    """
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    C = _capacity(cfg, T, dropless)
    topi, topw, load, imp = route(x2d, params_router, k)
    se, st, order, pos = _dispatch_indices(topi, k, E, C)
    sw = topw.reshape(-1)[order]

    local = jnp.logical_and(se >= e_lo, se < e_lo + e_local)
    valid = jnp.logical_and(local, pos < C)
    slot = jnp.where(valid, (se - e_lo) * C + pos, e_local * C)  # overflow row

    xg = jnp.zeros((e_local * C + 1, d), x2d.dtype).at[slot].set(x2d[st])
    yg = _expert_ffn(xg[:-1].reshape(e_local, C, d), w1, w3, w2,
                     cfg.activation)
    yg = yg.reshape(e_local * C, d)
    contrib = jnp.where(valid[:, None], yg[jnp.minimum(slot, e_local * C - 1)]
                        * sw[:, None].astype(yg.dtype), 0.0)
    out = jnp.zeros((T, d), yg.dtype).at[st].add(contrib)
    return out.astype(x2d.dtype), (load, imp)


def apply_moe(params, cfg, x, dropless: bool = False):
    """Single-device MoE. x: (B, S, d) -> (out, aux). ``dropless``
    disables capacity dropping (serving paths — see ``_capacity``)."""
    B, S, d = x.shape
    out, (load, imp) = _moe_math(x.reshape(-1, d), params["router"],
                                 params["w1"], params["w3"], params["w2"],
                                 cfg, 0, cfg.n_experts, dropless=dropless)
    return out.reshape(B, S, d), aux_loss(load, imp)


def _dp_index(dp):
    """Linear index over a (possibly composite) DP axis tuple."""
    idx = jax.lax.axis_index(dp[0])
    for a in dp[1:]:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def apply_moe_sharded(params, cfg, x, shard, mode: str = "gather",
                      dropless: bool = False):
    """EP MoE under shard_map. Two collective schedules:

    'gather'  (baseline, paper-faithful FSDP): expert weights are
        all-gathered over DP on use, full-d contraction, output psum over
        TP. Weight traffic per layer ~ 3 x |experts_local| x d x ff.
    'partial' (§Perf hillclimb): weights stay DP-sharded; the contraction
        runs on each device's d-slice and ACTIVATION partial sums move
        instead (h psums over DP, output all-gather over DP). For kimi-k2
        this trades ~6.3 GB/layer of weight gathers for ~0.8 GB/layer of
        activation traffic — the EPAC lesson that the NoC should move the
        smaller operand.
    """
    from jax.sharding import PartitionSpec as P

    mesh = shard.mesh
    dp, tp = shard.dp_axes, shard.tp_axis
    tp_size = mesh.shape[tp]
    assert cfg.n_experts % tp_size == 0, (cfg.n_experts, tp_size)
    e_local = cfg.n_experts // tp_size

    def local_gather(x_l, router, w1_l, w3_l, w2_l):
        B_l, S_l, d = x_l.shape
        w1 = jax.lax.all_gather(w1_l, dp, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3_l, dp, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2_l, dp, axis=2, tiled=True)
        e_lo = jax.lax.axis_index(tp) * e_local
        out, (load, imp) = _moe_math(x_l.reshape(-1, d), router, w1, w3, w2,
                                     cfg, e_lo, e_local, dropless=dropless)
        out = jax.lax.psum(out, tp)
        load = jax.lax.pmean(load, dp)   # identical over tp already
        imp = jax.lax.pmean(imp, dp)
        return out.reshape(B_l, S_l, d), aux_loss(load, imp)

    def local_partial(x_l, router, w1_l, w3_l, w2_l):
        B_l, S_l, d = x_l.shape
        T = B_l * S_l
        E, k = cfg.n_experts, cfg.moe_top_k
        C = _capacity(cfg, T, dropless)
        d_loc = w1_l.shape[1]
        x2 = x_l.reshape(T, d)
        topi, topw, load, imp = route(x2, router, k)
        se, st, order, pos = _dispatch_indices(topi, k, E, C)
        sw = topw.reshape(-1)[order]
        e_lo = jax.lax.axis_index(tp) * e_local
        local = jnp.logical_and(se >= e_lo, se < e_lo + e_local)
        valid = jnp.logical_and(local, pos < C)
        slot = jnp.where(valid, (se - e_lo) * C + pos, e_local * C)
        # Gather only my d-slice of the tokens into capacity buffers.
        d_lo = _dp_index(dp) * d_loc
        x_slice = jax.lax.dynamic_slice_in_dim(x2, d_lo, d_loc, axis=1)
        xg = jnp.zeros((e_local * C + 1, d_loc), x2.dtype).at[slot].set(
            x_slice[st])
        xg = xg[:-1].reshape(e_local, C, d_loc)
        # Partial contraction over d; psum assembles the full h.
        act = {"silu": jax.nn.silu,
               "gelu": lambda v: jax.nn.gelu(v, approximate=True)}[cfg.activation]
        h1 = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xg, w1_l), dp)
        h3 = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xg, w3_l), dp)
        h = act(h1) * h3
        yg = jnp.einsum("ecf,efd->ecd", h, w2_l)      # (E_loc, C, d_loc)
        yg = yg.reshape(e_local * C, d_loc)
        contrib = jnp.where(
            valid[:, None],
            yg[jnp.minimum(slot, e_local * C - 1)] * sw[:, None].astype(yg.dtype),
            0.0)
        out_loc = jnp.zeros((T, d_loc), yg.dtype).at[st].add(contrib)
        out_loc = jax.lax.psum(out_loc, tp)           # sum expert groups
        out = jax.lax.all_gather(out_loc, dp, axis=1, tiled=True)
        load = jax.lax.pmean(load, dp)
        imp = jax.lax.pmean(imp, dp)
        return out.reshape(B_l, S_l, d).astype(x_l.dtype), aux_loss(load, imp)

    local_fn = local_gather if mode == "gather" else local_partial
    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(tp, dp, None), P(tp, dp, None), P(tp, None, dp)),
        out_specs=(P(dp, None, None), P()),
    )(x, params["router"], params["w1"], params["w3"], params["w2"])
    return out, aux
