"""Unified model API: config -> init / loss / prefill / decode + input specs.

``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
model input of a dry-run cell (weak-type-correct, shardable, no device
allocation) — the multimodal frontends (whisper mel-conv, qwen2-vl ViT)
are stubs that specify precomputed frame/patch embeddings, per the
assignment.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec, transformer
from repro.models.transformer import RunCtx


@dataclasses.dataclass(frozen=True)
class ServingCaps:
    """Declared serving capabilities of one model configuration.

    One surface the scheduler, speculative backend, prefix cache and
    engine front-end all consult — replacing the ad-hoc ``supports_*``
    predicates that each consumer used to probe separately.

    Attributes
    ----------
    ragged_prefill : bool
        Right-padded (bucketed) prefill is exact: causal attention
        hides pad keys and per-row true lengths recover cache state at
        the real boundary (encoder-decoder masks encoder pads
        explicitly on top).
    prefix_cache : bool
        Block-granular KV prefix sharing is exact: every layer's decode
        state lives IN the shared pool blocks and K/V content depends
        only on prefix token ids + absolute positions (never true for
        encoder-decoder — decoder K/V depends on the encoder output).
    paged_decode : bool
        The model has a block-paged continuous-batching decode path.
    cross_attn : bool
        Requests carry encoder features; decode reads the cross-KV
        arena (encoder-decoder configs).
    moe : bool
        FFN layers route through experts; decode/verify may run
        expert-sharded over the model axis under a mesh.
    quantized_kv : bool
        The paged pool may store int8/fp8 K/V payloads with
        per-(token, head) scale leaves (``EngineConfig.kv_dtype``).
        Requires the paged decode path; excluded for encoder-decoder —
        the cross-KV arena and its self pools stay full-precision.
    """

    ragged_prefill: bool
    prefix_cache: bool
    paged_decode: bool
    cross_attn: bool
    moe: bool
    quantized_kv: bool


class Model:
    """Thin functional wrapper selecting the decoder-only or enc-dec path."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters -----------------------------------------------------

    def init(self, key):
        if self.cfg.enc_dec:
            return encdec.init_encdec(key, self.cfg)
        return transformer.init_lm(key, self.cfg)

    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree.leaves(params))

    # -- training -------------------------------------------------------

    def loss_fn(self, params, batch, ctx: RunCtx):
        if self.cfg.enc_dec:
            return encdec.loss_fn(params, self.cfg, batch, ctx)
        return transformer.loss_fn(params, self.cfg, batch, ctx)

    # -- serving --------------------------------------------------------

    def prefill(self, params, batch, ctx: RunCtx, max_len=None, length=None):
        if self.cfg.enc_dec:
            assert length is None, "padded prefill is decoder-only"
            return encdec.prefill(params, self.cfg, batch["tokens"],
                                  batch["frames"], ctx, max_len=max_len)
        return transformer.prefill(params, self.cfg, batch["tokens"], ctx,
                                   max_len=max_len,
                                   visual_embeds=batch.get("visual_embeds"),
                                   mrope_positions=batch.get("mrope_positions"),
                                   length=length)

    def serving_caps(self) -> ServingCaps:
        """The declared ``ServingCaps`` for this configuration.

        ``prefix_cache`` requires every layer's decode state to live IN
        the shared pool blocks (full attention, no sliding window —
        ring buffers and SSM carries are per-slot state a matched block
        chain cannot reconstruct) with K/V content a pure function of
        prefix token ids and absolute positions. ``paged_decode``
        excludes mrope/visual-prefix frontends (qwen2-vl); absolute
        position embeddings are served only through the encoder-decoder
        path, whose decode threads per-row positions explicitly.
        """
        cfg = self.cfg
        paged = (cfg.rope_style != "mrope"
                 and not cfg.visual_prefix
                 and (cfg.pos_embed == "none" or cfg.enc_dec))
        return ServingCaps(
            ragged_prefill=(cfg.enc_dec
                            or transformer.prefill_supports_ragged(cfg)),
            prefix_cache=(not cfg.enc_dec
                          and set(cfg.block_pattern) == {"attn"}
                          and not cfg.sliding_window
                          and cfg.rope_style in ("rope", "none")
                          and cfg.pos_embed == "none"
                          and not cfg.visual_prefix),
            paged_decode=paged,
            cross_attn=cfg.enc_dec,
            moe=cfg.is_moe,
            quantized_kv=paged and not cfg.enc_dec,
        )

    def init_cache(self, batch: int, max_len: int):
        if self.cfg.enc_dec:
            return encdec.init_cache(self.cfg, batch, max_len)
        return transformer.init_cache(self.cfg, batch, max_len)

    def decode_step(self, params, cache, tokens, pos, ctx: RunCtx,
                    mrope_positions=None):
        if self.cfg.enc_dec:
            return encdec.decode_step(params, self.cfg, cache, tokens, pos,
                                      ctx)
        return transformer.decode_step(params, self.cfg, cache, tokens, pos,
                                       ctx, mrope_positions=mrope_positions)

    # -- paged serving (continuous batching) ----------------------------

    def init_paged_cache(self, layout, spec=None):
        if self.cfg.enc_dec:
            assert spec is None or not spec.quantized, \
                "quantized KV is decoder-only (ServingCaps.quantized_kv)"
            return encdec.init_paged_cache(self.cfg, layout)
        return transformer.init_paged_cache(self.cfg, layout, spec)

    def paged_cache_specs(self, layout, shard, spec=None):
        """PartitionSpecs for ``init_paged_cache`` under a mesh (block
        pools head-sharded over TP; per-slot state on cache rules; the
        cross arena head-sharded over TP, rows replicated). Quantized
        scale leaves (``spec``) shard their kv-head axis over TP too."""
        if self.cfg.enc_dec:
            return encdec.paged_cache_specs(self.cfg, layout, shard)
        return transformer.paged_cache_specs(self.cfg, layout, shard, spec)

    def paged_pool_mask(self, layout, spec=None):
        """Same-structure tree of kind strings over ``init_paged_cache``:
        ``"pool"`` on block-pool leaves, ``"slot"`` on per-slot state,
        ``"cross"`` on cross-arena leaves — classified by layer kind
        (see transformer.paged_pool_mask). Drives the KV migration
        gather/scatter in launch/engine/transport.py."""
        if self.cfg.enc_dec:
            return encdec.paged_pool_mask(self.cfg, layout)
        return transformer.paged_pool_mask(self.cfg, layout, spec)

    def pack_prefill_into_paged(self, layout, pools, dense_caches,
                                row_of_slot, valid, block_ids, spec=None):
        """Batched install: block_ids (N, nbp) per prefill row;
        row_of_slot/valid the inverse slot<-row map for per-slot state.
        ``spec`` quantizes the pool writes (scales land alongside)."""
        return transformer.pack_prefill_into_paged(
            self.cfg, layout, pools, dense_caches, row_of_slot, valid,
            block_ids, spec)

    def prefill_paged_encdec(self, params, pools, tokens, frames,
                             enc_lengths, lengths, block_ids, arena_ids,
                             ctx: RunCtx):
        """Encoder-decoder admission: masked encoder forward, cross-KV
        scattered into the arena rows, ragged decoder prefill packed
        into the block pool. See encdec.prefill_paged."""
        return encdec.prefill_paged(params, self.cfg, pools, tokens,
                                    frames, enc_lengths, lengths,
                                    block_ids, arena_ids, ctx)

    def decode_step_paged(self, params, pools, block_table, lengths, tokens,
                          ctx: RunCtx, arena_ids=None, enc_lengths=None):
        if self.cfg.enc_dec:
            return encdec.decode_step_paged(params, self.cfg, pools,
                                            block_table, lengths, tokens,
                                            arena_ids, enc_lengths, ctx)
        return transformer.decode_step_paged(params, self.cfg, pools,
                                             block_table, lengths, tokens,
                                             ctx)

    def decode_verify(self, params, pools, block_table, lengths, tokens,
                      commit_fn, ctx: RunCtx):
        """Speculative verify: score a (B, K+1) token window in one
        pass; ``commit_fn(logits) -> (out_tokens, commit)`` is the
        accept rule traced inline. See transformer.decode_verify_paged."""
        assert not self.cfg.enc_dec, \
            "verify path is decoder-only (engine gates cross_attn)"
        return transformer.decode_verify_paged(
            params, self.cfg, pools, block_table, lengths, tokens,
            commit_fn, ctx)


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStructs; nothing allocated)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Model inputs for one dry-run cell, as ShapeDtypeStructs."""
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    if cell.kind == "train":
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "targets": _sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            batch["frames"] = _sds((B, cfg.encoder_len, d), cfg.dtype)
        if cfg.visual_prefix:
            batch["visual_embeds"] = _sds((B, cfg.visual_prefix, d), cfg.dtype)
        if cfg.rope_style == "mrope":
            batch["mrope_positions"] = _sds((3, B, S), jnp.int32)
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            batch["frames"] = _sds((B, cfg.encoder_len, d), cfg.dtype)
        if cfg.visual_prefix:
            batch["visual_embeds"] = _sds((B, cfg.visual_prefix, d), cfg.dtype)
        if cfg.rope_style == "mrope":
            batch["mrope_positions"] = _sds((3, B, S), jnp.int32)
        return batch
    if cell.kind == "decode":
        batch = {"tokens": _sds((B, 1), jnp.int32),
                 "pos": _sds((), jnp.int32)}
        if cfg.rope_style == "mrope":
            batch["mrope_positions"] = _sds((3, B, 1), jnp.int32)
        cache = jax.eval_shape(
            lambda: Model(cfg).init_cache(B, S))
        batch["cache"] = cache
        return batch
    raise ValueError(cell.kind)
