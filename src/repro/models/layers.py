"""Shared model layers: norms, MLPs, rotary embeddings, initializers.

Covers the variation across the 10 assigned architectures:
  norms       — rmsnorm (llama family), layernorm (whisper), nonparametric
                (OLMo's non-parametric LN: no scale/bias)
  MLPs        — gated (SwiGLU: yi/qwen/olmo-style; GeGLU: gemma) and plain
                (whisper)
  positions   — RoPE (default), M-RoPE (qwen2-vl 3-D multimodal rotary),
                sinusoidal (whisper encoder), none (xLSTM)
All functions are pure; params are plain dict pytrees.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map


def truncated_normal_init(key, shape, dtype, stddev=None):
    stddev = stddev if stddev is not None else 1.0 / math.sqrt(shape[0])
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}  # (1 + scale) convention
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)
    if kind in ("layernorm", "nonparametric"):
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            xf = xf * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return xf.astype(dt)
    raise ValueError(kind)


def group_norm(x, scale, groups: int, eps: float = 1e-6):
    """Per-head group norm (xLSTM cell output normalization)."""
    dt = x.dtype
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, groups, d // groups)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (xf * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACT = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True,
             out_dim: int | None = None):
    out_dim = out_dim or d_model
    ks = jax.random.split(key, 3)
    p = {"w_up": truncated_normal_init(ks[0], (d_model, d_ff), dtype),
         "w_down": truncated_normal_init(ks[1], (d_ff, out_dim), dtype)}
    if gated:
        p["w_gate"] = truncated_normal_init(ks[2], (d_model, d_ff), dtype)
    return p


def apply_mlp(params, x, activation: str = "silu"):
    act = _ACT[activation]
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def _rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim/2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    B, S, H, D = x.shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, S))
    cos, sin = _rope_angles(positions, D, theta)      # (B, S, D/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE. positions3: (3, B, S) [t, h, w] ids.

    ``sections`` partitions the head_dim/2 frequency slots among the three
    position streams (e.g. (16, 24, 24) for D=128). Text tokens carry
    identical t/h/w ids, reducing to standard RoPE.
    """
    B, S, H, D = x.shape
    half = D // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    start = 0
    for stream, sec in enumerate(sections):
        freqs = 1.0 / (theta ** (jnp.arange(start, start + sec, dtype=jnp.float32) / half))
        ang = positions3[stream][..., None].astype(jnp.float32) * freqs  # (B,S,sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    cos = jnp.concatenate(cos_parts, -1)[:, :, None, :]
    sin = jnp.concatenate(sin_parts, -1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embed(positions, d: int, dtype=jnp.float32):
    """Whisper-style sinusoidal embeddings at (possibly traced) positions.

    positions: (...,) int -> (..., d).

    Built as one ``where``-selected table rather than
    ``concatenate([sin, cos])``: when a downstream matmul operand is
    sharded on d, the SPMD partitioner miscompiles the
    concat-on-the-sharded-axis pattern (device halves glued back in the
    wrong order — sharded encoder outputs were off by |sin - cos|).
    The two forms are bitwise identical; only the where form survives
    partitioning.
    """
    half = d // 2
    dim = jnp.arange(half, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * dim / max(half - 1, 1))
    idx = jnp.arange(d)
    ang = positions[..., None].astype(jnp.float32) * inv[idx % half]
    return jnp.where(idx < half, jnp.sin(ang), jnp.cos(ang)).astype(dtype)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding lookup (Megatron-style)
# ---------------------------------------------------------------------------


def vocab_parallel_lookup(table, tokens, shard):
    """Gather rows of a vocab-sharded table without GSPMD's one-hot
    rewrite (observed: a (tokens, V) one-hot matmul costing ~70 GB temp
    and 1.4e13 bogus FLOPs/device on the 256-chip mesh).

    Each model-shard gathers ids that fall in its vocab range, zeros the
    rest, and a psum over the TP axis assembles the embeddings — the
    uncore analogy: the L2 slice owning the address responds, the NoC
    merges. Falls back to a plain take when V doesn't divide |tp|
    (whisper's 51865) or there is no mesh.
    """
    if shard is None or getattr(shard, "layout", "2d") != "2d":
        # fsdp layout shards the table on d: the row gather is local.
        return jnp.take(table, tokens, axis=0)
    V = table.shape[0]
    tp = shard.tp_axis
    tp_size = shard.tp_size
    if V % tp_size != 0:
        return jnp.take(table, tokens, axis=0)
    from jax.sharding import PartitionSpec as P

    v_loc = V // tp_size

    def local(tbl, ids):
        lo = jax.lax.axis_index(tp) * v_loc
        loc = ids - lo
        valid = jnp.logical_and(loc >= 0, loc < v_loc)
        g = jnp.take(tbl, jnp.clip(loc, 0, v_loc - 1), axis=0)
        g = jnp.where(valid[..., None], g, jnp.zeros((), g.dtype))
        return jax.lax.psum(g, tp)

    dp = shard.dp_axes
    batch_axes = dp if tokens.shape[0] % shard.dp_size == 0 else None
    return shard_map(
        local, mesh=shard.mesh,
        in_specs=(P(tp, None), P(batch_axes, None)),
        out_specs=P(batch_axes, None, None))(table, tokens)


# ---------------------------------------------------------------------------
# Causal depthwise temporal conv (Griffin / xLSTM front conv)
# ---------------------------------------------------------------------------


def init_conv1d(key, dim: int, width: int, dtype):
    return {"w": truncated_normal_init(key, (width, dim), dtype, stddev=0.1),
            "b": jnp.zeros((dim,), dtype)}


def conv_state_at(x, width, length):
    """Causal-conv carry state at a traced per-row offset.

    x: (B, S, D) conv INPUTS whose first ``length[b]`` positions are real
    (right-padded prefill); length: (B,) int32. Returns the
    (B, width-1, D) tail ``apply_conv1d`` would carry had row b stopped
    at ``length[b]`` — the last width-1 real inputs, zero-prefixed for
    rows shorter than the kernel.
    """
    B = x.shape[0]
    xc = jnp.concatenate(
        [jnp.zeros((B, width - 1) + x.shape[2:], x.dtype), x], axis=1)
    idx = length[:, None] + jnp.arange(width - 1)[None, :]
    return xc[jnp.arange(B)[:, None], idx]


def apply_conv1d(params, x, state=None):
    """Causal depthwise conv. x: (B, S, D); state: (B, width-1, D) or None.

    Returns (y, new_state) where new_state holds the last width-1 inputs.
    """
    w = params["w"]
    width = w.shape[0]
    if state is None:
        state = jnp.zeros(x[:, :1].shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    xc = jnp.concatenate([state, x], axis=1)
    y = sum(xc[:, i:i + x.shape[1]] * w[i] for i in range(width))
    y = y + params["b"]
    return y.astype(x.dtype), xc[:, -(width - 1):]
