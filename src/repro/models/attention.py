"""Attention blocks: GQA/MQA, sliding-window, cross-attention, KV cache.

Training/prefill lower through the STX flash kernel path (kernels/ops.py);
decode attends a preallocated KV cache with positional masking. All
projections are bias-optional (qwen2-vl uses QKV bias), with optional
per-head QK-norm (qwen3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map
from repro.kernels import ops as kops
from repro.models import layers


def init_attention(key, cfg, dtype, *, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": layers.truncated_normal_init(ks[0], (d, hq * hd), dtype),
        "wk": layers.truncated_normal_init(ks[1], (d, hkv * hd), dtype),
        "wv": layers.truncated_normal_init(ks[2], (d, hkv * hd), dtype),
        "wo": layers.truncated_normal_init(ks[3], (hq * hd, d), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = layers.init_norm("rmsnorm", hd, dtype)
        p["k_norm"] = layers.init_norm("rmsnorm", hd, dtype)
    return p


def _project_qkv(params, cfg, xq, xkv):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = xq @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, Sq, hq, hd)
    k = k.reshape(B, Skv, hkv, hd)
    v = v.reshape(B, Skv, hkv, hd)
    if "q_norm" in params:
        q = layers.apply_norm("rmsnorm", params["q_norm"], q)
        k = layers.apply_norm("rmsnorm", params["k_norm"], k)
    return q, k, v


def attend(params, cfg, x, positions, *, window=None, causal=True,
           mrope_positions=None, kernel_mode="auto"):
    """Full-sequence (train / prefill) self-attention. x: (B, S, d)."""
    q, k, v = _project_qkv(params, cfg, x, x)
    if cfg.rope_style == "mrope":
        q = layers.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = kops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        mode=kernel_mode)
    B, S, _ = x.shape
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"]


def attend_cross(params, cfg, x, kv_cache):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    B, Sq, _ = x.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, Sq, hq, hd).transpose(0, 2, 1, 3)
    k, v = kv_cache["k"], kv_cache["v"]  # (B, Hkv, Senc, hd)
    out = kops.flash_attention(q, k, v, causal=False, mode="ref")
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, hq * hd)
    return out @ params["wo"]


def attend_masked(params, cfg, x, lengths):
    """Bidirectional self-attention over a right-padded batch (the
    encoder stack under bucketed serving admission).

    x: (B, S, d) where only the first ``lengths[b]`` rows are real
    (the rest is frame-bucket padding). Pad KEYS are masked to -inf so
    they carry exact zero softmax mass; pad QUERY rows produce garbage
    nobody reads (the caller consumes encoder output only at real
    positions). Pure-jnp oracle math (kernels/ref.flash_attention with
    a key-validity mask) — the serving paths run mode='ref' and the
    engine/oracle identity tests rely on matching numerics.
    """
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(params, cfg, x, x)
    group = hq // hkv
    scale = float(1.0 / np.sqrt(hd))
    kx = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1)
    vx = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1)
    logits = jnp.einsum("bqhd,bhkd->bhqk",
                        q.astype(jnp.float32).reshape(B, S, hq, hd),
                        kx.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]     # (B, S) keys
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (batch-filler lanes, lengths == 0) -> zeros.
    probs = jnp.where(jnp.any(valid, -1)[:, None, None, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    out = out.transpose(0, 2, 1, 3).reshape(B, S, hq * hd).astype(x.dtype)
    return out @ params["wo"]


def attend_cross_masked(params, cfg, x, kv_cache, enc_lengths):
    """Cross-attention with per-row encoder-length masking.

    x: (B, Sq, d); kv_cache: {"k","v"} of (B, Hkv, Senc, hd) where only
    the first ``enc_lengths[b]`` encoder positions are real (the rest is
    frame-bucket padding or cross-arena capacity). The -inf key masking
    gives pads exact zero softmax mass, so real-row outputs match the
    unpadded ``attend_cross`` at token level; fully-masked rows (empty
    decode slots reading the null arena row) collapse to zeros instead
    of NaN. Same oracle math as ``attend_masked``.
    """
    B, Sq, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, Sq, hq, hd)
    k, v = kv_cache["k"], kv_cache["v"]              # (B, Hkv, Senc, hd)
    Senc = k.shape[2]
    group = hq // hkv
    scale = float(1.0 / np.sqrt(hd))
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bqhd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    valid = jnp.arange(Senc)[None, :] < enc_lengths[:, None]    # (B, Senc)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.any(valid, -1)[:, None, None, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, hq * hd).astype(x.dtype)
    return out @ params["wo"]


def encode_cross_kv(params, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, Senc, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = enc_out @ params["wk"]
    v = enc_out @ params["wv"]
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    k = k.reshape(B, Senc, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, Senc, hkv, hd).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_len: int, dtype, *, window=None):
    """Ring-buffer cache for windowed layers, linear cache otherwise."""
    size = min(window, max_len) if window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attend(params, cfg, x, cache, pos, *, window=None,
                  mrope_positions=None):
    """Single-token decode. x: (B, 1, d); pos: scalar int32 (contiguous
    decode) or (B,) int32 (ragged decode — each row at its own depth).
    The broadcast front-end of ``decode_attend_batched``."""
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
    return decode_attend_batched(params, cfg, x, cache, posv, window=window,
                                 mrope_positions=mrope_positions)


def decode_attend_batched(params, cfg, x, cache, pos, *, window=None,
                          mrope_positions=None):
    """Single-token decode with PER-SLOT positions (continuous batching).

    x: (B, 1, d); pos: (B,) int32 — each slot's current position (the new
    token's absolute position; equals that slot's cached length). Same
    ring/linear cache layout as ``decode_attend``, but writes and validity
    masks are per-row, so slots at different depths decode in one step.
    """
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(params, cfg, x, x)
    posb = pos[:, None].astype(jnp.int32)
    if cfg.rope_style == "mrope":
        q = layers.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = jnp.mod(pos, size) if window else jnp.clip(pos, 0, size - 1)
    bidx = jnp.arange(B)
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])

    # Per-row validity over cache slots. For ring buffers (windowed
    # layers) every slot is a held, in-window position once the buffer
    # has wrapped; before wrapping only slots 0..pos are written. Linear
    # caches mask future slots. RoPE is applied at write time, so held
    # keys carry their absolute positions and ring order does not matter.
    idx = jnp.arange(size)[None, :]
    valid = idx <= pos[:, None]
    if window:
        valid = jnp.logical_or(valid, (pos[:, None] + 1) >= size)

    qf = q.astype(jnp.float32).reshape(B, hq, hd)
    kf = ck.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = cv.astype(jnp.float32).transpose(0, 2, 1, 3)
    group = hq // hkv
    qg = qf.reshape(B, hkv, group, hd)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, kf) / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, vf)
    out = out.reshape(B, 1, hq * hd).astype(x.dtype)
    return out @ params["wo"], {"k": ck, "v": cv}


def ring_from_prefill(kv, size, length):
    """Length-aware ring-cache extraction for right-padded prefill.

    kv: (B, S, Hkv, D) full-sequence keys or values whose first
    ``length[b]`` positions are real (the rest is bucket padding);
    size: ring capacity; length: (B,) int32 true lengths (traced).
    Returns the (B, size, Hkv, D) ring holding positions
    [max(0, length-size), length) at slot ``pos % size`` — exactly the
    layout ``decode_attend_batched`` continues from — with never-written
    slots zeroed (masked by the decode validity predicate).
    """
    B = kv.shape[0]
    s = jnp.arange(size)[None, :]
    last = length[:, None] - 1                        # (B, 1)
    # largest position p < length with p % size == s (negative -> unset)
    p = last - jnp.mod(last - s, size)
    valid = p >= 0
    pc = jnp.clip(p, 0, kv.shape[1] - 1)
    ring = kv[jnp.arange(B)[:, None], pc]             # (B, size, Hkv, D)
    return jnp.where(valid[..., None, None], ring, 0).astype(kv.dtype)


def decode_attend_paged(params, cfg, x, pool, block_table, lengths, *,
                        window=None, mrope_positions=None,
                        kernel_mode="auto", shard=None, kv_spec=None):
    """Single-token decode against a block-paged KV pool.

    x: (B, 1, d); pool: {"k","v"} of (NB, BS, Hkv, D) (plus
    ``k_scale``/``v_scale`` leaves when ``kv_spec`` is a quantized
    ``paged_kv.PoolSpec`` — the new row is quantized at this write
    frontier and dequant fuses into the kernel); block_table:
    (B, NBMAX) int32; lengths: (B,) tokens already cached per slot — the
    new token lands at position ``lengths[b]``, whose destination block
    ``block_table[b, lengths[b] // BS]`` the scheduler must have allocated
    (retired slots point at the reserved null block 0, making their writes
    harmless). With ``shard`` (a ShardCtx; requires
    ``paged_kv.head_shard_ok``) attention runs through the
    collective-free head-sharded shard_map over the TP-sharded pool.
    Returns (out, new_pool).
    """
    from repro.models.paged_kv import write_kv_rows

    B = x.shape[0]
    hq, hd = cfg.n_heads, cfg.head_dim
    bs = pool["k"].shape[1]
    q, k, v = _project_qkv(params, cfg, x, x)
    posb = lengths[:, None].astype(jnp.int32)
    if cfg.rope_style == "mrope":
        q = layers.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)

    bidx = jnp.arange(B)
    logical = jnp.clip(lengths // bs, 0, block_table.shape[1] - 1)
    phys = block_table[bidx, logical]
    off = lengths % bs
    pool = write_kv_rows(pool, phys, off, k[:, 0], v[:, 0], kv_spec)

    out = kops.paged_attention(
        q.reshape(B, hq, hd), pool, block_table, lengths + 1,
        mode="decode", window=window, kernel_mode=kernel_mode,
        sharding=shard, kv_format=kv_spec)
    out = out.reshape(B, 1, hq * hd).astype(x.dtype)
    return out @ params["wo"], pool


def verify_attend_paged(params, cfg, x, pool, block_table, lengths, *,
                        kernel_mode="auto", shard=None, kv_spec=None):
    """Multi-token decode (speculative verify) against a paged KV pool.

    x: (B, K1, d) — the last accepted token plus K draft tokens per
    slot; lengths: (B,) tokens already cached, so fed token j lands at
    position ``lengths[b] + j`` (its destination block must be in the
    table — unallocated tail positions route to the reserved null
    block, where garbage writes are harmless because reads are masked
    by length). All K+1 K/V rows are written first, then every row
    attends causally within the window through the multi-query kernel —
    one pool sweep for the whole window instead of one per token.
    With ``shard`` (a ShardCtx; requires ``paged_kv.head_shard_ok``)
    the attention runs through the collective-free head-sharded
    shard_map over the TP-sharded pool, exactly like the single-token
    ``decode_attend_paged_headshard``. When ``kv_spec`` is a quantized
    ``paged_kv.PoolSpec`` all K+1 rows quantize at the write frontier
    and dequant fuses into the verify kernel. Returns (out (B, K1, d'),
    new_pool).
    """
    from repro.models.paged_kv import write_kv_rows

    B, K1, _ = x.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    bs = pool["k"].shape[1]
    q, k, v = _project_qkv(params, cfg, x, x)
    pos = lengths[:, None] + jnp.arange(K1)[None, :]    # (B, K1)
    if cfg.rope_style == "rope":
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)

    logical = pos // bs
    nbmax = block_table.shape[1]
    # pad rows can sit past the table's last row (a slot near max_len
    # with fewer than K usable drafts): route their writes to the
    # reserved null block 0 instead of clipping into the slot's own
    # last REAL block, which would corrupt live cached K/V
    phys = jnp.where(
        logical < nbmax,
        jnp.take_along_axis(block_table, jnp.clip(logical, 0, nbmax - 1),
                            axis=1),
        0)
    off = pos % bs
    pool = write_kv_rows(pool, phys, off, k, v, kv_spec)

    out = kops.paged_attention(
        q, pool, block_table, lengths, mode="verify",
        kernel_mode=kernel_mode, sharding=shard, kv_format=kv_spec)
    out = out.reshape(B, K1, hq * hd).astype(x.dtype)
    return out @ params["wo"], pool


def decode_attend_paged_headshard(params, cfg, x, pool, block_table,
                                  lengths, shard, *, kernel_mode="auto",
                                  kv_spec=None):
    """Tensor-parallel ``decode_attend_paged`` over a HEAD-sharded pool.

    Projections stay under GSPMD (wq/wk/wv are column-parallel, wo is
    row-parallel per launch/sharding.py), the new token's K/V write is a
    head-aligned scatter into the sharded pool, and the block gather +
    online softmax run under shard_map with every device holding its
    kv-head shard of every block — so the pool, by far the largest
    serving tensor, never crosses the interconnect and GSPMD can never
    fall back to all-gathering it. Quantized pools shard their
    per-(token, head) scale leaves on the same head axis, so dequant
    stays shard-local too. Thin wrapper over ``decode_attend_paged``
    with ``shard`` set; requires ``paged_kv.head_shard_ok`` (head
    counts divide |tp|).
    """
    return decode_attend_paged(params, cfg, x, pool, block_table, lengths,
                               kernel_mode=kernel_mode, shard=shard,
                               kv_spec=kv_spec)


def decode_attend_seqshard(params, cfg, x, cache, pos, shard,
                           mrope_positions=None):
    """Flash-decoding: KV cache sharded over the TP axis on the SEQUENCE
    dim; each shard computes a partial softmax over its positions and an
    LSE combine (pmax/psum) assembles the exact result.

    §Perf motivation: GQA kv-head counts (4-8) rarely divide |tp|=16, so
    the baseline keeps the cache head-replicated and GSPMD all-gathers it
    every step (~37 GB wire for yi_6b decode_32k). Sequence sharding cuts
    that to KBs: only (num, den, max) partials move. Linear caches only
    (windowed layers keep their small ring buffers).
    """
    from jax.sharding import PartitionSpec as P

    mesh = shard.mesh
    dp = shard.batch_axes
    tp = shard.tp_axis
    tp_size = shard.tp_size
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = hq // hkv
    q, k, v = _project_qkv(params, cfg, x, x)
    posb = jnp.full((B, 1), pos, jnp.int32)
    if cfg.rope_style == "mrope":
        q = layers.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)
    S = cache["k"].shape[1]
    s_loc = S // tp_size

    def local(qv, kv, vv, ck, cv):
        B_l = qv.shape[0]                  # batch is dp-sharded in here
        me = jax.lax.axis_index(tp)
        lo = me * s_loc
        slot = jnp.asarray(pos, jnp.int32) - lo
        in_range = jnp.logical_and(slot >= 0, slot < s_loc)
        cslot = jnp.clip(slot, 0, s_loc - 1)
        z = jnp.zeros((), jnp.int32)
        ck_new = jax.lax.dynamic_update_slice(ck, kv, (z, cslot, z, z))
        cv_new = jax.lax.dynamic_update_slice(cv, vv, (z, cslot, z, z))
        ck = jnp.where(in_range, ck_new, ck)
        cv = jnp.where(in_range, cv_new, cv)
        # partial attention over my positions (bf16 dot, f32 accumulate —
        # no f32 materialization of the cache)
        qg = qv.reshape(B_l, 1, hkv, group, hd)[:, 0]  # (B_l, hkv, g, hd)
        logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(cfg.dtype), ck,
                            preferred_element_type=jnp.float32)
        logits = logits * (1.0 / float(np.sqrt(hd)))
        kpos = lo + jnp.arange(s_loc)
        valid = kpos <= pos
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
        m_loc = jnp.max(logits, -1)                        # (B, hkv, g)
        p = jnp.exp(logits - m_loc[..., None])
        p = jnp.where(valid[None, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, -1)
        acc = jnp.einsum("bhgs,bshd->bhgd", p.astype(cfg.dtype), cv,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, tp)
        scale = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * scale, tp)
        acc_g = jax.lax.psum(acc * scale[..., None], tp)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        out = out.reshape(B_l, 1, hq * hd).astype(x.dtype)
        return out, ck, cv

    out, ck, cv = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, None, None, None),
                  P(dp, None, None, None),
                  P(dp, tp, None, None), P(dp, tp, None, None)),
        out_specs=(P(dp, None, None), P(dp, tp, None, None),
                   P(dp, tp, None, None)),
    )(q, k, v, cache["k"], cache["v"])
    return out @ params["wo"], {"k": ck, "v": cv}
