"""Encoder-decoder assembly (whisper-base backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, F, d) directly to the encoder. Decoder
blocks add a cross-attention sublayer; decode caches both the self-KV ring
and the (static per request) cross K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers
from repro.models.transformer import RunCtx, _logits
from repro.configs.base import ModelConfig


def init_encdec(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2 * (cfg.n_encoder_layers + cfg.n_layers) + 4)
    ki = 0

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn_lib.init_attention(k1, cfg, dtype),
            "ln2": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype,
                                   gated=cfg.gated_mlp),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn_lib.init_attention(k1, cfg, dtype),
            "lnx": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "xattn": attn_lib.init_attention(k2, cfg, dtype, cross=True),
            "ln2": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype,
                                   gated=cfg.gated_mlp),
        }

    enc = [enc_block(ks[ki + i]) for i in range(cfg.n_encoder_layers)]
    ki += cfg.n_encoder_layers
    dec = [dec_block(ks[ki + i]) for i in range(cfg.n_layers)]
    ki += cfg.n_layers
    return {
        "embed": layers.truncated_normal_init(
            ks[ki], (cfg.vocab_size, cfg.d_model), dtype, stddev=1.0),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
    }


def encode(params, cfg: ModelConfig, frames, ctx: RunCtx):
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + layers.sinusoidal_embed(jnp.arange(x.shape[1]), cfg.d_model,
                                    x.dtype)

    def body(xc, p):
        xn = layers.apply_norm(cfg.norm, p["ln1"], xc)
        xc = xc + attn_lib.attend(p["attn"], cfg, xn,
                                  jnp.arange(xn.shape[1]), causal=False,
                                  kernel_mode=ctx.kernel_mode)
        xn = layers.apply_norm(cfg.norm, p["ln2"], xc)
        xc = xc + layers.apply_mlp(p["mlp"], xn, cfg.activation)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=True if ctx.scan_unroll else 1)
    return layers.apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block(p, cfg, x, positions, cross_kv, ctx, self_cache=None,
               pos=None):
    xn = layers.apply_norm(cfg.norm, p["ln1"], x)
    if self_cache is None:
        x = x + attn_lib.attend(p["attn"], cfg, xn, positions, causal=True,
                                kernel_mode=ctx.kernel_mode)
        new_cache = None
    else:
        out, new_cache = attn_lib.decode_attend(p["attn"], cfg, xn,
                                                self_cache, pos)
        x = x + out
    xn = layers.apply_norm(cfg.norm, p["lnx"], x)
    x = x + attn_lib.attend_cross(p["xattn"], cfg, xn, cross_kv)
    xn = layers.apply_norm(cfg.norm, p["ln2"], x)
    x = x + layers.apply_mlp(p["mlp"], xn, cfg.activation)
    return x, new_cache


def forward(params, cfg: ModelConfig, tokens, frames, ctx: RunCtx):
    """Training forward: (B, S) tokens + (B, F, d) frames -> logits."""
    enc_out = encode(params, cfg, frames, ctx)
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(jnp.arange(x.shape[1]), cfg.d_model,
                                    x.dtype)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(xc, p):
        cross_kv = attn_lib.encode_cross_kv(p["xattn"], cfg, enc_out)
        xc, _ = _dec_block(p, cfg, xc, positions, cross_kv, ctx)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch, ctx: RunCtx):
    logits, aux = forward(params, cfg, batch["tokens"], batch["frames"], ctx)
    tgt = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "aux": aux, "loss": ce}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Self-KV ring per decoder layer + slot for cross K/V."""
    dtype = jnp.dtype(cfg.dtype)
    one = attn_lib.init_kv_cache(cfg, batch, max_len, dtype)
    L = cfg.n_layers
    stack = lambda t: jnp.broadcast_to(t[None], (L,) + t.shape)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cross = {"k": jnp.zeros((L, batch, hkv, cfg.encoder_len, hd), dtype),
             "v": jnp.zeros((L, batch, hkv, cfg.encoder_len, hd), dtype)}
    return {"self": jax.tree.map(stack, one), "cross": cross}


def prefill(params, cfg: ModelConfig, tokens, frames, ctx: RunCtx,
            max_len=None):
    """Encode + decoder prefill. Returns (logits, cache)."""
    S = tokens.shape[1]
    max_len = max_len or S
    enc_out = encode(params, cfg, frames, ctx)
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(jnp.arange(S), cfg.d_model, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(xc, p):
        cross_kv = attn_lib.encode_cross_kv(p["xattn"], cfg, enc_out)
        xn = layers.apply_norm(cfg.norm, p["ln1"], xc)
        q, k, v = attn_lib._project_qkv(p["attn"], cfg, xn, xn)
        from repro.kernels import ops as kops
        out = kops.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), causal=True,
                                   mode=ctx.kernel_mode)
        B = xc.shape[0]
        out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
        xc = xc + out @ p["attn"]["wo"]
        xn = layers.apply_norm(cfg.norm, p["lnx"], xc)
        xc = xc + attn_lib.attend_cross(p["xattn"], cfg, xn, cross_kv)
        xn = layers.apply_norm(cfg.norm, p["ln2"], xc)
        xc = xc + layers.apply_mlp(p["mlp"], xn, cfg.activation)
        pad = max_len - S
        cache = {"self": {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                          "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))},
                 "cross": cross_kv}
        return xc, cache

    x, caches = jax.lax.scan(body, x, params["dec"],
                             unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x), caches


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, ctx: RunCtx):
    """One decoder token against self cache + cross K/V."""
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(pos + jnp.arange(1), cfg.d_model, x.dtype)

    def body(xc, scanned):
        p, self_c, cross_c = scanned
        xc, new_c = _dec_block(p, cfg, xc, None, cross_c, ctx,
                               self_cache=self_c, pos=pos)
        return xc, new_c

    x, new_self = jax.lax.scan(body, x,
                               (params["dec"], cache["self"], cache["cross"]),
                               unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    new_cache = {"self": new_self, "cross": cache["cross"]}
    return _logits(params, cfg, x)[:, 0], new_cache
