"""Encoder-decoder assembly (whisper-base backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, F, d) directly to the encoder. Decoder
blocks add a cross-attention sublayer; decode caches both the self-KV ring
and the (static per request) cross K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers
from repro.models.transformer import RunCtx, _logits
from repro.configs.base import ModelConfig


def init_encdec(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2 * (cfg.n_encoder_layers + cfg.n_layers) + 4)
    ki = 0

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn_lib.init_attention(k1, cfg, dtype),
            "ln2": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype,
                                   gated=cfg.gated_mlp),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn_lib.init_attention(k1, cfg, dtype),
            "lnx": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "xattn": attn_lib.init_attention(k2, cfg, dtype, cross=True),
            "ln2": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype,
                                   gated=cfg.gated_mlp),
        }

    enc = [enc_block(ks[ki + i]) for i in range(cfg.n_encoder_layers)]
    ki += cfg.n_encoder_layers
    dec = [dec_block(ks[ki + i]) for i in range(cfg.n_layers)]
    ki += cfg.n_layers
    return {
        "embed": layers.truncated_normal_init(
            ks[ki], (cfg.vocab_size, cfg.d_model), dtype, stddev=1.0),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
    }


def encode(params, cfg: ModelConfig, frames, ctx: RunCtx,
           enc_lengths=None):
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d).

    ``enc_lengths`` ((B,) int32, optional) masks right-padding: the
    serving engine buckets frame counts to powers of two, so pad keys
    must carry zero attention mass for real positions to match the
    unpadded oracle. ``None`` (training / exact-length prefill) keeps
    the unmasked flash path.
    """
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + layers.sinusoidal_embed(jnp.arange(x.shape[1]), cfg.d_model,
                                    x.dtype)

    def body(xc, p):
        xn = layers.apply_norm(cfg.norm, p["ln1"], xc)
        if enc_lengths is None:
            out = attn_lib.attend(p["attn"], cfg, xn,
                                  jnp.arange(xn.shape[1]), causal=False,
                                  kernel_mode=ctx.kernel_mode)
        else:
            out = attn_lib.attend_masked(p["attn"], cfg, xn, enc_lengths)
        xc = xc + out
        xn = layers.apply_norm(cfg.norm, p["ln2"], xc)
        xc = xc + layers.apply_mlp(p["mlp"], xn, cfg.activation)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=True if ctx.scan_unroll else 1)
    return layers.apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block(p, cfg, x, positions, cross_kv, ctx, self_cache=None,
               pos=None):
    xn = layers.apply_norm(cfg.norm, p["ln1"], x)
    if self_cache is None:
        x = x + attn_lib.attend(p["attn"], cfg, xn, positions, causal=True,
                                kernel_mode=ctx.kernel_mode)
        new_cache = None
    else:
        out, new_cache = attn_lib.decode_attend(p["attn"], cfg, xn,
                                                self_cache, pos)
        x = x + out
    xn = layers.apply_norm(cfg.norm, p["lnx"], x)
    x = x + attn_lib.attend_cross(p["xattn"], cfg, xn, cross_kv)
    xn = layers.apply_norm(cfg.norm, p["ln2"], x)
    x = x + layers.apply_mlp(p["mlp"], xn, cfg.activation)
    return x, new_cache


def forward(params, cfg: ModelConfig, tokens, frames, ctx: RunCtx):
    """Training forward: (B, S) tokens + (B, F, d) frames -> logits."""
    enc_out = encode(params, cfg, frames, ctx)
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(jnp.arange(x.shape[1]), cfg.d_model,
                                    x.dtype)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(xc, p):
        cross_kv = attn_lib.encode_cross_kv(p["xattn"], cfg, enc_out)
        xc, _ = _dec_block(p, cfg, xc, positions, cross_kv, ctx)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch, ctx: RunCtx):
    logits, aux = forward(params, cfg, batch["tokens"], batch["frames"], ctx)
    tgt = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "aux": aux, "loss": ce}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Self-KV ring per decoder layer + slot for cross K/V."""
    dtype = jnp.dtype(cfg.dtype)
    one = attn_lib.init_kv_cache(cfg, batch, max_len, dtype)
    L = cfg.n_layers
    stack = lambda t: jnp.broadcast_to(t[None], (L,) + t.shape)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cross = {"k": jnp.zeros((L, batch, hkv, cfg.encoder_len, hd), dtype),
             "v": jnp.zeros((L, batch, hkv, cfg.encoder_len, hd), dtype)}
    return {"self": jax.tree.map(stack, one), "cross": cross}


def prefill(params, cfg: ModelConfig, tokens, frames, ctx: RunCtx,
            max_len=None):
    """Encode + decoder prefill. Returns (logits, cache)."""
    S = tokens.shape[1]
    max_len = max_len or S
    enc_out = encode(params, cfg, frames, ctx)
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(jnp.arange(S), cfg.d_model, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(xc, p):
        cross_kv = attn_lib.encode_cross_kv(p["xattn"], cfg, enc_out)
        xn = layers.apply_norm(cfg.norm, p["ln1"], xc)
        q, k, v = attn_lib._project_qkv(p["attn"], cfg, xn, xn)
        from repro.kernels import ops as kops
        out = kops.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), causal=True,
                                   mode=ctx.kernel_mode)
        B = xc.shape[0]
        out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
        xc = xc + out @ p["attn"]["wo"]
        xn = layers.apply_norm(cfg.norm, p["lnx"], xc)
        xc = xc + attn_lib.attend_cross(p["xattn"], cfg, xn, cross_kv)
        xn = layers.apply_norm(cfg.norm, p["ln2"], xc)
        xc = xc + layers.apply_mlp(p["mlp"], xn, cfg.activation)
        pad = max_len - S
        cache = {"self": {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                          "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))},
                 "cross": cross_kv}
        return xc, cache

    x, caches = jax.lax.scan(body, x, params["dec"],
                             unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x), caches


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, ctx: RunCtx):
    """One decoder token against self cache + cross K/V."""
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(pos + jnp.arange(1), cfg.d_model, x.dtype)

    def body(xc, scanned):
        p, self_c, cross_c = scanned
        xc, new_c = _dec_block(p, cfg, xc, None, cross_c, ctx,
                               self_cache=self_c, pos=pos)
        return xc, new_c

    x, new_self = jax.lax.scan(body, x,
                               (params["dec"], cache["self"], cache["cross"]),
                               unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    new_cache = {"self": new_self, "cross": cache["cross"]}
    return _logits(params, cfg, x)[:, 0], new_cache


# ---------------------------------------------------------------------------
# Paged serving (continuous batching): self-KV on the block pool,
# cross-KV in the per-request arena
# ---------------------------------------------------------------------------


def init_paged_cache(cfg: ModelConfig, layout):
    """Paged decode state for the encoder-decoder serving path.

    ``{"self": {"k","v"}, "cross": {"k","v"}}`` — decoder self-attention
    rides the standard block pool (``(L, NB, BS, Hkv, hd)``, flat
    layer-stacked to match ``params["dec"]``); cross-attention reads the
    per-request arena (``paged_kv.init_cross_arena``), written once at
    admission and static thereafter. Block table and lengths live with
    the scheduler, as in the decoder-only tree.
    """
    from repro.models import paged_kv

    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, layout.num_blocks, layout.block_size,
             cfg.n_kv_heads, cfg.head_dim)
    return {"self": {"k": jnp.zeros(shape, dtype),
                     "v": jnp.zeros(shape, dtype)},
            "cross": paged_kv.init_cross_arena(cfg, layout, dtype)}


def paged_pool_mask(cfg: ModelConfig, layout):
    """Kind strings over ``init_paged_cache``: the decoder self-KV is
    ``"pool"`` (block axis at axis 1), the cross arena is ``"cross"``
    (arena-row axis at axis 1). Drives KV migration gather/scatter."""
    return {"self": {"k": "pool", "v": "pool"},
            "cross": {"k": "cross", "v": "cross"}}


def paged_cache_specs(cfg: ModelConfig, layout, shard):
    """PartitionSpecs for the encoder-decoder paged tree: self-KV pools
    head-sharded over TP like every full-attention pool; the cross arena
    head-sharded over TP too (arena rows stay replicated over the data
    axes — row count is ``num_slots + 1``, which the null row keeps off
    any pow-2 divisibility grid)."""
    from jax.sharding import PartitionSpec as P

    from repro.launch import sharding as shlib

    shapes = jax.eval_shape(lambda: init_paged_cache(cfg, layout))
    pool = jax.tree.map(lambda t: shlib.paged_pool_spec(t, shard),
                        shapes["self"])
    hkv = cfg.n_kv_heads
    tp = shard.tp_axis if hkv % shard.tp_size == 0 else None
    cross_spec = P(None, None, tp, None, None)
    return {"self": pool,
            "cross": jax.tree.map(lambda t: cross_spec, shapes["cross"])}


def prefill_paged(params, cfg: ModelConfig, pools, tokens, frames,
                  enc_lengths, lengths, block_ids, arena_ids, ctx: RunCtx):
    """Batched admission for encoder-decoder requests: encoder forward
    (masked to each row's true frame count), cross-KV scattered into the
    arena, ragged causal decoder prefill packed into the block pool.

    tokens: (N, Sb) right-padded to the prompt bucket; frames:
    (N, Fb, d) right-padded to the frame bucket; enc_lengths, lengths:
    (N,) true frame/prompt counts; block_ids: (N, nbp) physical
    destinations (pad tails at the null block); arena_ids: (N,)
    destination arena rows (batch fillers at the null row). Right
    padding is exact for the decoder — causal attention hides pad keys,
    absolute sinusoidal positions don't shift, and pad-row K/V lands in
    the null block — while the encoder and cross-attention mask pads
    explicitly (bidirectional attention would otherwise see them).
    Returns ``(row_logits (N, V) at each row's last real position,
    new pools)``.
    """
    from repro.kernels import ops as kops
    from repro.models import paged_kv

    N, Sb = tokens.shape
    bs = pools["self"]["k"].shape[2]
    enc_out = encode(params, cfg, frames, ctx, enc_lengths=enc_lengths)
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(jnp.arange(Sb), cfg.d_model, x.dtype)

    def body(xc, p):
        cross_kv = attn_lib.encode_cross_kv(p["xattn"], cfg, enc_out)
        xn = layers.apply_norm(cfg.norm, p["ln1"], xc)
        q, k, v = attn_lib._project_qkv(p["attn"], cfg, xn, xn)
        out = kops.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), causal=True,
                                   mode=ctx.kernel_mode)
        out = out.transpose(0, 2, 1, 3).reshape(
            N, Sb, cfg.n_heads * cfg.head_dim)
        xc = xc + out @ p["attn"]["wo"]
        xn = layers.apply_norm(cfg.norm, p["lnx"], xc)
        xc = xc + attn_lib.attend_cross_masked(p["xattn"], cfg, xn,
                                               cross_kv, enc_lengths)
        xn = layers.apply_norm(cfg.norm, p["ln2"], xc)
        xc = xc + layers.apply_mlp(p["mlp"], xn, cfg.activation)
        return xc, {"kv": {"k": k, "v": v}, "cross": cross_kv}

    x, caches = jax.lax.scan(body, x, params["dec"],
                             unroll=True if ctx.scan_unroll else 1)
    W = block_ids.shape[1] * bs            # cache width, block multiple
    dense = jax.tree.map(
        lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, W - Sb), (0, 0), (0, 0))),
        caches["kv"])                      # (L, N, W, Hkv, hd)
    new_self = paged_kv.pack_prefill_kv(pools["self"], dense, block_ids, bs)
    new_cross = paged_kv.pack_cross_arena(pools["cross"], caches["cross"],
                                          arena_ids)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits(params, cfg, x)       # (N, Sb, V)
    rows = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
    return rows, {"self": new_self, "cross": new_cross}


def decode_step_paged(params, cfg: ModelConfig, pools, block_table,
                      lengths, tokens, arena_ids, enc_lengths,
                      ctx: RunCtx):
    """Shape-stable continuous-batching decode step (encoder-decoder).

    tokens: (B, 1); lengths: (B,) tokens already cached per slot (the
    new token's position, which also selects its absolute sinusoidal
    embedding per row); arena_ids: (B,) each slot's cross-arena row
    (empty slots at the null row 0, whose fully-masked cross read
    collapses to zeros); enc_lengths: (B,) true encoder lengths.
    Returns (logits (B, V), new pools) — the cross arena passes through
    untouched (written only at admission).
    """
    x = params["embed"][tokens]
    x = x + layers.sinusoidal_embed(lengths[:, None], cfg.d_model, x.dtype)

    def body(xc, scanned):
        p, self_pool, xk, xv = scanned
        xn = layers.apply_norm(cfg.norm, p["ln1"], xc)
        out, new_pool = attn_lib.decode_attend_paged(
            p["attn"], cfg, xn, self_pool, block_table, lengths,
            kernel_mode=ctx.kernel_mode)
        xc = xc + out
        xn = layers.apply_norm(cfg.norm, p["lnx"], xc)
        kv = {"k": xk[arena_ids], "v": xv[arena_ids]}  # (B, Hkv, enc, hd)
        xc = xc + attn_lib.attend_cross_masked(p["xattn"], cfg, xn, kv,
                                               enc_lengths)
        xn = layers.apply_norm(cfg.norm, p["ln2"], xc)
        xc = xc + layers.apply_mlp(p["mlp"], xn, cfg.activation)
        return xc, new_pool

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], pools["self"],
                  pools["cross"]["k"], pools["cross"]["v"]),
        unroll=True if ctx.scan_unroll else 1)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x)[:, 0], {"self": new_self,
                                           "cross": pools["cross"]}
