"""LM assembly: heterogeneous block stacks with scan-over-layers.

Layers are grouped into repeating *pattern periods* (e.g. recurrentgemma's
(rglru, rglru, local)) and each group is a lax.scan over stacked params —
HLO stays O(1) in depth, which keeps the 61-layer kimi-k2 dry-run
compilable. A trailing partial period becomes a count-1 group.

Block kinds: attn (optional SWA), local (windowed attn), mlstm, slstm,
rglru. Every kind supports three phases with one param set:
  forward  (train)            — full sequence, no cache
  prefill                     — full sequence, returns cache
  decode                      — one token + cache
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers, moe, ssm
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Static per-call context (jit-static; hashable)."""

    kernel_mode: str = "auto"       # kernels/ops dispatch mode
    causal: bool = True
    remat: str = "none"             # none | full
    shard: Any = None               # launch.sharding.ShardCtx or None
    moe_sharded: bool = False
    # Unroll layer/chunk scans. Used by the dry-run's shallow cost
    # variants: XLA cost_analysis ignores while-loop trip counts, so
    # FLOPs are only countable from unrolled bodies.
    scan_unroll: bool = False
    # §Perf knobs (hillclimbed; see EXPERIMENTS.md):
    ce_chunk: int = 0          # >0: scan CE over seq chunks (no full logits)
    moe_mode: str = "gather"   # 'gather' (FSDP weight gather) | 'partial'
    decode_seq_shard: bool = False  # flash-decoding LSE combine over tp
    # Paged serving under a mesh: run decode attention through the
    # head-sharded pool shard_map (each device owns its kv-head shard of
    # every block). Set by the Engine when paged_kv.head_shard_ok holds.
    decode_head_shard: bool = False
    # Quantized paged KV: a paged_kv.PoolSpec (hashable, jit-static) when
    # the pool stores int8/fp8 payloads with per-(token, head) scale
    # leaves; None keeps the historical bf16 path bit-identical.
    kv_spec: Any = None
    # Residual-stream constraint after every block:
    #   'none'  — GSPMD chooses; observed: it DELAYS the row-parallel
    #             reduction into the next norm's f32 upcast, so the
    #             activation all-reduce moves f32 (2x traffic);
    #   'batch' — constrain to (batch-sharded, replicated): forces the
    #             reduce on the bf16 tensor;
    #   'seq'   — Megatron-SP: additionally shard the sequence over tp
    #             between blocks (reduce-scatter + all-gather schedule,
    #             residual memory / |tp|).
    residual_spec: str = "none"


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": layers.init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind in ("attn", "local"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg, dtype)
        p["ln2"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.is_moe:
            p["moe"] = moe.init_moe(ks[1], cfg, dtype)
        elif cfg.d_ff > 0:
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                                       gated=cfg.gated_mlp)
    elif kind == "rglru":
        p["rec"] = ssm.init_rglru_block(ks[0], cfg, dtype)
        if cfg.d_ff > 0:
            p["ln2"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                                       gated=cfg.gated_mlp)
    elif kind == "mlstm":
        p["mix"] = ssm.init_mlstm_block(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mix"] = ssm.init_slstm_block(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def _window_for(cfg, kind):
    if kind == "local":
        return cfg.local_window
    return cfg.sliding_window


def _ffn_part(p, cfg, x, ctx, dropless: bool = False):
    """Post-mixing FFN/MoE with pre-norm + residual. Returns (x, aux).

    ``dropless`` is set by every SERVING path (prefill-with-cache,
    decode, verify): expert capacity is sized so no assignment ever
    drops, making the MoE per-token — outputs independent of right-
    padding, co-batched traffic and batch width, which the engine's
    identity contract requires. Training keeps capacity-factor drops.
    """
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        xn = layers.apply_norm(cfg.norm, p["ln2"], x)
        if ctx.moe_sharded and ctx.shard is not None:
            delta, aux = moe.apply_moe_sharded(p["moe"], cfg, xn, ctx.shard,
                                               mode=ctx.moe_mode,
                                               dropless=dropless)
        else:
            delta, aux = moe.apply_moe(p["moe"], cfg, xn,
                                       dropless=dropless)
        x = x + delta
    elif "mlp" in p:
        xn = layers.apply_norm(cfg.norm, p["ln2"], x)
        x = x + layers.apply_mlp(p["mlp"], xn, cfg.activation)
    return x, aux


def apply_block(p, cfg: ModelConfig, kind: str, x, positions, ctx: RunCtx,
                mrope_positions=None, with_cache: bool = False,
                cache_len: Optional[int] = None, prefill_length=None):
    """Full-sequence block. Returns (x, aux, cache-or-None).

    ``prefill_length`` ((B,) int32, traced) marks RIGHT-padded prefill:
    only the first ``prefill_length[b]`` tokens of row b are real. Causal
    masking already keeps padded keys out of every real query's window,
    so the forward math needs no change — but emitted decode caches must
    capture state *at the true length*, not at the padded end. Attention
    rings and the RG-LRU gather/recompute their state at the true
    boundary; mlstm freezes its chunk scan past it by gate masking and
    slstm by carry selection, so every decoder-only kind is exact under
    right padding (``prefill_supports_ragged``).
    """
    xn = layers.apply_norm(cfg.norm, p["ln1"], x)
    cache = None
    if kind in ("attn", "local"):
        window = _window_for(cfg, kind)
        if with_cache:
            out, cache = _attend_with_cache(p["attn"], cfg, xn, positions,
                                            window, ctx, mrope_positions,
                                            cache_len, prefill_length)
        else:
            out = attn_lib.attend(p["attn"], cfg, xn, positions,
                                  window=window, causal=ctx.causal,
                                  mrope_positions=mrope_positions,
                                  kernel_mode=ctx.kernel_mode)
        x = _constrain_residual(x + out, ctx)
        x, aux = _ffn_part(p, cfg, x, ctx, dropless=with_cache)
        return x, aux, cache
    if kind == "rglru":
        if with_cache:
            out, cache = _rglru_with_cache(p["rec"], cfg, xn, ctx,
                                           prefill_length)
        else:
            out = ssm.apply_rglru_block(p["rec"], cfg, xn,
                                        kernel_mode=ctx.kernel_mode)
        x = _constrain_residual(x + out, ctx)
        x, aux = _ffn_part(p, cfg, x, ctx, dropless=with_cache)
        return x, aux, cache
    if kind == "mlstm":
        # NOTE: the mLSTM chunk scan stays a loop even in unrolled cost
        # variants (fully unrolling 16 chunks x 7 layers x ~30 einsums
        # under autodiff blew XLA compile time past 30 min). Cost effect:
        # intra-chunk einsums are counted for 1 of N chunks, an ~11%
        # undercount of the mLSTM *mixing* flops (projections dominate
        # and are counted exactly) — recorded in EXPERIMENTS.md §Roofline.
        if with_cache:
            out, cache = _mlstm_with_cache(p["mix"], cfg, xn,
                                           length=prefill_length)
        else:
            out = ssm.apply_mlstm_block(p["mix"], cfg, xn,
                                        chunk=cfg.mlstm_chunk)
        return x + out, jnp.zeros((), jnp.float32), cache
    if kind == "slstm":
        if with_cache:
            out, cache = _slstm_with_cache(p["mix"], cfg, xn,
                                           length=prefill_length)
        else:
            out = ssm.apply_slstm_block(p["mix"], cfg, xn)
        return x + out, jnp.zeros((), jnp.float32), cache
    raise ValueError(kind)


# --- prefill variants that also emit a decode cache -------------------------


def _attend_with_cache(params, cfg, xn, positions, window, ctx,
                       mrope_positions, cache_len, length=None):
    B, S, _ = xn.shape
    q, k, v = attn_lib._project_qkv(params, cfg, xn, xn)
    if cfg.rope_style == "mrope":
        q = layers.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = __import__("repro.kernels.ops", fromlist=["x"]).flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=window,
        mode=ctx.kernel_mode)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = out @ params["wo"]
    size = min(window, cache_len or S) if window else (cache_len or S)
    if window and length is not None:
        # Right-padded prefill: rebuild the ring from the true per-row
        # tail, not the padded one. Linear caches need nothing — pad-key
        # garbage past ``length`` is masked by the decode validity
        # predicate and overwritten as decode advances.
        ck = attn_lib.ring_from_prefill(k, size, length)
        cv = attn_lib.ring_from_prefill(v, size, length)
    elif window and S >= size:
        ck, cv = k[:, -size:], v[:, -size:]
        # ring-order the tail so slot (pos % size) stays consistent
        roll = (S % size)
        ck = jnp.roll(ck, roll, axis=1)
        cv = jnp.roll(cv, roll, axis=1)
    else:
        pad = size - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, {"k": ck, "v": cv}


def _rglru_with_cache(params, cfg, xn, ctx, length=None):
    gate = jax.nn.gelu(xn @ params["w_gate"], approximate=True)
    xb = xn @ params["w_x"]
    y, conv_state = layers.apply_conv1d(params["conv"], xb)
    a, b = ssm._rglru_coeffs(params, y)
    h = __import__("repro.kernels.ops", fromlist=["x"]).rglru_scan(
        a, b, mode=ctx.kernel_mode)
    out = (gate * h.astype(xn.dtype)) @ params["w_out"]
    if length is None:
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    # Right-padded prefill: the recurrence is causal, so the state at the
    # true length is just an interior scan step — gather it, and rebuild
    # the conv tail from the last (width-1) REAL inputs (zero-prefixed,
    # matching apply_conv1d's initial state for short prompts).
    B = xn.shape[0]
    h_true = h[jnp.arange(B), jnp.maximum(length - 1, 0)]
    conv_true = layers.conv_state_at(xb, params["conv"]["w"].shape[0],
                                     length)
    return out, {"h": h_true.astype(jnp.float32), "conv": conv_true}


def _mlstm_with_cache(params, cfg, xn, unroll=False, length=None):
    B, S, d = xn.shape
    q, k, v, ig, fg, z, conv_state = ssm._mlstm_qkv_gates(
        params, cfg, xn, length=length)
    if length is not None:
        # Right-padded prefill: freeze the chunk scan past the true
        # length (input gate off, forget gate exactly 1), so the carried
        # (C, n, m) IS the state at ``length``; pad-row h is garbage and
        # never read (logits are taken at real positions only).
        ig, fg = ssm.freeze_gates_past(ig, fg, length)
    h, (C, n, m) = ssm.mlstm_chunkwise(q, k, v, ig, fg,
                                       chunk=min(cfg.mlstm_chunk, S),
                                       unroll=unroll)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d)
    h = layers.group_norm(h, params["gn_scale"], cfg.n_heads)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    return out, {"C": C, "n": n, "m": m, "conv": conv_state}


def _slstm_with_cache(params, cfg, xn, length=None):
    B, S, d = xn.shape
    H = cfg.n_heads
    hd = d // H
    x_parts = xn @ params["w_zifo"]
    state = (jnp.zeros((B, H, hd), jnp.float32),) * 3 + (
        jnp.full((B, H, hd), -1e30, jnp.float32),)

    def step(st, inp):
        xp, t = inp
        hidden, st_new = ssm._slstm_cell(params, cfg, xp, st)
        if length is None:
            return st_new, hidden
        # Right-padded prefill: keep the pre-step carry on pad rows so
        # the final state is frozen bit-exactly at each true length.
        keep = (t < length)[:, None, None]
        st = tuple(jnp.where(keep, new, old)
                   for new, old in zip(st_new, st))
        return st, hidden

    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(
        step, state, (jnp.moveaxis(x_parts, 1, 0),
                      jnp.arange(S, dtype=jnp.int32)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(xn.dtype)
    h = layers.group_norm(h, params["gn_scale"], H)
    out = layers.apply_mlp(params["ff"], h, "gelu")
    return out, {"h": h_f, "c": c_f, "n": n_f, "m": m_f}


# --- decode ------------------------------------------------------------------


def apply_block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos,
                       ctx: RunCtx, mrope_positions=None):
    """One-token block step. x: (B, 1, d). Returns (x, new_cache)."""
    xn = layers.apply_norm(cfg.norm, p["ln1"], x)
    if kind in ("attn", "local"):
        window = _window_for(cfg, kind)
        if (ctx.decode_seq_shard and ctx.shard is not None
                and window is None):
            out, cache = attn_lib.decode_attend_seqshard(
                p["attn"], cfg, xn, cache, pos, ctx.shard,
                mrope_positions=mrope_positions)
        else:
            out, cache = attn_lib.decode_attend(
                p["attn"], cfg, xn, cache, pos, window=window,
                mrope_positions=mrope_positions)
        x = x + out
        x, _ = _ffn_part(p, cfg, x, ctx, dropless=True)
        return x, cache
    if kind == "rglru":
        out, cache = ssm.apply_rglru_decode(p["rec"], cfg, xn, cache)
        x = x + out
        x, _ = _ffn_part(p, cfg, x, ctx, dropless=True)
        return x, cache
    if kind == "mlstm":
        out, cache = ssm.apply_mlstm_decode(p["mix"], cfg, xn, cache)
        return x + out, cache
    if kind == "slstm":
        out, cache = ssm.apply_slstm_decode(p["mix"], cfg, xn, cache)
        return x + out, cache
    raise ValueError(kind)


def apply_block_decode_paged(p, cfg: ModelConfig, kind: str, x, cache,
                             block_table, lengths, ctx: RunCtx,
                             mrope_positions=None):
    """One-token block step with PER-SLOT positions over the paged cache.

    Full-attention layers attend a shared block pool via the per-sequence
    block table; windowed layers keep per-slot ring buffers (bounded state
    — paging buys nothing); SSM kinds carry per-slot recurrent state and
    are position-independent, so the stock decode applies unchanged.
    """
    xn = layers.apply_norm(cfg.norm, p["ln1"], x)
    if kind in ("attn", "local"):
        window = _window_for(cfg, kind)
        if window is None:
            out, cache = attn_lib.decode_attend_paged(
                p["attn"], cfg, xn, cache, block_table, lengths,
                mrope_positions=mrope_positions,
                kernel_mode=ctx.kernel_mode,
                shard=ctx.shard if ctx.decode_head_shard else None,
                kv_spec=ctx.kv_spec)
        else:
            out, cache = attn_lib.decode_attend_batched(
                p["attn"], cfg, xn, cache, lengths, window=window,
                mrope_positions=mrope_positions)
        x = x + out
        x, _ = _ffn_part(p, cfg, x, ctx, dropless=True)
        return x, cache
    if kind == "rglru":
        out, cache = ssm.apply_rglru_decode(p["rec"], cfg, xn, cache)
        x = x + out
        x, _ = _ffn_part(p, cfg, x, ctx, dropless=True)
        return x, cache
    if kind == "mlstm":
        out, cache = ssm.apply_mlstm_decode(p["mix"], cfg, xn, cache)
        return x + out, cache
    if kind == "slstm":
        out, cache = ssm.apply_slstm_decode(p["mix"], cfg, xn, cache)
        return x + out, cache
    raise ValueError(kind)


def _decode_window_scan(p, cfg: ModelConfig, kind: str, x, cache,
                        block_table, lengths, ctx: RunCtx):
    """Scan the stock single-token decode cell over a K+1-token verify
    window, stacking the PER-POSITION cache states as candidates.

    x: (B, K1, d). Returns (out (B, K1, d), candidates) where every
    cache leaf gains a K1 axis after its batch axis ((B, K1, ...)):
    candidate j is the state after consuming fed tokens 0..j. Because
    the cells are causal, candidate j is independent of any rejected
    token after j — selection at the accept boundary is exact, and the
    math per position is bit-identical to the non-speculative decode
    path (same cells, same order).
    """
    K1 = x.shape[1]

    def cell(c, inp):
        xt, j = inp
        xo, c2 = apply_block_decode_paged(p, cfg, kind, xt[:, None], c,
                                          block_table, lengths + j, ctx)
        return c2, (xo[:, 0], c2)

    _, (outs, stk) = jax.lax.scan(
        cell, cache,
        (jnp.moveaxis(x, 1, 0), jnp.arange(K1, dtype=jnp.int32)))
    out = jnp.moveaxis(outs, 0, 1)
    cands = jax.tree.map(lambda t: jnp.moveaxis(t, 0, 1), stk)
    return out, cands


def apply_block_verify_paged(p, cfg: ModelConfig, kind: str, x, cache,
                             block_table, lengths, ctx: RunCtx):
    """Multi-token block step for the speculative verify window.

    x: (B, K1, d) — hidden states for the K+1 fed tokens. Full-attention
    layers run ONE multi-query pass over the paged pool (state commits
    by construction: the rejected tail is rolled back by the host's
    length-pointer rewind, no block copies); windowed rings and SSM
    kinds scan the stock decode cell and stack per-position candidate
    states for the later commit selection (``select_verify_state``).
    """
    if kind in ("attn", "local") and _window_for(cfg, kind) is None:
        xn = layers.apply_norm(cfg.norm, p["ln1"], x)
        out, pool = attn_lib.verify_attend_paged(
            p["attn"], cfg, xn, cache, block_table, lengths,
            kernel_mode=ctx.kernel_mode,
            shard=ctx.shard if ctx.decode_head_shard else None,
            kv_spec=ctx.kv_spec)
        x = x + out
        x, _ = _ffn_part(p, cfg, x, ctx, dropless=True)
        return x, pool
    return _decode_window_scan(p, cfg, kind, x, cache, block_table,
                               lengths, ctx)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype):
    if kind in ("attn", "local"):
        return attn_lib.init_kv_cache(cfg, batch, max_len, dtype,
                                      window=_window_for(cfg, kind))
    if kind == "rglru":
        return ssm.init_rglru_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return ssm.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Groups (pattern periods) and the full LM
# ---------------------------------------------------------------------------


def layer_groups(cfg: ModelConfig):
    """[(pattern tuple, repeat count), ...] covering all layers in order."""
    p = tuple(cfg.block_pattern)
    full, rem = divmod(cfg.n_layers, len(p))
    groups = []
    if full:
        groups.append((p, full))
    if rem:
        groups.append((p[:rem], 1))
    return groups


def layer_walk(cfg: ModelConfig):
    """Yield ``(group_key, pattern, count)`` per scan group, in order.

    THE shared walk over the stacked-group structure: every tree that
    mirrors ``params["groups"]`` (decode caches, paged pools, masks,
    sharding specs) is built or consumed through this generator (or
    ``map_layer_tree`` / ``scan_groups`` on top of it), so the group/
    pattern keying ``g{g}``/``p{pi}`` is defined in exactly one place.
    """
    for g, (pattern, count) in enumerate(layer_groups(cfg)):
        yield f"g{g}", pattern, count


def map_layer_tree(cfg: ModelConfig, fn):
    """Build ``{gk: {pk: fn(gk, pk, kind, count)}}`` over ``layer_walk``.

    The shared constructor for every same-structure side tree (caches,
    pools, pool masks, partition specs): ``fn`` sees the group/pattern
    keys plus the layer KIND and stack count and returns one subtree.
    """
    return {gk: {f"p{pi}": fn(gk, f"p{pi}", kind, count)
                 for pi, kind in enumerate(pattern)}
            for gk, pattern, count in layer_walk(cfg)}


def scan_groups(params, cfg: ModelConfig, x, trees, block_fn, ctx: RunCtx):
    """Scan ``x`` through every stacked group, threading a side tree.

    The shared driver behind ``decode_step`` / ``decode_step_paged`` /
    ``decode_verify_paged``: ``trees`` mirrors ``params["groups"]`` (a
    decode cache or paged pool) and ``block_fn(kind, layer_params,
    layer_tree, x) -> (x, new_layer_tree)`` is the per-layer cell.
    Returns ``(x, new_trees)`` with ``new_trees`` same-structure.
    """
    new_trees = {}
    for gk, pattern, count in layer_walk(cfg):
        gp = params["groups"][gk]
        gc = trees[gk]

        def body(xc, scanned, pattern=pattern):
            layer_params, layer_tree = scanned
            new_lt = {}
            for pi, kind in enumerate(pattern):
                xc, nt = block_fn(kind, layer_params[f"p{pi}"],
                                  layer_tree[f"p{pi}"], xc)
                new_lt[f"p{pi}"] = nt
            return xc, new_lt

        x, new_gc = jax.lax.scan(body, x, (gp, gc),
                                 unroll=True if ctx.scan_unroll else 1)
        new_trees[gk] = new_gc
    return x, new_trees


def _is_pool_kind(cfg: ModelConfig, kind: str) -> bool:
    """True for layer kinds whose decode state lives in the shared block
    pool (full attention); windowed rings and SSM carries are per-slot."""
    return kind in ("attn", "local") and _window_for(cfg, kind) is None


def init_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4 + cfg.n_layers)
    params = {"embed": layers.truncated_normal_init(
        ks[0], (cfg.vocab_size, cfg.d_model), dtype, stddev=1.0)}
    ki = 1
    groups = {}
    for gk, pattern, count in layer_walk(cfg):
        gp = {}
        for pi, kind in enumerate(pattern):
            stacked = [init_block(ks[ki + i], cfg, kind, dtype)
                       for i in range(count)]
            ki += count
            gp[f"p{pi}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
        groups[gk] = gp
    params["groups"] = groups
    params["final_norm"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.truncated_normal_init(
            ks[ki], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def _constrain_residual(x, ctx):
    if ctx.residual_spec == "none" or ctx.shard is None:
        return x
    from repro.launch import sharding as shlib
    sh = ctx.shard
    if ctx.residual_spec == "seq":
        return shlib.constrain(x, sh, sh.batch_axes, sh.tp_axis, None)
    return shlib.constrain(x, sh, sh.batch_axes, None, None)


def _pattern_runs(pattern):
    """[(kind, start_pos, run_len), ...] for consecutive equal kinds."""
    runs = []
    for pi, kind in enumerate(pattern):
        if runs and runs[-1][0] == kind:
            runs[-1][2] += 1
        else:
            runs.append([kind, pi, 1])
    return [tuple(r) for r in runs]


def _apply_groups(params, cfg, x, positions, ctx, mrope_positions=None,
                  with_cache=False, cache_len=None, prefill_length=None):
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for gk, pattern, count in layer_walk(cfg):
        gp = params["groups"][gk]
        runs = _pattern_runs(pattern)

        def body(carry, layer_params, runs=runs):
            xc, aux = carry
            layer_caches = {}
            for kind, start, n in runs:
                def one(xb, lp, kind=kind):
                    return apply_block(lp, cfg, kind, xb, positions, ctx,
                                       mrope_positions, with_cache,
                                       cache_len, prefill_length)
                if ctx.remat == "full":
                    one = jax.checkpoint(one)
                if n == 1:
                    xc, a, cache = one(xc, layer_params[f"p{start}"])
                    xc = _constrain_residual(xc, ctx)
                    aux = aux + a
                    if with_cache:
                        layer_caches[f"p{start}"] = cache
                else:
                    # Runs of identical kinds become an INNER scan: the
                    # period body stays O(1) in run length, keeping XLA
                    # compile time tractable (xLSTM's m^7 s period body
                    # compiled superlinearly when inlined 7x).
                    stacked = jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[layer_params[f"p{start + j}"] for j in range(n)])

                    def inner(c2, lp2, one=one):
                        x2, a2 = c2
                        x3, a3, cache = one(x2, lp2)
                        x3 = _constrain_residual(x3, ctx)
                        return (x3, a2 + a3), cache

                    (xc, aux), run_caches = jax.lax.scan(
                        inner, (xc, aux), stacked,
                        unroll=True if ctx.scan_unroll else 1)
                    if with_cache:
                        for j in range(n):
                            layer_caches[f"p{start + j}"] = jax.tree.map(
                                lambda t, j=j: t[j], run_caches)
            return (xc, aux), layer_caches if with_cache else None

        (x, aux_total), group_caches = jax.lax.scan(
            body, (x, aux_total), gp, unroll=True if ctx.scan_unroll else 1)
        if with_cache:
            caches[gk] = group_caches
    return x, aux_total, caches if with_cache else None


def _logits(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32)


def _embed(params, cfg, tokens, visual_embeds=None, pos_offset=0,
           shard=None):
    x = layers.vocab_parallel_lookup(params["embed"], tokens, shard)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.visual_prefix and visual_embeds is not None:
        x = jnp.concatenate([visual_embeds.astype(x.dtype),
                             x[:, cfg.visual_prefix:]], axis=1)
    if cfg.pos_embed == "sinusoidal":
        positions = pos_offset + jnp.arange(x.shape[1])
        x = x + layers.sinusoidal_embed(positions, cfg.d_model, x.dtype)
    return x


def forward_hidden(params, cfg: ModelConfig, tokens, ctx: RunCtx,
                   visual_embeds=None, mrope_positions=None):
    """tokens: (B, S) -> final-norm hidden (B, S, d), aux scalar."""
    B, S = tokens.shape
    x = _embed(params, cfg, tokens, visual_embeds, shard=ctx.shard)
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux, _ = _apply_groups(params, cfg, x, positions, ctx,
                              mrope_positions)
    return layers.apply_norm(cfg.norm, params["final_norm"], x), aux


def forward(params, cfg: ModelConfig, tokens, ctx: RunCtx,
            visual_embeds=None, mrope_positions=None):
    """tokens: (B, S) -> logits (B, S, V) f32, aux scalar."""
    x, aux = forward_hidden(params, cfg, tokens, ctx, visual_embeds,
                            mrope_positions)
    return _logits(params, cfg, x), aux


def _ce_from_hidden(params, cfg, x, tgt, ctx: RunCtx):
    """Cross-entropy from hidden states; ctx.ce_chunk > 0 scans over
    sequence chunks so the full (B, S, V) logits never materialize
    (§Perf: at gemma's 256k vocab full train logits are 13 GB f32 per
    device even vocab-sharded)."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    C = ctx.ce_chunk
    B, S, _ = x.shape
    if not C or S % C != 0 or S == C:
        logits = (x @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    nc = S // C
    xs = jnp.moveaxis(x.reshape(B, nc, C, x.shape[-1]), 1, 0)
    ts = jnp.moveaxis(tgt.reshape(B, nc, C), 1, 0)

    def body(acc, inp):
        xc, tc = inp
        logits = (xc @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts))
    return total / (B * S)


def loss_fn(params, cfg: ModelConfig, batch, ctx: RunCtx):
    """batch: {tokens (B, S), targets (B, S)} -> (loss, metrics)."""
    x, aux = forward_hidden(params, cfg, batch["tokens"], ctx,
                            batch.get("visual_embeds"),
                            batch.get("mrope_positions"))
    ce = _ce_from_hidden(params, cfg, x, batch["targets"], ctx)
    loss = ce + cfg.moe_aux_coef * aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked decode caches mirroring the group structure."""
    dtype = jnp.dtype(cfg.dtype)

    def one(gk, pk, kind, count):
        c = init_block_cache(cfg, kind, batch, max_len, dtype)
        return jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (count,) + t.shape), c)

    return map_layer_tree(cfg, one)


def init_paged_cache(cfg: ModelConfig, layout, spec=None):
    """Stacked per-layer caches for the paged serving engine.

    Full-attention layers share a block pool (paged_kv.init_layer_pool);
    windowed and SSM layers keep per-slot bounded state exactly as in
    ``init_cache``. ``spec`` (a quantized ``paged_kv.PoolSpec``) switches
    the full-attention pools to int8/fp8 payloads with per-(token, head)
    scale leaves; windowed rings and SSM state stay full-precision. The
    block table and lengths live with the scheduler, not in this tree —
    all layers of a sequence share one table.
    """
    from repro.models import paged_kv

    dtype = jnp.dtype(cfg.dtype)

    def one(gk, pk, kind, count):
        if kind in ("attn", "local"):
            c = paged_kv.init_layer_pool(
                cfg, layout, dtype, window=_window_for(cfg, kind),
                spec=spec)
        else:
            c = init_block_cache(cfg, kind, layout.num_slots,
                                 layout.max_len, dtype)
        return jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (count,) + t.shape), c)

    return map_layer_tree(cfg, one)


def paged_pool_mask(cfg: ModelConfig, layout, spec=None):
    """Same-structure tree of kind strings over ``init_paged_cache``:
    ``"pool"`` for full-attention BLOCK-POOL leaves (block axis at
    axis 1, after the stacked layer-count axis) and ``"slot"`` for
    PER-SLOT state (windowed rings, SSM carries, conv tails — slot axis
    also at axis 1). Encoder-decoder trees add ``"cross"`` for the
    cross-KV arena (arena-row axis at axis 1). The classification walks
    layer KINDS, exactly like ``paged_cache_specs`` — never shapes, so a
    ring buffer whose slot count happens to equal the pool's block count
    cannot be misclassified. Consumed by ``paged_kv.extract_blocks``/
    ``insert_blocks`` (KV migration between replicas)."""
    shapes = jax.eval_shape(lambda: init_paged_cache(cfg, layout, spec))

    def one(gk, pk, kind, count):
        tag = "pool" if _is_pool_kind(cfg, kind) else "slot"
        return jax.tree.map(lambda t: tag, shapes[gk][pk])

    return map_layer_tree(cfg, one)


def paged_cache_specs(cfg: ModelConfig, layout, shard, spec=None):
    """PartitionSpecs for the ``init_paged_cache`` tree under a mesh:
    block pools head-sharded over TP (every device owns its kv-head
    shard of every block, replicated over data axes), ring buffers and
    SSM state on the standard per-slot cache rules. Pool leaves are
    identified by LAYER KIND (the same walk as ``init_paged_cache``),
    not by shape. Quantized pools (``spec``) add 4-D scale leaves, whose
    kv-head axis lands on the same TP axis via the truncating spec fit."""
    from repro.launch import sharding as shlib

    shapes = jax.eval_shape(lambda: init_paged_cache(cfg, layout, spec))

    def one(gk, pk, kind, count):
        sub = shapes[gk][pk]
        if _is_pool_kind(cfg, kind):
            return jax.tree.map(
                lambda t: shlib.paged_pool_spec(t, shard), sub)
        return shlib.batch_specs(sub, shard)

    return map_layer_tree(cfg, one)


def pack_prefill_into_paged(cfg: ModelConfig, layout, pools, dense_caches,
                            row_of_slot, valid, block_ids, spec=None):
    """Install a BATCH of prefilled dense caches (from ``prefill`` with
    ``max_len == block_ids.shape[1] * block_size``) into the paged tree.

    ``block_ids`` is (N, nbp) — per prefill-batch row, the physical
    destinations of its cache blocks (pad tails at the null block);
    ``row_of_slot`` ((num_slots,) int32) and ``valid`` ((num_slots,)
    bool) give the inverse slot<-row map for per-slot state (rings, SSM
    carries, conv tails): slot s takes row ``row_of_slot[s]`` where
    ``valid[s]``. Pure function; jit per (prompt-bucket, batch-bucket).
    """
    from repro.models import paged_kv

    def one(gk, pk, kind, count):
        pool = pools[gk][pk]
        dense = dense_caches[gk][pk]
        if kind in ("attn", "local"):
            if _window_for(cfg, kind) is None:
                return paged_kv.pack_prefill_kv(
                    pool, dense, block_ids, layout.block_size, spec=spec)
            return {
                "k": paged_kv.pack_prefill_ring(
                    pool["k"], dense["k"], row_of_slot, valid),
                "v": paged_kv.pack_prefill_ring(
                    pool["v"], dense["v"], row_of_slot, valid)}
        return paged_kv.pack_prefill_state(pool, dense, row_of_slot, valid)

    return map_layer_tree(cfg, one)


def decode_step_paged(params, cfg: ModelConfig, pools, block_table, lengths,
                      tokens, ctx: RunCtx):
    """Shape-stable continuous-batching decode step.

    tokens: (B, 1) — one token per decode slot; lengths: (B,) int32 tokens
    already cached per slot (the new token's position); block_table:
    (B, NBMAX) int32. Retired slots ride along pointed at the null block,
    their outputs discarded by the scheduler. Returns
    (logits (B, V) f32, new pools).
    """
    if cfg.enc_dec or cfg.rope_style == "mrope" or cfg.pos_embed != "none":
        raise NotImplementedError(
            "paged decode supports decoder-only rope/none-pos models")
    x = _embed(params, cfg, tokens, shard=ctx.shard)

    def block_fn(kind, lp, lc, xc):
        return apply_block_decode_paged(lp, cfg, kind, xc, lc,
                                        block_table, lengths, ctx)

    x, new_pools = scan_groups(params, cfg, x, pools, block_fn, ctx)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x)[:, 0], new_pools


def select_verify_state(cfg: ModelConfig, cands, commit):
    """Commit a verify window's per-slot state at the accept boundary.

    ``cands`` is the candidate tree from ``decode_verify_paged``'s layer
    walk: full-attention pool leaves are already final (length-pointer
    rollback), every other leaf is (count, B, K1, ...) — candidate j is
    the state after fed token j. ``commit``: (B,) int32 in [1, K1] —
    keep the state after fed token ``commit - 1``.
    """
    idx = jnp.maximum(commit - 1, 0).astype(jnp.int32)

    def sel(leaf):
        ix = idx.reshape((1, -1, 1) + (1,) * (leaf.ndim - 3))
        return jnp.take_along_axis(leaf, ix, axis=2)[:, :, 0]

    def one(gk, pk, kind, count):
        sub = cands[gk][pk]
        if _is_pool_kind(cfg, kind):
            return sub
        return jax.tree.map(sel, sub)

    return map_layer_tree(cfg, one)


def decode_verify_paged(params, cfg: ModelConfig, pools, block_table,
                        lengths, tokens, commit_fn, ctx: RunCtx):
    """Speculative-decode verify: score a K+1-token window in ONE pass.

    tokens: (B, K1) — per slot, the last accepted token followed by K
    draft tokens; fed token j is cached at position ``lengths[b] + j``
    and logits row j scores the NEXT position — so row j is exactly what
    ``decode_step_paged`` would have returned after feeding tokens
    0..j. ``commit_fn(logits (B, K1, V)) -> (out_tokens, commit)`` is
    the accept rule traced inline (engine/sampling.verify_accept);
    ``commit[b]`` in [1, K1] counts the fed tokens whose cache state to
    keep. Full-attention pools commit by construction (the host rewinds
    the length pointer over the rejected tail — no block copies);
    per-slot states are selected at the accept boundary. Returns
    (out_tokens (B, K1), commit (B,), new_pools).
    """
    if cfg.enc_dec or cfg.rope_style == "mrope" or cfg.pos_embed != "none":
        raise NotImplementedError(
            "paged verify supports decoder-only rope/none-pos models")
    x = _embed(params, cfg, tokens, shard=ctx.shard)

    def block_fn(kind, lp, lc, xc):
        return apply_block_verify_paged(lp, cfg, kind, xc, lc,
                                        block_table, lengths, ctx)

    x, cands = scan_groups(params, cfg, x, pools, block_fn, ctx)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits(params, cfg, x)                  # (B, K1, V) f32
    out_tokens, commit = commit_fn(logits)
    return out_tokens, commit, select_verify_state(cfg, cands, commit)


def prefill_supports_ragged(cfg: ModelConfig) -> bool:
    """True when right-padded (bucketed / ragged-batch) prefill is exact
    for this architecture: every block kind captures its decode state at
    the traced true length (attention rings and RG-LRU by gather/
    recompute, mlstm by gate freezing, slstm by carry selection), and
    positions are either relative (rope) or absent. The serving engines
    gate on this and fall back to exact-length prefill otherwise."""
    kinds = set(cfg.block_pattern)
    return (kinds <= {"attn", "local", "rglru", "mlstm", "slstm"}
            and not cfg.enc_dec and not cfg.visual_prefix
            and cfg.rope_style in ("rope", "none")
            and cfg.pos_embed == "none")


def prefill(params, cfg: ModelConfig, tokens, ctx: RunCtx, max_len=None,
            visual_embeds=None, mrope_positions=None, length=None):
    """Prefill: logits for the full prompt + a decode cache at max_len.

    ``length`` ((B,) int32, traced) marks RIGHT-padded prompts: row b's
    real tokens are ``tokens[b, :length[b]]``. Causal attention already
    ignores the padded tail for every real query, so logits at real
    positions are exact; the emitted caches capture per-row state at the
    true length (see ``apply_block``). Requires
    ``prefill_supports_ragged(cfg)``.
    """
    B, S = tokens.shape
    if length is not None and not prefill_supports_ragged(cfg):
        raise NotImplementedError(
            f"{cfg.name}: padded prefill needs a decoder-only stack "
            "with relative/absent positions")
    x = _embed(params, cfg, tokens, visual_embeds, shard=ctx.shard)
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux, caches = _apply_groups(params, cfg, x, positions, ctx,
                                   mrope_positions, with_cache=True,
                                   cache_len=max_len or S,
                                   prefill_length=length)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x), caches


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, ctx: RunCtx,
                mrope_positions=None):
    """tokens: (B, 1) at position ``pos`` -> (logits (B, V), new cache)."""
    x = _embed(params, cfg, tokens, pos_offset=pos, shard=ctx.shard)

    def block_fn(kind, lp, lc, xc):
        return apply_block_decode(lp, cfg, kind, xc, lc, pos, ctx,
                                  mrope_positions)

    x, new_caches = scan_groups(params, cfg, x, cache, block_fn, ctx)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return _logits(params, cfg, x)[:, 0], new_caches
