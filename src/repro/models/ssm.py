"""Recurrent blocks: xLSTM's mLSTM/sLSTM and RecurrentGemma's RG-LRU.

mLSTM uses a **chunkwise-parallel** form for training/prefill (linear in
sequence length — the reason xlstm/recurrentgemma run the long_500k cell)
and an O(1)-state recurrent step for decode. The two forms are
algebraically identical (tests/test_ssm.py checks chunkwise == step-by-
step). All gate math is log-space stabilized (the m-state of the xLSTM
paper).

sLSTM has a true recurrent matrix R and "cannot be parallelized" (xLSTM
paper) — it is a lax.scan over time, block-diagonal per head.

RG-LRU is a gated diagonal linear recurrence; training/prefill lower
through kernels/ops.rglru_scan (STX chunked-scan kernel on TPU).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM)
# ---------------------------------------------------------------------------


def init_mlstm_block(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "w_up": layers.truncated_normal_init(ks[0], (d, 2 * d), dtype),
        "conv": layers.init_conv1d(ks[1], d, 4, dtype),
        "wq": layers.truncated_normal_init(ks[2], (d, d), dtype),
        "wk": layers.truncated_normal_init(ks[3], (d, d), dtype),
        "wv": layers.truncated_normal_init(ks[4], (d, d), dtype),
        "w_if": layers.truncated_normal_init(ks[5], (d, 2 * cfg.n_heads), dtype),
        # Positive forget bias => long memory at init (standard xLSTM init).
        "b_if": jnp.concatenate([jnp.zeros((cfg.n_heads,), dtype),
                                 jnp.linspace(3.0, 6.0, cfg.n_heads).astype(dtype)]),
        "gn_scale": jnp.ones((d,), dtype),
        "w_down": layers.truncated_normal_init(ks[6], (d, d), dtype),
    }


def mlstm_chunkwise(q, k, v, ig, fg, chunk: int = 256, state=None,
                    unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B, H, S, hd); ig/fg: (B, H, S) raw gate pre-activations.
    Returns (h (B,H,S,hd), final_state (C, n, m)).
    """
    B, H, S, hd = q.shape
    S0 = S
    pad = (-S) % chunk
    if pad:
        # VLA tail padding: pad gates so pads are no-ops on the carried
        # state (input gate -> 0 weight, forget gate -> keep).
        zp = [(0, 0), (0, 0), (0, pad), (0, 0)]
        gp = [(0, 0), (0, 0), (0, pad)]
        q, k, v = (jnp.pad(t, zp) for t in (q, k, v))
        ig = jnp.pad(ig, gp, constant_values=-1e30)
        fg = jnp.pad(fg, gp, constant_values=30.0)
        S = S + pad
    L, N = chunk, S // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(fg.astype(jnp.float32))     # log forget
    li = ig.astype(jnp.float32)                          # log input

    rs = lambda x: x.reshape(B, H, N, L, *x.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)
    # -> (N, B, H, L, ...)
    qc, kc, vc = rs(qf), rs(kf), rs(vf)
    lfc, lic = rs(lf), rs(li)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        C, n, m = carry
        qj, kj, vj, lfj, lij = inp                      # (B, H, L, ...)
        a = jnp.cumsum(lfj, axis=-1)                    # inclusive decay sums
        A = a[..., -1:]                                 # (B, H, 1)
        # Intra-chunk log weights D_ij = a_i - a_j + li_j (j <= i).
        D = a[..., :, None] - a[..., None, :] + lij[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)                   # (B, H, L)
        m_inter = m[..., None] + a                      # (B, H, L)
        m_i = jnp.maximum(m_inter, m_intra)
        m_i = jnp.maximum(m_i, -1e30)                   # keep finite
        Sij = jnp.einsum("bhid,bhjd->bhij", qj, kj) * jnp.exp(D - m_i[..., None])
        inter_w = jnp.exp(m_inter - m_i)                # (B, H, L)
        num = (inter_w[..., None] * jnp.einsum("bhid,bhde->bhie", qj, C)
               + jnp.einsum("bhij,bhje->bhie", Sij, vj))
        den = (inter_w * jnp.einsum("bhid,bhd->bhi", qj, n)
               + jnp.sum(Sij, axis=-1))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # Carry update.
        m_k = A - a + lij                               # gate weight per key
        m_new = jnp.maximum(m[..., None] + A, jnp.max(m_k, -1, keepdims=True))[..., 0]
        carry_w = jnp.exp(m[..., None] + A - m_new[..., None])[..., 0]
        kw = jnp.exp(m_k - m_new[..., None])            # (B, H, L)
        C = carry_w[..., None, None] * C + jnp.einsum("bhj,bhjd,bhje->bhde", kw, kj, vj)
        n = carry_w[..., None] * n + jnp.einsum("bhj,bhjd->bhd", kw, kj)
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qc, kc, vc, lfc, lic),
                                 unroll=True if unroll else 1)
    h = hs.swapaxes(0, 2).swapaxes(0, 1).reshape(B, H, S, hd)
    return h[:, :, :S0].astype(q.dtype), (C, n, m)


def mlstm_step(q, k, v, ig, fg, state):
    """Single-token recurrent mLSTM. q,k,v: (B, H, hd); gates (B, H)."""
    C, n, m = state
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    li = ig.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    C = fw[..., None, None] * C + iw[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n = fw[..., None] * n + iw[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C, n, m_new)


def _mlstm_qkv_gates(params, cfg, xn, conv_state=None, length=None):
    B, S, d = xn.shape
    H = cfg.n_heads
    hd = d // H
    up = xn @ params["w_up"]
    c, z = jnp.split(up, 2, axis=-1)
    cc, conv_state = layers.apply_conv1d(params["conv"], c, conv_state)
    if length is not None:
        # Right-padded prefill: the emitted carry must hold the last
        # width-1 REAL conv inputs, not the padded tail.
        conv_state = layers.conv_state_at(
            c, params["conv"]["w"].shape[0], length)
    cc = jax.nn.silu(cc)
    split_heads = lambda t: t.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    q = split_heads(cc @ params["wq"])
    k = split_heads(cc @ params["wk"])
    v = split_heads(c @ params["wv"])
    gates = c @ params["w_if"] + params["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)               # (B, S, H)
    return q, k, v, ig.transpose(0, 2, 1), fg.transpose(0, 2, 1), z, conv_state


def freeze_gates_past(ig, fg, length):
    """Mask mLSTM gate pre-activations past each row's true length so the
    chunkwise scan carries state FROZEN at ``length`` — the trick
    ``mlstm_chunkwise`` plays on its own chunk-tail padding, made exact:
    input gate -> -1e30 (zero key weight) and forget gate -> 1e30
    (log_sigmoid(1e30) == -0.0, so pad steps decay nothing). The carried
    (C, n, m) then equals the state at ``length``; pad-position outputs
    are garbage and must not be read. ig/fg: (B, H, S); length: (B,)."""
    pad = jnp.arange(ig.shape[-1])[None, None, :] >= length[:, None, None]
    return (jnp.where(pad, -1e30, ig).astype(ig.dtype),
            jnp.where(pad, 1e30, fg).astype(fg.dtype))


def apply_mlstm_block(params, cfg, xn, chunk: int = 256, unroll: bool = False):
    """Full-sequence mLSTM mixing (pre-normed input xn). Returns delta."""
    B, S, d = xn.shape
    q, k, v, ig, fg, z, _ = _mlstm_qkv_gates(params, cfg, xn)
    h, _ = mlstm_chunkwise(q, k, v, ig, fg, chunk=min(chunk, S), unroll=unroll)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d)
    h = layers.group_norm(h, params["gn_scale"], cfg.n_heads)
    return (h * jax.nn.silu(z)) @ params["w_down"]


def init_mlstm_cache(cfg, batch, dtype):
    H, d = cfg.n_heads, cfg.d_model
    hd = d // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d), dtype),
    }


def apply_mlstm_decode(params, cfg, xn, cache):
    B, _, d = xn.shape
    q, k, v, ig, fg, z, conv_state = _mlstm_qkv_gates(
        params, cfg, xn, cache["conv"])
    h, (C, n, m) = mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                              ig[:, :, 0], fg[:, :, 0],
                              (cache["C"], cache["n"], cache["m"]))
    h = h.reshape(B, 1, d)
    h = layers.group_norm(h, params["gn_scale"], cfg.n_heads)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    return out, {"C": C, "n": n, "m": m, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with recurrent matrix; sequential by design)
# ---------------------------------------------------------------------------


def init_slstm_block(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 5)
    ffp = int(round(d * 4 / 3 / 64)) * 64 or 64          # xLSTM pf=4/3 FFN
    return {
        "w_zifo": layers.truncated_normal_init(ks[0], (d, 4 * d), dtype),
        "r_zifo": layers.truncated_normal_init(
            ks[1], (4, H, hd, hd), dtype, stddev=1.0 / math.sqrt(hd)),
        "b_zifo": jnp.concatenate([
            jnp.zeros((2 * d,), dtype),
            jnp.full((d,), 4.0, dtype),                  # forget bias
            jnp.zeros((d,), dtype)]),
        "gn_scale": jnp.ones((d,), dtype),
        "ff": layers.init_mlp(ks[2], d, ffp, dtype, gated=True),
    }


def _slstm_cell(params, cfg, x_part, state):
    """One sLSTM step. x_part: (B, 4d) precomputed input projection."""
    h, c, n, m = state                                   # h: (B, H, hd)
    B = x_part.shape[0]
    H = cfg.n_heads
    d = cfg.d_model
    hd = d // H
    rec = jnp.einsum("bhd,ghde->bghe", h, params["r_zifo"].astype(jnp.float32))
    rec = rec.reshape(B, 4 * d)
    pre = x_part.astype(jnp.float32) + rec + params["b_zifo"].astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zt).reshape(B, H, hd)
    ot = jax.nn.sigmoid(ot).reshape(B, H, hd)
    li = it.reshape(B, H, hd)
    lf = jax.nn.log_sigmoid(ft).reshape(B, H, hd)
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    c = fw * c + iw * zt
    n = fw * n + iw
    hidden = ot * c / jnp.maximum(n, jnp.exp(-m_new))
    return hidden, (hidden, c, n, m_new)


def apply_slstm_block(params, cfg, xn):
    """Sequential sLSTM over (B, S, d) pre-normed input. Returns delta."""
    B, S, d = xn.shape
    H = cfg.n_heads
    hd = d // H
    x_parts = xn @ params["w_zifo"]                      # (B, S, 4d)
    state = (jnp.zeros((B, H, hd), jnp.float32),) * 3 + (
        jnp.full((B, H, hd), -1e30, jnp.float32),)

    def step(st, xp):
        hidden, st = _slstm_cell(params, cfg, xp, st)
        return st, hidden

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(x_parts, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(xn.dtype)
    h = layers.group_norm(h, params["gn_scale"], H)
    return layers.apply_mlp(params["ff"], h, "gelu")


def init_slstm_cache(cfg, batch, dtype):
    H, d = cfg.n_heads, cfg.d_model
    hd = d // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def apply_slstm_decode(params, cfg, xn, cache):
    B, _, d = xn.shape
    xp = (xn @ params["w_zifo"])[:, 0]
    hidden, (h, c, n, m) = _slstm_cell(
        params, cfg, xp, (cache["h"], cache["c"], cache["n"], cache["m"]))
    out = hidden.reshape(B, 1, d).astype(xn.dtype)
    out = layers.group_norm(out, params["gn_scale"], cfg.n_heads)
    out = layers.apply_mlp(params["ff"], out, "gelu")
    return out, {"h": h, "c": c, "n": n, "m": m}


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_block(key, cfg, dtype):
    d = cfg.d_model
    dr = cfg.rnn_width or d
    ks = jax.random.split(key, 7)
    # Lambda init so that a = exp(-c*softplus(L)) spans ~(0.9, 0.999).
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    return {
        "w_x": layers.truncated_normal_init(ks[1], (d, dr), dtype),
        "w_gate": layers.truncated_normal_init(ks[2], (d, dr), dtype),
        "conv": layers.init_conv1d(ks[3], dr, 4, dtype),
        "lam": lam.astype(jnp.float32),
        "w_a": layers.truncated_normal_init(ks[4], (dr, dr), dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_i": layers.truncated_normal_init(ks[5], (dr, dr), dtype),
        "b_i": jnp.zeros((dr,), dtype),
        "w_out": layers.truncated_normal_init(ks[6], (dr, d), dtype),
    }


def _rglru_coeffs(params, y):
    """Gated decay a_t and driven input b_t from conv output y (f32)."""
    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(yf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(yf @ params["w_i"].astype(jnp.float32)
                       + params["b_i"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * (i * yf)


def apply_rglru_block(params, cfg, xn, kernel_mode="auto"):
    """Full-sequence Griffin recurrent mixing. Returns delta."""
    gate = jax.nn.gelu(xn @ params["w_gate"], approximate=True)
    xb = xn @ params["w_x"]
    y, _ = layers.apply_conv1d(params["conv"], xb)
    a, b = _rglru_coeffs(params, y)
    h = kops.rglru_scan(a, b, mode=kernel_mode).astype(xn.dtype)
    return (gate * h) @ params["w_out"]


def init_rglru_cache(cfg, batch, dtype):
    dr = cfg.rnn_width or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, 3, dr), dtype)}


def apply_rglru_decode(params, cfg, xn, cache):
    gate = jax.nn.gelu(xn @ params["w_gate"], approximate=True)
    xb = xn @ params["w_x"]
    y, conv_state = layers.apply_conv1d(params["conv"], xb, cache["conv"])
    a, b = _rglru_coeffs(params, y)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (gate * h[:, None].astype(xn.dtype)) @ params["w_out"]
    return out, {"h": h, "conv": conv_state}
