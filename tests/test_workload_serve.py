"""Three workload classes, one serving stack (tentpole coverage).

MoE and encoder-decoder requests flow through the SAME ``Engine`` as
dense decoder-only traffic and come out bit-identical to their dense
``prefill`` + ``decode_step`` oracles:

  * encoder-decoder (whisper smoke): requests carry encoder features
    (``Request.encoder_features``); admission writes the cross-KV arena
    once, decode reads it per slot; greedy AND seeded sampling match
    the unbatched dense oracle; identical feature arrays share one
    refcounted arena row; preemption frees rows (zero arena leaks) and
    resume re-encodes, still bit-identical;
  * MoE (qwen3-moe / kimi-k2 smokes): serving runs DROPLESS expert
    capacity, so routed outputs are per-token — independent of right
    padding, co-batched traffic and batch width — and the engine
    matches the per-request oracle exactly, with and without
    speculative decoding;
  * validation: ``check_request`` rejects encoder features on
    non-enc-dec configs and their absence on enc-dec configs with
    errors naming the config family; static/speculative backends
    reject cross-attention up front;
  * compile caps: encoder frame lengths get their OWN pow-2 bucket
    axis — prefill compiles stay O(log) per axis.

Sharded variants (expert-sharded MoE decode, submesh identity) live in
tests/test_sharded_serve.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import (DisaggregatedEngine, Engine, EngineConfig,
                                 ReplicaSet, SamplingParams)
from repro.launch.engine.api import Request
from repro.launch.engine.sampling import sample_tokens
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx

CTX = RunCtx(kernel_mode="ref")


@pytest.fixture(scope="module")
def whisper():
    cfg = get_config("whisper_base").smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module", params=["qwen3_moe_30b_a3b",
                                        "kimi_k2_1t_a32b"])
def moe_smoke(request):
    cfg = get_config(request.param).smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _frames(rng, cfg, n_frames):
    return jnp.asarray(rng.normal(size=(n_frames, cfg.d_model)),
                       jnp.float32)


def _oracle(model, params, prompt, sp, frames=None, max_len=48):
    """Unbatched dense reference: exact prefill + scalar decode loop,
    greedy or seeded (the engine's own per-request sampler rule)."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if frames is not None:
        batch["frames"] = frames[None]
    logits, cache = model.prefill(params, batch, CTX, max_len=max_len)
    row = logits[0, len(prompt) - 1]

    def sample(row, step):
        if sp.greedy:
            return int(jnp.argmax(row))
        return int(sample_tokens(
            row[None].astype(jnp.float32),
            jnp.asarray([sp.seed], jnp.int32),
            jnp.asarray([step], jnp.int32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32))[0])

    out = [sample(row, 0)]
    while len(out) < sp.max_tokens:
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(len(prompt) + len(out) - 1), CTX)
        out.append(sample(lg[0], len(out)))
    return out


# -- cross-KV arena unit ------------------------------------------------


def test_cross_arena_alloc_share_free():
    a = paged_kv.CrossArena(3)
    assert a.free_count == 3 and a.used_count == 0
    r1 = a.alloc(key="feat-a")
    r2 = a.alloc(key="feat-b")
    assert r1 != r2 and paged_kv.NULL_ARENA not in (r1, r2)
    assert a.lookup("feat-a") == r1
    assert a.lookup("missing") == paged_kv.NULL_ARENA
    a.share(r1)                            # second request, same features
    assert a.refcount(r1) == 2 and a.used_count == 2
    a.free(r1)
    assert a.refcount(r1) == 1             # still resident
    assert a.lookup("feat-a") == r1
    a.free(r1)
    assert a.lookup("feat-a") == paged_kv.NULL_ARENA
    assert a.free_count == 2
    a.check_invariant()


def test_cross_arena_exhaustion_and_double_free():
    a = paged_kv.CrossArena(2)
    assert a.can_admit(2) and not a.can_admit(3)
    r1, r2 = a.alloc(), a.alloc()
    assert not a.can_admit(1)
    with pytest.raises(MemoryError):
        a.alloc()
    a.free(r1)
    with pytest.raises(ValueError, match="double-free"):
        a.free(r1)
    a.free(r2)
    a.check_invariant()
    assert a.free_count == 2


# -- encoder-decoder through the Engine ---------------------------------


def test_encdec_engine_matches_oracle_greedy_and_seeded(whisper, rng):
    """whisper smoke through Engine.generate == dense oracle, token for
    token, greedy and seeded (temperature high enough that the untrained
    smoke model actually produces varied streams)."""
    cfg, model, params = whisper
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 5, 9)]
    feats = [_frames(rng, cfg, F) for F in (5, 16, 9, 12)]
    sp = [SamplingParams(max_tokens=6),
          SamplingParams(max_tokens=6, temperature=8.0, seed=3),
          SamplingParams(max_tokens=5, temperature=10.0, top_k=32,
                         seed=7),
          SamplingParams(max_tokens=6)]
    want = [_oracle(model, params, p, s, frames=f)
            for p, s, f in zip(prompts, sp, feats)]
    eng = Engine(model, params,
                 EngineConfig(num_slots=3, block_size=4, num_blocks=33,
                              max_len=32), CTX)
    got = eng.generate(prompts, sp, encoder_features=feats)
    assert got == want, (got, want)
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    assert be.arena.used_count == 0
    be.arena.check_invariant()


def test_encdec_paged_layer_parity(whisper, rng):
    """Logit-level bar (stronger than token identity on a degenerate
    smoke model): paged admission + paged decode reproduce the dense
    path's logits at matched positions."""
    cfg, model, params = whisper
    prompt = [3, 1, 4, 1, 5]
    S = len(prompt)
    frames = _frames(rng, cfg, 11)
    logits_d, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32),
                 "frames": frames[None]}, CTX, max_len=16)
    tok = int(jnp.argmax(logits_d[0, -1]))
    dec_d, _ = model.decode_step(params, cache,
                                 jnp.asarray([[tok]], jnp.int32),
                                 jnp.int32(S), CTX)

    layout = paged_kv.PagedLayout(num_slots=2, num_blocks=16,
                                  block_size=4, max_len=16)
    pools = model.init_paged_cache(layout)
    Sb = 8                                 # right-padded prompt bucket
    toks = np.zeros((2, Sb), np.int32)
    toks[0, :S] = prompt
    fr = np.zeros((2, 16, cfg.d_model), np.float32)
    fr[0, :11] = np.asarray(frames)
    rows, pools = model.prefill_paged_encdec(
        params, pools, jnp.asarray(toks), jnp.asarray(fr),
        jnp.asarray([11, 0], jnp.int32), jnp.asarray([S, 1], jnp.int32),
        jnp.asarray([[1, 2], [0, 0]], jnp.int32),
        jnp.asarray([1, 0], jnp.int32), CTX)
    np.testing.assert_allclose(np.asarray(rows[0]),
                               np.asarray(logits_d[0, S - 1]),
                               rtol=1e-4, atol=1e-5)
    table = np.full((2, layout.max_blocks_per_seq), paged_kv.NULL_BLOCK,
                    np.int32)
    table[0, :2] = [1, 2]
    dec_p, _ = model.decode_step_paged(
        params, pools, jnp.asarray(table),
        jnp.asarray([S, 0], jnp.int32),
        jnp.asarray([[tok], [0]], jnp.int32), CTX,
        arena_ids=jnp.asarray([1, 0], jnp.int32),
        enc_lengths=jnp.asarray([11, 0], jnp.int32))
    np.testing.assert_allclose(np.asarray(dec_p[0]), np.asarray(dec_d[0]),
                               rtol=1e-4, atol=1e-5)


def test_encdec_arena_sharing_by_identity(whisper, rng):
    """Requests submitting the SAME feature array share one arena row
    by refcount (best-of-n over one clip costs one encoder pass of
    arena memory), and outputs stay per-request."""
    cfg, model, params = whisper
    clip = _frames(rng, cfg, 12)
    prompts = [[1, 2, 3], [1, 2, 3], [4, 5]]
    sp = [SamplingParams(max_tokens=5, temperature=9.0, seed=s)
          for s in (1, 2, 3)]
    want = [_oracle(model, params, p, s, frames=f)
            for p, s, f in zip(prompts, sp, [clip, clip, clip])]
    eng = Engine(model, params,
                 EngineConfig(num_slots=3, block_size=4, num_blocks=33,
                              max_len=32), CTX)
    got = eng.generate(prompts, sp, encoder_features=[clip, clip, clip])
    assert got == want, (got, want)
    st = eng.stats()["cross_arena"]
    assert st["shared_hits"] >= 1          # co-resident duplicates shared
    assert st["rows_used"] == 0


def test_encdec_preemption_zero_arena_leak(whisper, rng):
    """Tight pool forces LIFO preemption; preempted slots free their
    arena rows (resume re-encodes) and outputs stay bit-identical; at
    drain both the block pool and the arena are empty."""
    cfg, model, params = whisper
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 5, 9)]
    feats = [_frames(rng, cfg, F) for F in (5, 16, 9, 12)]
    sp = [SamplingParams(max_tokens=10, temperature=8.0, seed=s)
          for s in (1, 2, 3, 4)]
    want = [_oracle(model, params, p, s, frames=f)
            for p, s, f in zip(prompts, sp, feats)]
    eng = Engine(model, params,
                 EngineConfig(num_slots=4, block_size=4, num_blocks=9,
                              max_len=32), CTX)
    got = eng.generate(prompts, sp, encoder_features=feats)
    assert got == want, (got, want)
    st = eng.stats()
    assert st["preemptions"] > 0, "pool was not tight enough to preempt"
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    be.alloc.check_invariant()
    assert be.arena.used_count == 0 and be.arena.free_count == 4
    be.arena.check_invariant()


def test_encdec_compile_cap_enc_bucket_axis(whisper, rng):
    """Frame counts bucket on their own pow-2 axis: many distinct
    (prompt, frame) length pairs compile O(log) x O(log) prefill
    variants, not one per pair."""
    cfg, model, params = whisper
    eng = Engine(model, params,
                 EngineConfig(num_slots=2, block_size=4, num_blocks=65,
                              max_len=32), CTX)
    lengths = [2, 3, 5, 7, 9, 11]
    frame_counts = [3, 5, 7, 9, 11, 13]
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in lengths]
    feats = [_frames(rng, cfg, F) for F in frame_counts]
    eng.generate(prompts, SamplingParams(max_tokens=2),
                 encoder_features=feats)
    # prompt buckets {4, 8, 16} x frame buckets {8, 16} x batch buckets
    # — far below the 36 distinct (length, frames, co-batch) shapes
    assert eng.stats()["prefill_compiles"] <= 8


def test_encdec_through_replicaset_and_disagg(whisper, rng):
    """Request objects travel the shared queue and migration packets
    intact: dp=2 ReplicaSet and 1P+1D disaggregation both match the
    single engine, and every pool/arena drains empty."""
    cfg, model, params = whisper
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 5, 9)]
    feats = [_frames(rng, cfg, F) for F in (5, 16, 9, 12)]
    sp = [SamplingParams(max_tokens=6, temperature=8.0, seed=s)
          for s in (1, 2, 3, 4)]
    base = EngineConfig(num_slots=3, block_size=4, num_blocks=33,
                        max_len=32)
    want = Engine(model, params, base, CTX).generate(
        prompts, sp, encoder_features=feats)
    rset = ReplicaSet(model, params, base, dp=2, ctx=CTX)
    got_r = rset.generate(prompts, sp, encoder_features=feats)
    assert got_r == want, (got_r, want)
    de = DisaggregatedEngine(model, params, base, dp=2, ctx=CTX)
    got_d = de.generate(prompts, sp, encoder_features=feats)
    assert got_d == want, (got_d, want)
    assert de.stats()["disagg"]["imported"] >= len(prompts)
    for front in (rset, de):
        for eng in front.replicas:
            be = eng.backend
            assert be.alloc.free_count == be.layout.usable_blocks
            be.alloc.check_invariant()
            be.arena.check_invariant()
            assert be.arena.used_count == 0


# -- request validation (ServingCaps-aware) -----------------------------


def test_check_request_rejects_features_on_decoder_only(rng):
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_len=32), CTX)
    feats = jnp.zeros((4, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match=r"dense/olmo-1b-smoke"):
        eng.add_request([1, 2, 3], SamplingParams(max_tokens=2),
                        encoder_features=feats)
    with pytest.raises(ValueError, match="inside the Request"):
        eng.add_request(Request([1, 2, 3]), SamplingParams(max_tokens=2))


def test_check_request_requires_features_on_encdec(whisper):
    cfg, model, params = whisper
    eng = Engine(model, params, EngineConfig(max_len=32), CTX)
    with pytest.raises(ValueError, match=r"audio/whisper-base-smoke"):
        eng.add_request([1, 2, 3], SamplingParams(max_tokens=2))
    bad_shape = jnp.zeros((4, cfg.d_model + 1), jnp.float32)
    with pytest.raises(ValueError, match="d_model"):
        eng.add_request([1, 2, 3], SamplingParams(max_tokens=2),
                        encoder_features=bad_shape)
    too_long = jnp.zeros((cfg.encoder_len + 1, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match="encoder_len"):
        eng.add_request([1, 2, 3], SamplingParams(max_tokens=2),
                        encoder_features=too_long)


def test_encdec_rejected_by_static_and_speculative(whisper):
    cfg, model, params = whisper
    with pytest.raises(ValueError, match="paged backend"):
        Engine(model, params, EngineConfig(backend="static", max_len=32),
               CTX)
    with pytest.raises(ValueError, match="decoder-only"):
        Engine(model, params, EngineConfig(spec_tokens=2, max_len=32),
               CTX)


def test_paged_decode_gate_names_config():
    cfg = get_config("qwen2_vl_2b").smoke()
    model = Model(cfg)
    assert not model.serving_caps().paged_decode
    with pytest.raises(NotImplementedError, match="qwen2-vl-2b-smoke"):
        Engine(model, None, EngineConfig(max_len=32), CTX)


# -- MoE through the Engine ---------------------------------------------


def test_moe_engine_matches_oracle_greedy_and_seeded(moe_smoke, rng):
    """MoE serving is DROPLESS: expert capacity can never drop a token,
    so routing is per-token and the batched, right-padded engine equals
    the per-request dense oracle exactly — the capacity-factor C of the
    training path would make outputs depend on co-batched traffic."""
    cfg, model, params = moe_smoke
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 5, 12)]
    sp = [SamplingParams(max_tokens=6),
          SamplingParams(max_tokens=6, temperature=2.0, seed=3),
          SamplingParams(max_tokens=5, temperature=1.0, top_k=24,
                         seed=7),
          SamplingParams(max_tokens=6)]
    want = [_oracle(model, params, p, s) for p, s in zip(prompts, sp)]
    eng = Engine(model, params,
                 EngineConfig(num_slots=4, block_size=4, num_blocks=33,
                              max_len=32), CTX)
    got = eng.generate(prompts, sp)
    assert got == want, (got, want)
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    assert be.arena is None                # no cross-KV arena for MoE


def test_moe_decode_logit_parity_at_matched_positions(moe_smoke, rng):
    """decode_step_paged == dense decode_step logits on an identical
    history, at decode width > 1 (batch-width invariance of dropless
    routing), for every co-resident row."""
    cfg, model, params = moe_smoke
    histories = [[5, 4, 3, 2], [9, 8, 7]]
    layout = paged_kv.PagedLayout(num_slots=2, num_blocks=16,
                                  block_size=4, max_len=16)
    pools = model.init_paged_cache(layout)
    table = np.full((2, layout.max_blocks_per_seq), paged_kv.NULL_BLOCK,
                    np.int32)
    dense_rows = []
    for r, h in enumerate(histories):
        _, cache = model.prefill(
            params, {"tokens": jnp.asarray([h], jnp.int32)}, CTX,
            max_len=8)
        ids = [2 * r + 1, 2 * r + 2]
        pools = model.pack_prefill_into_paged(
            layout, pools, cache, jnp.asarray([0, 0], jnp.int32),
            jnp.asarray([r == 0, r == 1]), jnp.asarray([ids], jnp.int32))
        table[r, :2] = ids
        lg, _ = model.decode_step(params, cache,
                                  jnp.asarray([[1]], jnp.int32),
                                  jnp.int32(len(h)), CTX)
        dense_rows.append(np.asarray(lg[0]))
    lg_p, _ = model.decode_step_paged(
        params, pools, jnp.asarray(table),
        jnp.asarray([len(h) for h in histories], jnp.int32),
        jnp.asarray([[1], [1]], jnp.int32), CTX)
    for r in range(2):
        np.testing.assert_allclose(np.asarray(lg_p[r]), dense_rows[r],
                                   rtol=1e-4, atol=1e-5)


def test_moe_speculative_token_identical(rng):
    """Expert routing through the verify window (decode_verify_paged)
    stays dropless: speculative == plain, token for token."""
    cfg = get_config("qwen3_moe_30b_a3b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 5)]
    sp = [SamplingParams(max_tokens=6),
          SamplingParams(max_tokens=6, temperature=2.0, seed=3),
          SamplingParams(max_tokens=6)]
    base = dict(num_slots=3, block_size=4, num_blocks=33, max_len=32)
    want = Engine(model, params, EngineConfig(**base), CTX).generate(
        prompts, sp)
    got = Engine(model, params,
                 EngineConfig(spec_tokens=3, **base), CTX).generate(
        prompts, sp)
    assert got == want, (got, want)


def test_moe_dropless_is_pad_and_batch_invariant(rng):
    """The layer-level property behind the identity tests: apply_moe
    with dropless=True gives each token an output independent of
    co-batched rows and right padding; the capacity path does not."""
    from repro.models import moe

    cfg = get_config("qwen3_moe_30b_a3b").smoke()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 6, cfg.d_model)), jnp.float32)
    alone, _ = moe.apply_moe(params, cfg, x, dropless=True)
    xpad = jnp.concatenate(
        [x, jnp.asarray(rng.normal(size=(1, 10, cfg.d_model)),
                        jnp.float32)], axis=1)
    padded, _ = moe.apply_moe(params, cfg, xpad, dropless=True)
    np.testing.assert_allclose(np.asarray(alone[0]),
                               np.asarray(padded[0, :6]),
                               rtol=1e-5, atol=1e-6)
