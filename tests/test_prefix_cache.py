"""Copy-on-write prefix caching: allocator refcounts, the trie index,
and engine-level bit-identity with the cache on vs off.

Contract chain, weakest to strongest:
  1. allocator: FIFO free order, share/refcount lifecycle, LRU reclaim
     with index eviction callback, and the partition invariant
     (owned ⊎ LRU ⊎ free == blocks 1..N-1) enforced on every
     transition;
  2. prefix index: block-chunk insert/match/evict semantics, first
     insert wins, descendants of an evicted block become unmatchable;
  3. engine equivalence: outputs with the prefix cache ON are
     bit-identical to the cache-OFF engine — greedy and seeded, across
     architectures (non-attention stacks auto-disable), under full-hit
     COW, preemption mid-shared-prefix and speculative rejection at a
     shared-block boundary — with zero block leaks throughout;
  4. scheduler bugfix sweep regressions: a preempt-only step reports no
     progress; telemetry reset clears per-request draft counters on
     still-live handles.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.models import paged_kv
from repro.models.model import Model


# -- 1. allocator -------------------------------------------------------


def _layout(num_blocks=9, bs=4):
    return paged_kv.PagedLayout(num_slots=2, num_blocks=num_blocks,
                                block_size=bs, max_len=bs * 4)


def test_allocator_fifo_free_order():
    """Freed blocks go to the BACK of the free queue: a preempted
    victim's blocks are not handed straight to its preemptor, so the
    victim can re-hit its own prefix blocks on resume (regression for
    the LIFO free stack)."""
    al = paged_kv.BlockAllocator(_layout(num_blocks=9))
    a = al.alloc(4)
    al.free(a)
    b = al.alloc(4)                       # the 4 never-used blocks first
    assert set(a).isdisjoint(b)
    c = al.alloc(4)                       # now the freed ones, same order
    assert c == a


def test_allocator_share_refcount_lru_reclaim():
    evicted = []
    al = paged_kv.BlockAllocator(_layout(num_blocks=5),
                                 on_evict=evicted.append)
    (b,) = al.alloc(1)
    assert al.refcount(b) == 1
    al.share(b)
    assert al.refcount(b) == 2
    al.register(b)                        # indexed: free -> LRU, not pool
    al.free([b])
    assert al.refcount(b) == 1 and al.used_count == 1
    al.free([b])
    assert al.used_count == 0
    assert al.lru_count == 1              # cached, reclaimable
    assert al.free_count == al.layout.usable_blocks
    got = al.alloc(4)                     # 3 fresh + the LRU block last
    assert b in got and evicted == [b]
    assert al.lru_count == 0


def test_allocator_share_resurrects_lru_block():
    al = paged_kv.BlockAllocator(_layout())
    (b,) = al.alloc(1)
    al.register(b)
    al.free([b])
    assert al.used_count == 0
    al.share(b)                           # cache hit on an LRU block
    assert al.refcount(b) == 1 and al.lru_count == 0
    assert al.must_cow(b)                 # indexed: writes must copy
    al.free([b])
    assert al.lru_count == 1


def test_allocator_misuse_raises():
    al = paged_kv.BlockAllocator(_layout(num_blocks=4))
    blocks = al.alloc(3)
    with pytest.raises(MemoryError):
        al.alloc(1)
    al.free(blocks)
    with pytest.raises(ValueError):
        al.free([blocks[0]])              # double free
    with pytest.raises(ValueError):
        al.free([paged_kv.NULL_BLOCK])
    with pytest.raises(ValueError):
        al.share(blocks[0])               # unreferenced, not cached


def test_allocator_invariant_checked():
    """The partition invariant is asserted after every transition and
    catches corrupted internal state."""
    al = paged_kv.BlockAllocator(_layout())
    al.check_invariant()
    (b,) = al.alloc(1)
    al._free.append(b)                    # corrupt: owned AND free
    with pytest.raises(AssertionError):
        al.check_invariant()


# -- 2. prefix index ----------------------------------------------------


def test_prefix_index_insert_match_evict():
    ix = paged_kv.PrefixIndex(4)
    toks = list(range(11))                # two full chunks + partial tail
    assert ix.insert(toks, [5, 6, 7]) == [5, 6]
    assert ix.match(toks) == [5, 6]
    assert ix.match(toks[:4]) == [5]
    assert ix.match(toks[:3]) == []       # sub-chunk prefix: no match
    assert ix.match([9] + toks[1:]) == []
    assert ix.insert(toks, [8, 9]) == []  # first insert wins
    assert ix.match(toks) == [5, 6]
    ix.evict_block(5)
    assert ix.match(toks) == []           # 6 orphaned -> unmatchable
    assert ix.insert(toks[:8], [3, 4]) == [3, 4]
    assert ix.match(toks) == [3, 4]


# -- 3. engine equivalence: cache on == cache off -----------------------


def _shared_work(rng, vocab, n=6, shared=12, unique=3):
    """Prompts sharing a long common prefix (block-aligned at bs=4)."""
    common = list(map(int, rng.integers(0, vocab, shared)))
    return [common + list(map(int, rng.integers(0, vocab, unique)))
            for _ in range(n)]


def _eng(model, params, *, prefix_cache, backend="paged", **kw):
    base = dict(backend=backend, num_slots=2, block_size=4, num_blocks=33,
                max_len=48, prefix_cache=prefix_cache)
    base.update(kw)
    return Engine(model, params, EngineConfig(**base))


def _assert_clean(be):
    assert be.alloc.used_count == 0
    assert be.alloc.free_count == be.layout.usable_blocks
    be.alloc.check_invariant()


@pytest.mark.parametrize("arch", ["olmo_1b", "recurrentgemma_2b",
                                  "xlstm_1_3b"])
def test_prefix_cache_bit_identical_greedy(rng, arch):
    """Outputs with the prefix cache on == off, greedy, shared-prefix
    trace. Non-attention stacks silently disable the cache (per-slot
    recurrent state cannot ride a matched block chain) and must be
    trivially identical."""
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _shared_work(rng, cfg.vocab_size)
    sp = SamplingParams(max_tokens=5)
    off = _eng(model, params, prefix_cache=False).generate(prompts, sp)
    on = _eng(model, params, prefix_cache=True)
    assert on.generate(prompts, sp) == off
    st = on.stats()["prefix_cache"]
    if arch == "olmo_1b":
        assert st["enabled"] and st["hits"] > 0 and st["hit_tokens"] > 0
    else:
        assert not st["enabled"]
    _assert_clean(on.backend)


def test_prefix_cache_bit_identical_seeded(rng):
    """Seeded sampling: the hit path samples each request's first token
    from the admission step's decode instead of the prefill logits —
    same RNG stream position, same logits row, bit-identical tokens."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _shared_work(rng, cfg.vocab_size)
    sps = [SamplingParams(max_tokens=5, temperature=0.8, top_k=20,
                          seed=100 + i) for i in range(len(prompts))]
    off = _eng(model, params, prefix_cache=False).generate(prompts, sps)
    on = _eng(model, params, prefix_cache=True)
    assert on.generate(prompts, sps) == off
    assert on.stats()["prefix_cache"]["hits"] > 0
    _assert_clean(on.backend)


def test_prefix_cache_full_hit_cow(rng):
    """An identical prompt re-submitted is a FULL-prefix hit: no prefill
    call at all, and the first decode triggers exactly one
    copy-on-write of the shared tail block."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 12)))
    sp = SamplingParams(max_tokens=4)
    eng = _eng(model, params, prefix_cache=True, num_slots=1)
    want = _eng(model, params, prefix_cache=False,
                num_slots=1).generate([prompt], sp)[0]
    assert eng.generate([prompt], sp) == [want]
    calls0 = eng.stats()["prefill_calls"]
    assert eng.generate([prompt], sp) == [want]
    st = eng.stats()
    pc = st["prefix_cache"]
    assert st["prefill_calls"] == calls0   # full hit: no device prefill
    assert pc["hit_tokens"] >= 12 and pc["cow_copies"] >= 1
    _assert_clean(eng.backend)


def test_prefix_cache_partial_hit_prefills_only_suffix(rng):
    """A block-aligned shared prefix leaves only the unique suffix to
    prefill: prefill_tokens with the cache on must shrink by at least
    the shared-token volume of the hits."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _shared_work(rng, cfg.vocab_size, n=6, shared=16, unique=3)
    sp = SamplingParams(max_tokens=3)
    off = _eng(model, params, prefix_cache=False)
    out_off = off.generate(prompts, sp)
    on = _eng(model, params, prefix_cache=True)
    assert on.generate(prompts, sp) == out_off
    st_on, st_off = on.stats(), off.stats()
    # the first TWO prompts co-admit into slots before anything is
    # registered (one batch), so at most n-2 can hit
    assert st_on["prefix_cache"]["hits"] >= 4
    assert st_on["prefill_tokens"] <= st_off["prefill_tokens"] - 4 * 16
    _assert_clean(on.backend)


def test_prefix_cache_under_preemption(rng):
    """A pool tight enough to preempt mid-run must still produce
    bit-identical outputs with shared prefixes resumed through the
    cache (preempted victims re-hit their own just-freed blocks)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _shared_work(rng, cfg.vocab_size, n=5, shared=8, unique=3)
    sp = SamplingParams(max_tokens=8)
    off = _eng(model, params, prefix_cache=False, num_slots=3,
               num_blocks=17, max_len=32)
    out_off = off.generate(prompts, sp)
    on = _eng(model, params, prefix_cache=True, num_slots=3,
              num_blocks=17, max_len=32)
    out_on = on.generate(prompts, sp)
    assert out_on == out_off
    _assert_clean(on.backend)


@pytest.mark.parametrize("temp", [0.0, 0.9])
def test_prefix_cache_with_spec_decode(rng, temp):
    """Speculative decoding over shared prefixes: the ngram drafter
    matches across the shared history, verify windows start inside a
    shared tail block (COW before the device call), and rejection at a
    shared-block boundary rolls back without touching shared blocks.
    Outputs must equal the non-speculative cache-off engine."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # periodic prompts: the self-drafter actually proposes, and the
    # shared prefix is an exact block multiple (boundary rejections)
    base = [7, 3, 9, 5] * 3
    prompts = [base + [11 + i] for i in range(4)]
    sps = [SamplingParams(max_tokens=6, temperature=temp, seed=i)
           for i in range(4)]
    off = _eng(model, params, prefix_cache=False).generate(prompts, sps)
    on = _eng(model, params, prefix_cache=True, spec_tokens=3)
    assert on.generate(prompts, sps) == off
    st = on.stats()
    assert st["prefix_cache"]["hits"] > 0
    _assert_clean(on.backend)


def test_prefix_cache_survives_eviction_pressure(rng):
    """More distinct prompts than the pool can cache: LRU reclaim must
    fire (evictions > 0), matches must stay exact, outputs greedy-
    stable, and the pool must drain clean."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 12)))
               for _ in range(8)]
    sp = SamplingParams(max_tokens=4)
    off = _eng(model, params, prefix_cache=False, num_blocks=13,
               max_len=24).generate(prompts, sp)
    on = _eng(model, params, prefix_cache=True, num_blocks=13,
              max_len=24)
    assert on.generate(prompts, sp) == off
    assert on.stats()["prefix_cache"]["evictions"] > 0
    _assert_clean(on.backend)


# -- 4. bugfix-sweep regressions ----------------------------------------


def test_preempt_only_step_reports_no_progress(rng):
    """Satellite regression: ``_preempt`` must NOT set made_progress —
    a step that only evicts and re-queues emits nothing, and counting
    it as progress would let Engine.drive spin through
    preempt/re-prefill churn without a token leaving."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = _eng(model, params, prefix_cache=False, num_slots=2)
    eng.add_request(list(rng.integers(0, cfg.vocab_size, 6)),
                    SamplingParams(max_tokens=4))
    be = eng.backend
    be.step()                              # admit + first decode
    assert be.num_active == 1
    be.made_progress = False
    be._preempt(next(i for i, s in enumerate(be.slots)
                     if s.req is not None))
    assert not be.made_progress
    eng.drain()                            # and the engine still finishes
    _assert_clean(be)


def test_spec_reset_telemetry_clears_live_handles(rng):
    """Satellite regression: warmup -> reset -> measure. Per-request
    draft counters on STILL-ACTIVE handles must reset with the
    aggregates, or the warmup proposals pollute the measured
    ``stats()['spec']`` per-request accept rates."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = _eng(model, params, prefix_cache=False, spec_tokens=3,
               num_slots=2)
    base = [7, 3, 9, 5] * 3               # periodic: drafter proposes
    eng.add_request(base, SamplingParams(max_tokens=24))
    be = eng.backend
    for _ in range(6):                    # warmup with the request LIVE
        be.step()
    live = [s.req for s in be.slots if s.req is not None]
    assert live and any(r.num_draft_proposed > 0 for r in live)
    be.reset_telemetry()
    st = be.stats()["spec"]
    assert st["proposed"] == st["accepted"] == 0
    assert all(v["proposed"] == 0 and v["accepted"] == 0
               for v in st["per_request"].values())
    eng.drain()
    _assert_clean(be)
