"""Serving-correctness invariants: decode-with-cache == full forward,
chunkwise == stepwise recurrences."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import encdec, ssm, transformer
from repro.models.model import Model
from repro.models.transformer import RunCtx

CTX = RunCtx(kernel_mode="ref")


def test_mlstm_chunkwise_equals_stepwise(rng):
    B, H, S, hd = 2, 2, 33, 8          # deliberately non-multiple of chunk
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
               for _ in range(3))
    ig = jnp.asarray(rng.normal(size=(B, H, S)), jnp.float32)
    fg = jnp.asarray(rng.normal(size=(B, H, S)) + 2, jnp.float32)
    h_chunk, st_chunk = ssm.mlstm_chunkwise(q, k, v, ig, fg, chunk=8)
    state = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
             jnp.full((B, H), -1e30))
    hs = []
    for t in range(S):
        h_t, state = ssm.mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                    ig[:, :, t], fg[:, :, t], state)
        hs.append(h_t)
    h_step = jnp.stack(hs, axis=2)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_step),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_chunk[0]), np.asarray(state[0]),
                               rtol=1e-4, atol=1e-5)


def test_mlstm_unroll_invariance(rng):
    B, H, S, hd = 1, 2, 32, 8
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
               for _ in range(3))
    ig = jnp.asarray(rng.normal(size=(B, H, S)), jnp.float32)
    fg = jnp.asarray(rng.normal(size=(B, H, S)) + 2, jnp.float32)
    a, _ = ssm.mlstm_chunkwise(q, k, v, ig, fg, chunk=8, unroll=False)
    b, _ = ssm.mlstm_chunkwise(q, k, v, ig, fg, chunk=8, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("arch", ["yi_6b", "h2o_danube_3_4b", "olmo_1b",
                                  "recurrentgemma_2b", "xlstm_1_3b",
                                  "whisper_base", "gemma_7b"])
def test_decode_matches_forward(arch, rng):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
    batch = {"tokens": toks[:, :S]}
    if cfg.enc_dec:
        fr = jnp.asarray(rng.normal(size=(B, cfg.encoder_len, cfg.d_model)),
                         jnp.float32)
        batch["frames"] = fr
        full_logits, _ = encdec.forward(params, cfg, toks, fr, CTX)
    else:
        full_logits, _ = transformer.forward(params, cfg, toks, CTX)
    _, cache = model.prefill(params, batch, CTX, max_len=S + 4)
    dec_logits, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                      jnp.int32(S), CTX)
    scale = float(jnp.max(jnp.abs(full_logits[:, S]))) + 1e-6
    err = float(jnp.max(jnp.abs(dec_logits - full_logits[:, S]))) / scale
    assert err < 1e-4, f"{arch}: decode/forward mismatch rel={err:.2e}"


def test_moe_decode_matches_forward_with_capacity(rng):
    cfg = dataclasses.replace(get_config("qwen3_moe_30b_a3b").smoke(),
                              moe_capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
    full_logits, _ = transformer.forward(params, cfg, toks, CTX)
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, CTX,
                             max_len=S + 4)
    dec_logits, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                      jnp.int32(S), CTX)
    err = float(jnp.max(jnp.abs(dec_logits - full_logits[:, S])))
    assert err < 1e-4


def test_sliding_window_decode_ring_buffer(rng):
    """Danube SWA: decode past the window must match full forward."""
    cfg = get_config("h2o_danube_3_4b").smoke()  # window 16
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24                                  # S > window
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
    full_logits, _ = transformer.forward(params, cfg, toks, CTX)
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, CTX,
                             max_len=S + 8)
    dec_logits, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                      jnp.int32(S), CTX)
    scale = float(jnp.max(jnp.abs(full_logits[:, S]))) + 1e-6
    err = float(jnp.max(jnp.abs(dec_logits - full_logits[:, S]))) / scale
    assert err < 1e-4, f"ring-buffer decode mismatch rel={err:.2e}"
