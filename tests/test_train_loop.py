"""Integration: fault-tolerant training loop — loss decreases, restart
resumes exactly, stragglers observed, elastic replan arithmetic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.elastic import replan_mesh, survivors_after_failure
from repro.launch.train import (StragglerMonitor, TrainLoopConfig, init_state,
                                train_loop)
from repro.models.model import Model
from repro.models.transformer import RunCtx
from repro.optim import OptConfig


def _setup(tmp_path, steps=30, arch="olmo_1b"):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    opt_cfg = OptConfig(weight_decay=0.0)
    ctx = RunCtx(kernel_mode="ref")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4, seed=0)
    loop_cfg = TrainLoopConfig(steps=steps, ckpt_every=10,
                               ckpt_dir=str(tmp_path / "ckpt"),
                               log_every=1000)
    return model, opt_cfg, ctx, data_cfg, loop_cfg


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    import functools
    from repro.optim.schedule import constant
    model, opt_cfg, ctx, data_cfg, loop_cfg = _setup(tmp_path, steps=40)
    _, hist = train_loop(model, opt_cfg, ctx, data_cfg, loop_cfg,
                         lr_fn=functools.partial(constant, peak_lr=3e-3))
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, (first, last)


@pytest.mark.slow
def test_restart_resumes_equivalently(tmp_path):
    """Kill at step 15, restart, final state == uninterrupted run."""
    model, opt_cfg, ctx, data_cfg, loop_cfg = _setup(tmp_path, steps=20)
    # uninterrupted reference
    ref_loop = TrainLoopConfig(steps=20, ckpt_every=10,
                               ckpt_dir=str(tmp_path / "ref"),
                               log_every=1000)
    ref_state, _ = train_loop(model, opt_cfg, ctx, data_cfg, ref_loop)

    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(model, opt_cfg, ctx, data_cfg, loop_cfg, fail_at=15)
    # restart: restores from step_10 checkpoint, replays steps 10..19
    state, hist = train_loop(model, opt_cfg, ctx, data_cfg, loop_cfg)
    assert hist[0]["step"] == 10
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_straggler_monitor():
    m = StragglerMonitor(window=16, threshold=3.0)
    for _ in range(10):
        assert not m.observe(0.1)
    assert m.observe(1.0)          # 10x median -> flagged
    assert m.flags == 1


def test_elastic_replan():
    p = replan_mesh(512, tp=16, prefer_pods=2)
    assert p.shape == (2, 16, 16) and p.dropped_devices == 0
    p = survivors_after_failure(512, failed=16, tp=16)
    assert p.shape == (31, 16) and p.dropped_devices == 0
    p = survivors_after_failure(512, failed=10, tp=16)
    assert p.shape == (31, 16) and p.dropped_devices == 6


def test_grad_accum_matches_full_batch(tmp_path):
    """A=2 microbatching == single batch (up to f32 accumulation)."""
    from repro.launch.train import make_train_step
    from repro.optim.schedule import constant
    import functools
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    ctx = RunCtx(kernel_mode="ref")
    lr = functools.partial(constant, peak_lr=1e-2)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))}
    s1 = init_state(model, OptConfig(weight_decay=0.0, grad_accum=1))
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = make_train_step(model, OptConfig(weight_decay=0.0, grad_accum=1),
                            ctx, lr)
    step2 = make_train_step(model, OptConfig(weight_decay=0.0, grad_accum=2),
                            ctx, lr)
    n1, _ = step1(s1, batch)
    n2, _ = step2(s2, batch)
    for a, b in zip(jax.tree.leaves(n1["params"]),
                    jax.tree.leaves(n2["params"])):
        # atol covers Adam's rsqrt amplification of f32 reduction-order
        # noise on near-zero gradient elements (O(1/10k) of entries).
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=1e-4)
