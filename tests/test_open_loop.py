"""Open-loop traffic, chaos/fault-injection, and property tests for the
serving invariants (ISSUE-10 test layer).

What PRs 1–9 pinned with friendly traces, this suite attacks with
adversarial ones:

  1. traffic generators (benchmarks/traffic.py): determinism (a trace
     is a pure function of its seed — no wall clock), arrival-order and
     rate sanity per kind, SLO scoring arithmetic;
  2. chaos traces, injected by STEP INDEX (not wall time, so a failure
     reproduces from nothing but its seed): admission bursts at
     pool-exhaustion boundaries, all-max-length storms, and
     cancel-mid-prefill floods (max_tokens=1 / instant-stop-token
     requests — the register-before-retire path) — each replayed with
     ``overlap=`` off AND on, asserting bit-identical outputs, zero
     block leaks, and exact completion;
  3. strict FCFS under preemption pressure: fresh admissions leave the
     queue in uid order — the head is never overtaken (resumes are
     replica-local and exempt by design);
  4. overlap bit-identity across attention/recurrent/hybrid archs with
     mixed greedy + seeded stochastic sampling (the RNG-stream
     contract is WHY dispatch-ahead is legal);
  5. BlockAllocator property tests (tests/_hypothesis_compat.py):
     random op interleavings always satisfy ``check_invariant`` and
     owned ⊎ LRU ⊎ free partitions every non-null block;
  6. telemetry clocks: ReplicaSet busy/wait clocks survive wall-clock
     jumps (monotonic stamps), the paged backend's ``device_s``
     interval union stays inside the step wall time under overlap;
  7. a multi-device subprocess run of the overlap identity (mesh-
     sharded pools change WHERE tensors live, never WHAT comes out).
"""

import collections
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.launch.engine import (Engine, EngineConfig, ReplicaSet,
                                 SamplingParams)
from repro.launch.engine import replica as replica_mod
from repro.models import paged_kv
from repro.models.model import Model

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:                  # `benchmarks` lives at the
    sys.path.insert(0, _ROOT)              # repo root, not under src/
from benchmarks import traffic  # noqa: E402


def _smoke(arch="olmo_1b"):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# -- 1. traffic generators ------------------------------------------------


@pytest.mark.parametrize("kind", ["poisson", "bursty", "ramp"])
def test_trace_deterministic_and_ordered(kind):
    """A trace is a pure function of its seed: two builds are equal
    field-for-field, a different seed diverges, and arrivals are
    nondecreasing (the replay loop pops the head only)."""
    cfg, _, _ = _smoke()
    mk = lambda s: traffic.make_open_loop_trace(  # noqa: E731
        cfg, kind=kind, n_requests=40, rate=100.0, seed=s)
    a, b, c = mk(7), mk(7), mk(8)
    assert [(i.arrival, i.prompt, i.max_new) for i in a] \
        == [(i.arrival, i.prompt, i.max_new) for i in b]
    assert [i.prompt for i in a] != [i.prompt for i in c]
    arr = [i.arrival for i in a]
    assert arr == sorted(arr) and arr[0] >= 0.0
    assert all(0 <= t < cfg.vocab_size for i in a for t in i.prompt)


def test_trace_kinds_shape():
    """Kind-specific structure: bursty arrivals cluster (many gaps are
    the intra-burst spread), ramp inter-arrival gaps shrink over the
    trace, and an unknown kind raises."""
    cfg, _, _ = _smoke()
    rng = np.random.default_rng(3)
    burst = traffic.bursty_arrivals(64, 200.0, rng, burst=8)
    gaps = np.diff(burst)
    assert (gaps <= 2e-4).sum() >= 48     # 7 of each 8-burst are spread
    ramp = traffic.ramp_arrivals(400, 200.0, np.random.default_rng(3))
    g = np.diff(ramp)
    assert g[:100].mean() > g[-100:].mean()   # rate ramps UP
    with pytest.raises(ValueError):
        traffic.make_open_loop_trace(cfg, kind="lumpy", n_requests=4,
                                     rate=1.0, seed=0)


class _FakeHandle:
    def __init__(self, t_first, gaps, n_tokens):
        self.t_submit = 0.0
        self.t_first_token = t_first
        self.t_tokens = ([t_first + sum(gaps[:i]) for i in
                          range(len(gaps) + 1)] if t_first is not None
                         else [])
        self.token_ids = list(range(n_tokens))


def test_slo_report_scoring():
    """Goodput counts tokens ONLY from requests meeting both budgets;
    TTFT-only requests (a single token — TPOT undefined) pass on TTFT
    alone; an unfinished request (no first token) never meets."""
    cfg, _, _ = _smoke()
    trace = traffic.make_open_loop_trace(cfg, kind="poisson",
                                         n_requests=4, rate=1.0, seed=0)
    traffic.annotate_slos(trace, ttft_s=0.1, tpot_s=0.01)
    handles = [
        _FakeHandle(0.05, [0.005] * 9, 10),    # meets both
        _FakeHandle(0.05, [0.5] * 9, 10),      # blows TPOT
        _FakeHandle(10.0, [0.005] * 9, 10),    # blows TTFT (scale <= 2)
        _FakeHandle(None, [], 0),              # never started
    ]
    rep = traffic.slo_report(handles, trace, wall_s=2.0)
    assert rep["slo_met"] == 1 and rep["count"] == 4
    assert rep["goodput_tok_s"] == pytest.approx(10 / 2.0)
    assert rep["goodput_frac"] == pytest.approx(10 / 30)
    assert rep["ttft"]["count"] == 3 and rep["tpot"]["count"] == 3


# -- 2. chaos traces (step-indexed injection) -----------------------------


def _drive_steps(eng, work, max_steps=20_000):
    """Open-loop replay on the STEP clock: ``work`` is a list of
    (arrival_step, prompt, SamplingParams); request i is submitted the
    moment the step counter reaches its arrival step, whether or not
    the engine has capacity — arrivals never wait for completions.
    Deterministic: no wall clock anywhere."""
    pending = collections.deque(work)
    handles = []
    step = 0
    while pending or eng.has_work:
        while pending and pending[0][0] <= step:
            _, prompt, sp = pending.popleft()
            handles.append(eng.add_request(prompt, sp))
        if eng.has_work:
            eng.step()
        step += 1
        assert step < max_steps, "chaos trace stalled"
    return handles


def _assert_clean(eng, handles, work):
    st = eng.stats()
    assert st["blocks_used"] == 0, st
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    assert np.all(be.lengths == 0)
    for h, (_, _, sp) in zip(handles, work):
        assert h.finished
        assert len(h.token_ids) <= sp.max_tokens


def _chaos_outputs(model, params, work, *, overlap, **cfg_kw):
    base = dict(backend="paged", num_slots=3, block_size=4,
                num_blocks=17, max_len=32, overlap=overlap)
    base.update(cfg_kw)
    eng = Engine(model, params, EngineConfig(**base))
    handles = _drive_steps(eng, work)
    _assert_clean(eng, handles, work)
    return [h.token_ids for h in handles], eng.stats()


def _both_overlaps(model, params, work, **cfg_kw):
    """Replay one chaos trace with overlap off and on: outputs must be
    bit-identical (RNG-stream contract) and both runs leak-free."""
    toks_off, _ = _chaos_outputs(model, params, work, overlap=False,
                                 **cfg_kw)
    toks_on, st = _chaos_outputs(model, params, work, overlap=True,
                                 **cfg_kw)
    assert toks_on == toks_off
    return toks_on, st


def test_chaos_pool_exhaustion_bursts(rng):
    """Bursts wider than the free pool at admission boundaries: 6
    requests land on one step into a 16-usable-block pool that can hold
    ~2 of them, repeatedly — optimistic admission + LIFO preemption
    churn. Zero leaks, exact completion, overlap-identical."""
    cfg, model, params = _smoke()
    work = []
    for b in range(4):                     # 4 bursts of 6
        for _ in range(6):
            plen = int(rng.integers(6, 14))
            prompt = list(map(int, rng.integers(0, cfg.vocab_size, plen)))
            work.append((b * 40, prompt,
                         SamplingParams(max_tokens=int(
                             rng.integers(4, 14)))))
    _, st = _both_overlaps(model, params, work, num_blocks=13)
    assert st["preemptions"] > 0           # the burst actually bit


def test_chaos_all_max_len_storm(rng):
    """Every request wants the whole lane: prompt + output pinned at
    the max_len boundary (the growth path crosses a block boundary on
    the final token). Nothing leaks, nobody is starved."""
    cfg, model, params = _smoke()
    work = []
    for i in range(8):
        plen = 16
        prompt = list(map(int, rng.integers(0, cfg.vocab_size, plen)))
        work.append((0, prompt, SamplingParams(max_tokens=32 - plen - 1)))
    _both_overlaps(model, params, work, num_slots=2, num_blocks=17)


def test_chaos_cancel_mid_prefill_flood(rng):
    """Cancel-like floods: max_tokens=1 requests retire INSIDE the
    admission step (the register-before-retire path), and stop-token
    requests retire on their first sampled token — interleaved with
    long-running requests so retirement constantly races admission and,
    under overlap, the in-flight harvest."""
    cfg, model, params = _smoke()
    # a stop id that greedy decode actually emits: the oracle's first
    # token for a probe prompt (cheap: one engine call)
    probe = list(map(int, rng.integers(0, cfg.vocab_size, 6)))
    eng = Engine(model, params, EngineConfig(
        backend="paged", num_slots=1, block_size=4, num_blocks=17,
        max_len=32))
    stop_id = eng.generate([probe], SamplingParams(max_tokens=1))[0][0]
    del eng
    work = []
    for i in range(18):
        if i % 3 == 2:                     # a long request to race with
            plen = int(rng.integers(8, 12))
            sp = SamplingParams(max_tokens=12)
        elif i % 3 == 1:                   # instant stop-token retire
            plen = 6
            sp = SamplingParams(max_tokens=12,
                                stop_token_ids=(stop_id,))
        else:                              # retire inside admission
            plen = int(rng.integers(4, 9))
            sp = SamplingParams(max_tokens=1)
        prompt = probe if plen == 6 else list(
            map(int, rng.integers(0, cfg.vocab_size, plen)))
        work.append((i // 3, prompt, sp))
    toks, _ = _both_overlaps(model, params, work)
    assert any(t == [] for t in toks)      # stop floods emitted nothing


def test_chaos_bursty_trace_through_generator(rng):
    """End to end with the real generator: a seeded bursty trace's
    arrivals quantized onto the step clock (one step per ms of trace
    time) through a tiny pool — the bench's trace shape under the
    chaos harness, with stochastic sampling in the mix."""
    cfg, model, params = _smoke()
    items = traffic.make_open_loop_trace(
        cfg, kind="bursty", n_requests=16, rate=400.0, seed=11,
        prompt_lens=(4, 6, 10), max_new_choices=(2, 5, 9),
        max_new_p=(0.3, 0.4, 0.3), burst=5)
    work = []
    for k, it in enumerate(items):
        sp = SamplingParams(max_tokens=it.max_new) if k % 2 == 0 else \
            SamplingParams(max_tokens=it.max_new, temperature=0.8,
                           top_k=7, top_p=0.9, seed=k)
        work.append((int(it.arrival * 1000), it.prompt, sp))
    _both_overlaps(model, params, work)


# -- 3. strict FCFS: the head is never overtaken --------------------------


def test_fcfs_head_never_overtaken(rng):
    """Fresh admissions must leave the queue in uid order even under
    preemption churn: spy on ``_place_batch`` and assert the fresh
    (never-preempted, zero-sampled) admission sequence is sorted.
    Resumed victims re-enter at the FRONT of the queue by design —
    they are not fresh admissions and are exempt."""
    cfg, model, params = _smoke()
    eng = Engine(model, params, EngineConfig(
        backend="paged", num_slots=3, block_size=4, num_blocks=13,
        max_len=32, overlap=True))
    be = eng.backend
    fresh_order = []
    orig = be._place_batch

    def spy(run, outs):
        for req, m, cached, S in run:
            if req.num_preemptions == 0 and req._n_sampled == 0:
                fresh_order.append(req.uid)
        return orig(run, outs)

    be._place_batch = spy
    work = []
    for i in range(20):
        plen = int(rng.integers(4, 14))
        prompt = list(map(int, rng.integers(0, cfg.vocab_size, plen)))
        work.append((i // 4, prompt,
                     SamplingParams(max_tokens=int(rng.integers(4, 14)))))
    handles = _drive_steps(eng, work)
    _assert_clean(eng, handles, work)
    assert eng.stats()["preemptions"] > 0
    assert fresh_order == sorted(fresh_order)
    assert len(fresh_order) == len(work)


# -- 4. overlap bit-identity across architectures -------------------------


@pytest.mark.parametrize("arch", ["olmo_1b", "recurrentgemma_2b",
                                  "xlstm_1_3b"])
def test_overlap_identity_across_archs(rng, arch):
    """The acceptance identity: ``overlap=True`` changes WHEN tokens
    are fetched, never WHICH tokens come out — per arch family
    (attention / recurrent-hybrid / xLSTM), ragged prompts, mixed
    greedy + seeded stochastic sampling, pool small enough to preempt."""
    cfg, model, params = _smoke(arch)
    work = []
    for i, plen in enumerate((5, 9, 3, 12, 7, 6)):
        prompt = list(map(int, rng.integers(0, cfg.vocab_size, plen)))
        sp = SamplingParams(max_tokens=6 + i % 4) if i % 2 == 0 else \
            SamplingParams(max_tokens=6 + i % 4, temperature=0.7,
                           top_k=9, top_p=0.95, seed=100 + i)
        work.append((i // 2, prompt, sp))
    _both_overlaps(model, params, work, num_slots=2, num_blocks=17)


def test_overlap_config_validation():
    """The toggle is paged-only and incompatible with speculation (the
    verify window already amortizes fetches; overlapping it would
    double-buffer the wrong boundary)."""
    _, model, params = _smoke()
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params, EngineConfig(backend="static",
                                           overlap=True))
    with pytest.raises(ValueError, match="speculative"):
        Engine(model, params, EngineConfig(
            backend="paged", num_slots=2, block_size=4, num_blocks=17,
            max_len=32, overlap=True, spec_tokens=2))


# -- 5. BlockAllocator property tests --------------------------------------


def _decode_ops(codes, alloc, num_blocks):
    """Interpret an integer stream as allocator ops against a live
    multiset mirror; every mutation is followed by check_invariant()
    inside the allocator itself. Returns the mirror."""
    live = []                              # our references, multiset
    for code in codes:
        op, arg = code % 6, code // 6
        if op == 0:
            n = arg % 3 + 1
            if alloc.can_alloc(n):
                live += alloc.alloc(n)
        elif op == 1 and live:
            alloc.free([live.pop(arg % len(live))])
        elif op == 2 and live:             # extra ref on a live block
            b = live[arg % len(live)]
            alloc.share(b)
            live.append(b)
        elif op == 3 and live:             # index it (parks in LRU later)
            alloc.register(live[arg % len(live)])
        elif op == 4 and alloc.lru_count:  # prefix-cache re-hit: revive
            b = list(alloc._lru)[arg % alloc.lru_count]
            alloc.share(b)
            live.append(b)
        elif op == 5:                      # read-only probe
            assert isinstance(
                alloc.must_cow(1 + arg % (num_blocks - 1)), bool)
    return live


@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=80),
       st.integers(4, 14))
@settings(max_examples=25, deadline=None)
def test_allocator_random_interleavings(codes, num_blocks):
    """Any interleaving of alloc/free/share/register/revive keeps the
    invariant (owned ⊎ LRU ⊎ free partitions blocks 1..N-1, cached ⊆
    resident, refcounts >= 1), the allocator's refcounts agree with an
    independent multiset mirror, and releasing every mirror reference
    returns the pool to fully-free."""
    layout = paged_kv.PagedLayout(num_slots=2, num_blocks=num_blocks,
                                  block_size=4, max_len=64)
    evicted = []
    alloc = paged_kv.BlockAllocator(layout, watermark=1,
                                    on_evict=evicted.append)
    live = _decode_ops(codes, alloc, num_blocks)
    owned = set(alloc._refs)
    lru = set(alloc._lru)
    free = set(alloc._free)
    assert not (owned & lru) and not (owned & free) and not (lru & free)
    assert owned | lru | free == set(range(1, num_blocks))
    assert alloc._refs == dict(collections.Counter(live))
    assert len(set(evicted) & owned) == len(
        set(evicted) & owned & set(live))  # evictions only recycle
    for b in list(live):
        alloc.free([b])
    assert alloc.used_count == 0
    assert alloc.free_count == num_blocks - 1


@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=40),
       st.integers(4, 10))
@settings(max_examples=15, deadline=None)
def test_allocator_misuse_always_raises(codes, num_blocks):
    """After ANY legal op prefix: double-free, freeing the null block,
    sharing a free block, and registering a non-live block all raise —
    and the failed call leaves the invariant intact."""
    layout = paged_kv.PagedLayout(num_slots=2, num_blocks=num_blocks,
                                  block_size=4, max_len=64)
    alloc = paged_kv.BlockAllocator(layout)
    live = _decode_ops(codes, alloc, num_blocks)
    with pytest.raises(ValueError):
        alloc.free([paged_kv.NULL_BLOCK])
    if alloc._free:
        b = alloc._free[0]
        with pytest.raises(ValueError):
            alloc.share(b)
        with pytest.raises(ValueError):
            alloc.register(b)
        with pytest.raises(ValueError):
            alloc.free([b])
    alloc.check_invariant()
    for b in list(live):
        alloc.free([b])
    assert alloc.used_count == 0


# -- 6. telemetry clocks ---------------------------------------------------


def test_replica_busy_clock_survives_wall_jump(rng, monkeypatch):
    """Regression for the busy-clock skew: stamps must come from the
    monotonic clock, so a wall clock jumping BACKWARD mid-run (NTP
    slew) cannot produce negative busy/wait intervals. time.time is
    patched to run backwards; telemetry must not notice."""
    cfg, model, params = _smoke()
    jumpy = iter(np.arange(1e9, 1e9 - 500, -7.3))
    monkeypatch.setattr(replica_mod.time, "time",
                        lambda: float(next(jumpy)))
    rset = ReplicaSet(model, params, EngineConfig(
        backend="paged", num_slots=2, block_size=4, num_blocks=17,
        max_len=32), dp=2)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 8, 6, 7)]
    rset.generate(prompts, SamplingParams(max_tokens=4))
    st = rset.stats()
    assert all(b >= 0.0 for b in st["busy_s"])
    assert sum(st["busy_s"]) > 0.0
    assert st["queue_wait_s_mean"] >= 0.0
    assert all(w >= 0.0 for w in rset.wait_wall)
    assert st["latency"]["ttft"]["count"] == len(prompts)
    assert st["latency"]["ttft"]["p50_s"] >= 0.0


def test_device_clock_interval_union_under_overlap(rng):
    """``device_s`` is a union of dispatch->fetch intervals: with
    overlap ON, consecutive in-flight windows must not double-count —
    the device clock stays within the total wall time of the run."""
    import time as _time

    cfg, model, params = _smoke()
    eng = Engine(model, params, EngineConfig(
        backend="paged", num_slots=2, block_size=4, num_blocks=33,
        max_len=32, overlap=True))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 8, 6)]
    eng.generate(prompts, SamplingParams(max_tokens=8))  # warm compiles
    eng.backend.reset_telemetry()
    t0 = _time.monotonic()
    eng.generate(prompts, SamplingParams(max_tokens=8))
    wall = _time.monotonic() - t0
    st = eng.stats()
    assert st["overlap"] is True
    assert 0.0 < st["device_s"] <= wall
    assert st["latency"]["tpot"]["count"] == len(prompts)


# -- 7. multi-device overlap identity (subprocess) -------------------------

_PRELUDE = """
import jax, numpy as np
from repro.configs import get_config
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.launch.mesh import make_mesh
from repro.models.model import Model

assert len(jax.devices()) == 8
MESH = make_mesh((4, 2), ("data", "model"))
"""


def _run(body: str):
    # dedent the body BEFORE prepending the unindented prelude (see
    # test_sharded_serve.py); "body ran" guards against a silently
    # unexecuted body.
    code = _PRELUDE + textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "body ran" in proc.stdout, f"test body never executed:\n{code}"
    return proc.stdout


def test_overlap_identity_sharded_subprocess():
    """Overlap identity on a (4 data x 2 model) mesh: the fused overlap
    step runs against the head-sharded pool, and its outputs must match
    the no-overlap mesh engine token for token (greedy and seeded
    stochastic), with zero leaks on both."""
    _run("""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 9, 3, 12, 7)]
    sps = [SamplingParams(max_tokens=7) if i % 2 == 0 else
           SamplingParams(max_tokens=7, temperature=0.8, top_k=5,
                          top_p=0.9, seed=40 + i)
           for i in range(len(prompts))]
    outs = {}
    for overlap in (False, True):
        eng = Engine(model, params, EngineConfig(
            backend="paged", num_slots=2, block_size=4, num_blocks=17,
            max_len=32, mesh=MESH, overlap=overlap))
        handles = [eng.add_request(p, sp) for p, sp in zip(prompts, sps)]
        while eng.has_work:
            eng.step()
        assert eng.stats()["blocks_used"] == 0
        outs[overlap] = [h.token_ids for h in handles]
        del eng
    assert outs[True] == outs[False]
    print("body ran")
    """)
