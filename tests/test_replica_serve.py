"""Multi-replica data-parallel serving (ReplicaSet) + shared queue.

Contract, in two halves:

In-process (single device, ``mesh=None`` — replicas are plain engines):
  * ReplicaSet(dp=2) is token-identical to a single Engine on ragged
    prompts, greedy AND seeded stochastic sampling, both backends;
  * queue fairness under saturation: dispatch is strictly FCFS (the
    shared-queue head is never overtaken), every request completes, and
    no request's shared-queue wait is unbounded;
  * zero block leaks across ALL replicas under per-replica preemption;
  * the dispatch policies place work deterministically (least-loaded
    spreads, round-robin rotates) and batched prefill admission still
    batches inside each replica.

Subprocess (8 fake CPU devices, like test_sharded_serve): dp=2 tp=2 —
each replica on its own (data=2, model=2) submesh with its own
head-sharded pool — stays token-identical to the single unsharded
engine across olmo / recurrentgemma / xlstm.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import (Engine, EngineConfig, ReplicaSet,
                                 SamplingParams)
from repro.models.model import Model

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _smoke(arch="olmo_1b"):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _ragged_work(cfg, rng, n=6):
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12, 5, 9, 14)[:n]]
    sp = [SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=4, temperature=0.9, top_k=12, seed=3),
          SamplingParams(max_tokens=6, temperature=1.0, top_p=0.85,
                         seed=5),
          SamplingParams(max_tokens=3),
          SamplingParams(max_tokens=5, temperature=0.7, seed=11),
          SamplingParams(max_tokens=4)][:n]
    return prompts, sp


@pytest.mark.parametrize("backend", ["paged", "static"])
def test_replicaset_token_identical_to_single_engine(rng, backend):
    """dp=2 == one engine, greedy + seeded sampling, both backends."""
    cfg, model, params = _smoke()
    prompts, sp = _ragged_work(cfg, rng)
    base = dict(backend=backend, num_slots=3, block_size=4,
                num_blocks=33, max_len=32)
    want = Engine(model, params,
                  EngineConfig(**base)).generate(prompts, sp)
    rset = ReplicaSet(model, params, EngineConfig(**base), dp=2)
    got = rset.generate(prompts, sp)
    assert got == want, (got, want)
    st = rset.stats()
    assert st["blocks_used"] == 0
    assert sum(st["dispatched"]) == len(prompts)
    assert all(d > 0 for d in st["dispatched"]), \
        "least-loaded never spread across replicas"


def test_replicaset_drafter_mix_token_identical(rng):
    """Per-replica drafter choice (satellite): ``overrides=`` mixes a
    speculative replica (spec_tokens=4) with a plain decode replica in
    ONE ReplicaSet. Outputs stay bit-identical to a single plain
    engine regardless of which replica serves a request — speculation
    is an engine-local throughput choice, invisible in tokens (the
    verify pass accepts exactly the plain stream)."""
    cfg, model, params = _smoke()
    prompts, sp = _ragged_work(cfg, rng)
    base = dict(backend="paged", num_slots=3, block_size=4,
                num_blocks=33, max_len=32)
    want = Engine(model, params,
                  EngineConfig(**base)).generate(prompts, sp)
    rset = ReplicaSet(model, params, EngineConfig(**base), dp=2,
                      overrides=[{"spec_tokens": 4}, {"spec_tokens": 0}])
    assert rset.replicas[0].cfg.spec_tokens == 4
    assert rset.replicas[1].cfg.spec_tokens == 0
    got = rset.generate(prompts, sp)
    assert got == want, (got, want)
    st = rset.stats()
    assert st["blocks_used"] == 0
    assert all(d > 0 for d in st["dispatched"]), \
        "mix never exercised both drafter choices"
    assert rset.replicas[0].stats()["spec"]["proposed"] > 0, \
        "the speculative replica never drafted"


def test_replicaset_fcfs_fairness_under_saturation(rng):
    """Satellite invariant: with every replica saturated (1 slot each,
    12 queued requests), dispatch stays strictly FCFS — request i never
    leaves the shared queue after request j > i — every request
    completes, and the max shared-queue wait is bounded by the drain
    time of the requests ahead (no unbounded waiting)."""
    cfg, model, params = _smoke()
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 4 + i % 3)))
               for i in range(12)]
    rset = ReplicaSet(
        model, params,
        EngineConfig(backend="paged", num_slots=1, block_size=4,
                     num_blocks=9, max_len=32), dp=2)
    order = []
    orig_dispatch = rset._dispatch

    def spying_dispatch():
        before = {h.uid for h in rset.queue}
        moved = orig_dispatch()
        after = {h.uid for h in rset.queue}
        order.extend(sorted(before - after))
        return moved

    rset._dispatch = spying_dispatch
    handles = [rset.add_request(p, SamplingParams(max_tokens=4))
               for p in prompts]
    rset.drain()
    assert all(h.finished for h in handles)
    assert order == sorted(order), f"dispatch overtook FCFS: {order}"
    st = rset.stats()
    assert len(order) == 12
    # 12 requests over 2 single-slot replicas, <= 4+4 tokens each: the
    # last request waits at most the steps the 10 ahead of it occupy
    assert st["queue_wait_steps_max"] <= 12 * 8
    assert st["blocks_used"] == 0


def test_replicaset_preemption_stays_local_no_leaks(rng):
    """Pools too small for each replica's co-admitted worst cases force
    per-replica LIFO preemption; outputs still match the uncontended
    single engine and every replica's allocator drains to empty."""
    cfg, model, params = _smoke()
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(6)]
    sp = SamplingParams(max_tokens=16)
    want = Engine(model, params,
                  EngineConfig(backend="paged", num_slots=3, block_size=4,
                               num_blocks=65, max_len=64)).generate(
                      prompts, sp)
    rset = ReplicaSet(
        model, params,
        EngineConfig(backend="paged", num_slots=3, block_size=4,
                     num_blocks=14, max_len=64), dp=2)
    got = rset.generate(prompts, sp)
    assert got == want
    st = rset.stats()
    assert st["preemptions"] >= 1, st
    assert st["blocks_used"] == 0
    for eng in rset.replicas:
        be = eng.backend
        assert be.alloc.free_count == be.layout.usable_blocks


def test_replicaset_round_robin_rotates(rng):
    cfg, model, params = _smoke()
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 5)))
               for _ in range(6)]
    rset = ReplicaSet(
        model, params,
        EngineConfig(backend="paged", num_slots=4, block_size=4,
                     num_blocks=33, max_len=32), dp=2,
        policy="round_robin")
    rset.generate(prompts, SamplingParams(max_tokens=3))
    assert rset.stats()["dispatched"] == [3, 3]


def test_replicaset_batched_prefill_inside_replicas(rng):
    """A same-bucket burst split across replicas still batches: total
    prefill calls well under one per request."""
    cfg, model, params = _smoke()
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 8, 6, 7, 5, 8, 7, 6)]
    rset = ReplicaSet(
        model, params,
        EngineConfig(backend="paged", num_slots=4, block_size=4,
                     num_blocks=33, max_len=32), dp=2)
    rset.generate(prompts, SamplingParams(max_tokens=3))
    st = rset.stats()
    assert st["prefill_reqs"] == 8
    assert st["prefill_calls"] <= 4, st


def test_replicaset_rejects_impossible_request(rng):
    """Validation happens at the shared queue, not at dispatch: an
    over-budget request raises immediately and nothing is enqueued."""
    cfg, model, params = _smoke()
    rset = ReplicaSet(
        model, params,
        EngineConfig(backend="paged", num_slots=2, block_size=4,
                     num_blocks=5, max_len=64), dp=2)
    with pytest.raises(ValueError):
        rset.add_request(list(range(1, 9)), SamplingParams(max_tokens=32))
    assert not rset.has_work
    with pytest.raises(ValueError):
        ReplicaSet(model, params,
                   EngineConfig(backend="paged",
                                mesh="not-none"), dp=2)


# -- subprocess: dp=2 x tp=2 over 8 fake devices ------------------------

_PRELUDE = """
import jax, numpy as np
from repro.configs import get_config
from repro.launch.engine import (Engine, EngineConfig, ReplicaSet,
                                 SamplingParams)
from repro.launch.mesh import make_mesh
from repro.models.model import Model

assert len(jax.devices()) == 8
MESH = make_mesh((2, 2), ("data", "model"))   # dp x tp: 4 of 8 devices

def setup(arch):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))

def work(cfg, rng):
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12, 6)]
    sp = [SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=5, temperature=0.9, top_k=12,
                         seed=3),
          SamplingParams(max_tokens=5, temperature=1.0, top_p=0.85,
                         seed=5),
          SamplingParams(max_tokens=4)]
    return prompts, sp
"""


def _run(body: str):
    # Dedent the body BEFORE prepending the (unindented) prelude; the
    # "body ran" marker guards against the body silently parsing into a
    # prelude trailing function (see test_sharded_serve.py).
    code = _PRELUDE + textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "body ran" in proc.stdout, f"test body never executed:\n{code}"
    return proc.stdout


def test_replicaset_dp2_tp2_token_identical():
    """Acceptance: ReplicaSet(dp=2) over (2, 2) submeshes — each replica
    head-sharding its own pool over its model axis — emits tokens
    identical to the single unsharded engine, greedy and seeded, on
    olmo (head-shard path) and recurrentgemma (GSPMD fallback)."""
    _run("""
    from repro.launch.mesh import submeshes
    rng = np.random.default_rng(0)
    for arch in ("olmo_1b", "recurrentgemma_2b"):
        cfg, model, params = setup(arch)
        prompts, sp = work(cfg, rng)
        base = dict(backend="paged", num_slots=2, block_size=4,
                    num_blocks=33, max_len=32)
        want = Engine(model, params, EngineConfig(
            **base)).generate(prompts, sp)
        rset = ReplicaSet(model, params, EngineConfig(**base),
                          dp=2, mesh=MESH)
        subs = [e.cfg.mesh for e in rset.replicas]
        assert all(dict(zip(s.axis_names, s.devices.shape))
                   == {"data": 1, "model": 2} for s in subs)
        assert not set(subs[0].devices.flat) & set(subs[1].devices.flat)
        got = rset.generate(prompts, sp)
        assert got == want, (arch, got, want)
        assert rset.stats()["blocks_used"] == 0
        print(arch, "ok")
    print("body ran")
    """)


def test_replicaset_dp2_preemption_no_leaks_sharded():
    """Per-replica LIFO preemption on head-sharded pools: outputs match
    the uncontended run; every replica's allocator and table drain."""
    _run("""
    from repro.models import paged_kv
    rng = np.random.default_rng(2)
    cfg, model, params = setup("olmo_1b")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(6)]
    sp = SamplingParams(max_tokens=16)
    want = Engine(model, params, EngineConfig(
        backend="paged", num_slots=3, block_size=4, num_blocks=65,
        max_len=64)).generate(prompts, sp)
    rset = ReplicaSet(model, params, EngineConfig(
        backend="paged", num_slots=3, block_size=4, num_blocks=14,
        max_len=64), dp=2, mesh=MESH)
    got = rset.generate(prompts, sp)
    st = rset.stats()
    assert st["preemptions"] >= 1, st
    assert got == want
    assert st["blocks_used"] == 0
    for eng in rset.replicas:
        be = eng.backend
        assert be.alloc.free_count == be.layout.usable_blocks
        assert np.all(be.table == paged_kv.NULL_BLOCK)
    print("body ran")
    """)


@pytest.mark.slow
def test_replicaset_dp2_third_arch_xlstm():
    """xLSTM: per-slot mlstm/slstm states shard over each replica's
    submesh while pools stay head-sharded — still token-identical."""
    _run("""
    rng = np.random.default_rng(4)
    cfg, model, params = setup("xlstm_1_3b")
    prompts, sp = work(cfg, rng)
    base = dict(backend="paged", num_slots=2, block_size=4,
                num_blocks=33, max_len=32)
    want = Engine(model, params, EngineConfig(
        **base)).generate(prompts, sp)
    got = ReplicaSet(model, params, EngineConfig(**base),
                     dp=2, mesh=MESH).generate(prompts, sp)
    assert got == want, (got, want)
    print("body ran")
    """)
