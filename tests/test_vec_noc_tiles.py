"""VEC strip-mining, uncore/NoC model, tile dispatch."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import noc
from repro.core.tiles import DEFAULT_POLICY, STX_POLICY, TilePolicy, \
    dispatch_matmul, dispatch_reduction
from repro.core.vec import VecTimingModel, strip_mine, strip_reduce


@given(st.integers(1, 300), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_strip_mine_vla_property(n, vl):
    """Any length == direct computation (RVV no-tail-handling semantics)."""
    x = jnp.arange(n, dtype=jnp.float32)
    out = strip_mine(lambda v: v * 2 + 1, x, max_vl=vl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x * 2 + 1))


def test_strip_reduce():
    x = jnp.arange(100, dtype=jnp.float32)
    total = strip_reduce(
        lambda acc, strip, mask: acc + jnp.sum(jnp.where(mask, strip, 0)),
        x, max_vl=16, init=jnp.float32(0))
    assert float(total) == float(jnp.sum(x))


def test_vpu_timing_model_paper_numbers():
    """§3.1: 8 FUs x 8 elem/cycle -> 256-elem vop in 32 (+~3) cycles."""
    m = VecTimingModel()
    assert m.vop_cycles(256) == 32 + 3
    assert m.vop_cycles(8) == 1 + 3
    assert m.utilization(256) > m.utilization(64) > m.utilization(8)
    # full-VL DP GFLOPS at 1 GHz: 256 elems * 2 flop / 35 cycles
    assert abs(m.gflops(256) - 256 * 2 / 35) < 1e-9


def test_noc_collective_model_paper_numbers():
    """§4: ring all-reduce/all-gather against the EPAC C2C/NoC tiers."""
    t_pod = noc.all_reduce_time(1e9, 2, "pod")
    t_ici = noc.all_reduce_time(1e9, 2, "data")
    assert t_pod == pytest.approx(1e9 / noc.V5E_FABRIC.pod_bw)
    assert t_ici == pytest.approx(1e9 / noc.V5E_FABRIC.ici_bw)
    assert noc.all_reduce_time(1e9, 1, "data") == 0.0
    assert noc.all_gather_time(1e6, 16, "data") == pytest.approx(
        15 * 1e6 / 50e9)
    assert noc.EPAC_NOC["noc_port_bw_GBps_per_dir"] == 64.0
    assert noc.EPAC_NOC["c2c_bw_GBps_per_dir"] == 25.0


def test_l2_interleave():
    assert noc.interleave(0, 4) == 0
    assert noc.interleave(64, 4) == 1
    assert noc.interleave(64 * 4, 4) == 0
    assert noc.interleave(4096, 4, mode="block") == 1


def test_l2_interleave_modes_and_errors():
    # line mode respects a custom line size
    assert noc.interleave(256, 4, line_bytes=128) == 2
    # block mode keeps a whole 4 KiB block on one slice
    assert all(noc.interleave(a, 8, mode="block") == 0
               for a in range(0, 4096, 512))
    assert noc.interleave(4096 * 9, 8, mode="block") == 1
    with pytest.raises(ValueError):
        noc.interleave(0, 4, mode="page")


@pytest.mark.parametrize("fn", [noc.all_reduce_time, noc.all_gather_time,
                                noc.reduce_scatter_time,
                                noc.all_to_all_time])
def test_collectives_trivial_axis_is_free(fn):
    """axis_size <= 1 -> exactly 0, for every collective and tier."""
    for axis in ("data", "model", "pod"):
        assert fn(1e9, 1, axis) == 0.0
        assert fn(1e9, 0, axis) == 0.0


@pytest.mark.parametrize("fn", [noc.all_reduce_time, noc.all_gather_time,
                                noc.reduce_scatter_time,
                                noc.all_to_all_time])
def test_collectives_monotone(fn):
    """Time grows with axis size (fixed per-device bytes), with bytes,
    and pod tier is never faster than ICI."""
    times = [fn(1e9, n, "data") for n in (2, 4, 8, 16, 64)]
    assert all(b > a for a, b in zip(times, times[1:]))
    assert fn(2e9, 8, "data") == pytest.approx(2 * fn(1e9, 8, "data"))
    assert fn(1e9, 8, "pod") >= fn(1e9, 8, "data")


def test_collective_formula_shapes():
    """Ring formula factors: all-reduce moves 2(n-1)/n, reduce-scatter
    (n-1)/n, all-gather (n-1) shard-bytes."""
    n, by, bw = 8, 1e9, noc.V5E_FABRIC.ici_bw
    assert noc.all_reduce_time(by, n, "data") == pytest.approx(
        2 * (n - 1) / n * by / bw)
    assert noc.reduce_scatter_time(by, n, "data") == pytest.approx(
        (n - 1) / n * by / bw)
    assert noc.all_gather_time(by, n, "data") == pytest.approx(
        (n - 1) * by / bw)
    assert noc.all_reduce_time(by, n, "data") == pytest.approx(
        noc.reduce_scatter_time(by, n, "data")
        + noc.all_gather_time(by / n, n, "data"))


def test_p2p_time_formula():
    """Wormhole point-to-point: bandwidth paid once, latency per hop;
    same-device transfers are free."""
    f = noc.V5E_FABRIC
    by = 1e6
    assert noc.p2p_time(by, 0, "data") == 0.0
    assert noc.p2p_time(by, -1, "data") == 0.0
    assert noc.p2p_time(by, 1, "data") == pytest.approx(
        by / f.ici_bw + f.latency_us * 1e-6)
    assert noc.p2p_time(by, 3, "data") == pytest.approx(
        by / f.ici_bw + 3 * f.latency_us * 1e-6)
    # slow tier: the pod axis maps to the C2C SerDes analogue
    assert noc.p2p_time(by, 1, "pod") == pytest.approx(
        by / f.pod_bw + f.latency_us * 1e-6)


def test_p2p_time_monotone():
    """More bytes or more hops never gets cheaper."""
    ts = [noc.p2p_time(b, 1, "data") for b in (1e3, 1e6, 1e9)]
    assert ts == sorted(ts) and ts[0] < ts[-1]
    th = [noc.p2p_time(1e6, h, "data") for h in (1, 2, 4, 8)]
    assert th == sorted(th) and th[0] < th[-1]


def test_p2p_time_epac_section4_numbers():
    """Cross-check against the paper's §4 bandwidth table: one 64-byte
    L2 line over a 64 GB/s NoC port takes 1 ns at zero latency, and the
    default fabric's slow tier IS the 25 GB/s C2C per-direction rate."""
    port = noc.FabricSpec(
        ici_bw=noc.EPAC_NOC["noc_port_bw_GBps_per_dir"] * 1e9,
        latency_us=0.0)
    line = noc.EPAC_NOC["l2_line_bytes"]
    assert noc.p2p_time(line, 1, "data", port) == pytest.approx(1e-9)
    assert noc.V5E_FABRIC.pod_bw == pytest.approx(
        noc.EPAC_NOC["c2c_bw_GBps_per_dir"] * 1e9)
    # the demonstrated bring-up rate (§5) prices a transfer slower than
    # the spec rate for the same payload
    demo = noc.FabricSpec(
        pod_bw=noc.EPAC_NOC["c2c_demonstrated_GBps"] * 1e9)
    assert noc.p2p_time(1e6, 1, "pod", demo) > noc.p2p_time(1e6, 1, "pod")


def test_tile_dispatch_agreement(rng):
    x = jnp.asarray(rng.normal(size=(48, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    vec_out = dispatch_matmul(x, w, DEFAULT_POLICY)
    stx_out = dispatch_matmul(
        x, w, TilePolicy(matmul="stx", interpret=True,
                         stx_block_m=16, stx_block_n=16, stx_block_k=16))
    np.testing.assert_allclose(np.asarray(vec_out), np.asarray(stx_out),
                               rtol=1e-5, atol=1e-4)


def test_vrp_reduction_tile(rng):
    x = jnp.asarray(rng.normal(size=4096) * 1e4, jnp.float32)
    vec = float(dispatch_reduction(x, DEFAULT_POLICY))
    vrp = float(dispatch_reduction(
        x, TilePolicy(reduction="vrp", vrp_env="vp128")))
    exact = float(np.sum(np.asarray(x, np.float64)))
    assert abs(vrp - exact) <= abs(vec - exact) + 1e-3


def test_tile_policy_validation():
    with pytest.raises(ValueError):
        TilePolicy(matmul="gpu")
