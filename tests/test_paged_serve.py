"""Paged KV cache + continuous batching: kernel/layer/engine equivalence
and scheduler invariants (tentpole coverage).

Contract chain, weakest to strongest:
  1. paged kernel (interpret) == jnp ref oracle, over GQA/MQA, sliding
     window, ragged lengths and block-boundary cases;
  2. paged layer decode == dense layer decode on identical histories;
  3. continuous-batching Scheduler == static Server greedy outputs,
     end-to-end through real smoke models;
  4. scheduler invariants: no block leaked/double-freed, retired slots
     reused, outputs independent of admission order and slot count.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.launch.serve import Scheduler, SchedulerConfig, ServeConfig, Server
from repro.models import attention as attn_lib
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx

CTX = RunCtx(kernel_mode="ref")


def _rand_pool_case(rng, B, hq, hkv, hd, bs, nbmax, lengths):
    nb = B * nbmax + 1
    q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    # distinct physical blocks per sequence, deliberately scrambled
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    return q, kp, vp, bt, jnp.asarray(lengths, jnp.int32)


# -- 1. kernel vs oracle ------------------------------------------------


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("window", [None, 5])
def test_paged_kernel_matches_ref(rng, hq, hkv, window):
    bs, nbmax = 4, 4
    # ragged: mid-block, exact block boundary, single token, full
    lengths = [7, 8, 1, 16]
    q, kp, vp, bt, ln = _rand_pool_case(rng, 4, hq, hkv, 16, bs, nbmax,
                                        lengths)
    got = ops.paged_decode_attention(q, kp, vp, bt, ln, window=window,
                                     mode="interpret")
    want = ref.paged_decode_attention(q, kp, vp, bt, ln, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_bf16(rng):
    q, kp, vp, bt, ln = _rand_pool_case(rng, 2, 4, 2, 32, 8, 2, [5, 11])
    q, kp, vp = (t.astype(jnp.bfloat16) for t in (q, kp, vp))
    got = ops.paged_decode_attention(q, kp, vp, bt, ln, mode="interpret")
    want = ref.paged_decode_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@given(st.integers(1, 31), st.integers(1, 31))
@settings(max_examples=15, deadline=None)
def test_paged_kernel_any_ragged_pair(l0, l1):
    """Property: any pair of lengths within the table range agrees with
    the oracle (block-boundary cases arise from the sweep)."""
    rng = np.random.default_rng(l0 * 100 + l1)
    q, kp, vp, bt, ln = _rand_pool_case(rng, 2, 4, 2, 8, 4, 8, [l0, l1])
    got = ops.paged_decode_attention(q, kp, vp, bt, ln, mode="interpret")
    want = ref.paged_decode_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# -- 2. paged oracle vs dense attention on one history ------------------


def test_paged_ref_matches_dense_gather(rng):
    """Gathering a sequence's blocks and running dense attention over its
    first L positions must equal the paged oracle."""
    B, hq, hkv, hd, bs, nbmax = 3, 4, 2, 16, 4, 4
    lengths = [6, 12, 16]
    q, kp, vp, bt, ln = _rand_pool_case(rng, B, hq, hkv, hd, bs, nbmax,
                                        lengths)
    S = nbmax * bs
    k_seq = kp[bt].reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)
    v_seq = vp[bt].reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)
    paged = ref.paged_decode_attention(q, kp, vp, bt, ln)
    for b, L in enumerate(lengths):
        dense = ref.flash_attention(q[b:b + 1, :, None],
                                    k_seq[b:b + 1, :, :L],
                                    v_seq[b:b + 1, :, :L], causal=False)
        np.testing.assert_allclose(np.asarray(paged[b]),
                                   np.asarray(dense[0, :, 0]),
                                   rtol=1e-5, atol=1e-5)


# -- 3. layer-level: paged/batched decode vs stock decode ---------------


@pytest.mark.parametrize("arch,window", [("olmo_1b", None),
                                         ("h2o_danube_3_4b", 16)])
def test_layer_decode_paged_matches_dense(rng, arch, window):
    """Replay the same token history through the dense decode_attend and
    the paged/batched path; outputs must agree step by step."""
    cfg = get_config(arch).smoke()
    params = attn_lib.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, n_steps = 2, 9
    layout = paged_kv.PagedLayout(num_slots=B, num_blocks=9, block_size=4,
                                  max_len=16)
    dense = attn_lib.init_kv_cache(cfg, B, 16, jnp.float32, window=window)
    if window is None:
        paged = paged_kv.init_layer_pool(cfg, layout, jnp.float32)
        table = np.zeros((B, layout.max_blocks_per_seq), np.int32)
        alloc = paged_kv.BlockAllocator(layout)
        for b in range(B):
            ids = alloc.alloc(layout.max_blocks_per_seq)
            table[b] = ids
        table = jnp.asarray(table)
    else:
        paged = attn_lib.init_kv_cache(cfg, B, 16, jnp.float32,
                                       window=window)
    for t in range(n_steps):
        x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
        out_d, dense = attn_lib.decode_attend(params, cfg, x, dense,
                                              jnp.int32(t), window=window)
        lengths = jnp.full((B,), t, jnp.int32)
        if window is None:
            out_p, paged = attn_lib.decode_attend_paged(
                params, cfg, x, paged, table, lengths, kernel_mode="ref")
        else:
            out_p, paged = attn_lib.decode_attend_batched(
                params, cfg, x, paged, lengths, window=window)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"step {t}")


# -- 4. engine-level: Scheduler == static Server ------------------------


def _greedy_static(model, params, prompts, n_new):
    server = Server(model, params,
                    ServeConfig(batch_size=len(prompts), max_len=64))
    return server.generate(prompts, n_new)


@pytest.mark.parametrize("arch", ["olmo_1b", "h2o_danube_3_4b",
                                  "recurrentgemma_2b"])
def test_scheduler_matches_static_server(rng, arch):
    """Same-length prompts (so the static batcher adds no padding): both
    engines must produce identical greedy continuations."""
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_new, plen = 6, 7
    prompts = [list(rng.integers(0, cfg.vocab_size, plen))
               for _ in range(3)]
    want = _greedy_static(model, params, prompts, n_new)
    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=2, block_size=4,
                                      num_blocks=17, max_len=32))
    reqs = [sched.submit(p, n_new) for p in prompts]
    sched.run()
    for r, w in zip(reqs, want):
        assert r.out == w, f"req{r.uid}: {r.out} != {w}"


def test_scheduler_single_long_prompt_spans_blocks(rng):
    """One prompt spanning several blocks decodes identically to the
    dense path (block-table indirection is invisible)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(rng.integers(0, cfg.vocab_size, 19))  # 5 blocks of 4
    want = _greedy_static(model, params, [prompt], 8)[0]
    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=1, block_size=4,
                                      num_blocks=17, max_len=40))
    req = sched.submit(prompt, 8)
    sched.run()
    assert req.out == want


# -- 5. scheduler invariants --------------------------------------------


def _run_trace(model, params, prompts_and_targets, *, num_slots,
               num_blocks=33):
    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=num_slots, block_size=4,
                                      num_blocks=num_blocks, max_len=32))
    reqs = [sched.submit(p, n) for p, n in prompts_and_targets]
    sched.run()
    return sched, reqs


def test_scheduler_no_block_leak_and_slot_reuse(rng):
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    work = [(list(rng.integers(0, cfg.vocab_size,
                               int(rng.integers(2, 12)))),
             int(rng.integers(1, 10))) for _ in range(9)]
    sched, reqs = _run_trace(model, params, work, num_slots=3)
    # more requests than slots -> retired slots were reused
    assert len(sched.finished) == 9
    # every block returned to the free list; allocator saw no double-free
    # (it raises on double-free) and nothing leaked:
    assert sched.alloc.used_count == 0
    assert sched.alloc.free_count == sched.layout.usable_blocks
    assert np.all(sched.table == paged_kv.NULL_BLOCK)
    assert np.all(sched.lengths == 0)
    for r, (p, n) in zip(reqs, work):
        assert r.done and len(r.out) == n


def test_scheduler_outputs_independent_of_admission_order(rng):
    """Greedy outputs are a pure function of (params, prompt): shuffling
    submission order and changing slot count must not change any
    request's tokens (no cross-request contamination through the shared
    pool or the null block)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    work = [(list(rng.integers(0, cfg.vocab_size,
                               int(rng.integers(2, 10)))),
             int(rng.integers(2, 8))) for _ in range(6)]
    _, reqs_a = _run_trace(model, params, work, num_slots=2)
    order = [3, 0, 5, 1, 4, 2]
    _, reqs_b = _run_trace(model, params, [work[i] for i in order],
                           num_slots=4)
    outs_a = {tuple(work[i][0]): reqs_a[i].out for i in range(6)}
    for j, i in enumerate(order):
        assert reqs_b[j].out == outs_a[tuple(work[i][0])]


def test_scheduler_queues_when_pool_tight(rng):
    """Pool too small for all requests at once: admission must block and
    later admit from the queue, not fail or corrupt."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # each request reserves ceil((8+8)/4)=4 blocks; pool has 9 usable ->
    # at most 2 concurrent of 5 requests
    work = [(list(rng.integers(0, cfg.vocab_size, 8)), 8)
            for _ in range(5)]
    sched, reqs = _run_trace(model, params, work, num_slots=4,
                             num_blocks=10)
    assert all(len(r.out) == 8 for r in reqs)
    assert sched.alloc.used_count == 0


def test_scheduler_eos_retirement(rng):
    """EOS is stripped, never emitted — whether it arrives straight out
    of prefill (zero tokens) or mid-decode — and retirement frees the
    slot for queued work."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(rng.integers(0, cfg.vocab_size, 7))
    # discover what the model greedily emits for this prompt
    probe = Scheduler(model, params,
                      SchedulerConfig(num_slots=1, block_size=4,
                                      num_blocks=17, max_len=32))
    first = probe.submit(list(prompt), 1)
    probe.run()
    eos = first.out[0]
    sched = Scheduler(model, params,
                      SchedulerConfig(num_slots=1, block_size=4,
                                      num_blocks=17, max_len=32,
                                      eos_id=eos))
    r1 = sched.submit(list(prompt), 20)          # prefill-EOS case
    r2 = sched.submit(list(rng.integers(0, cfg.vocab_size, 5)), 3)
    sched.run()
    assert r1.done and r1.out == []              # stripped, not emitted
    assert r2.done and len(r2.out) <= 3 and eos not in r2.out
    assert sched.alloc.used_count == 0


def test_allocator_double_free_detected():
    layout = paged_kv.PagedLayout(num_slots=1, num_blocks=4, block_size=4,
                                  max_len=8)
    alloc = paged_kv.BlockAllocator(layout)
    ids = alloc.alloc(2)
    alloc.free(ids)
    with pytest.raises(ValueError):
        alloc.free([ids[0]])
    with pytest.raises(ValueError):
        alloc.free([paged_kv.NULL_BLOCK])
    with pytest.raises(MemoryError):
        alloc.alloc(4)
