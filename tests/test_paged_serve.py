"""Paged KV cache + the unified serving Engine: kernel/layer/engine
equivalence and scheduler invariants (tentpole coverage).

Contract chain, weakest to strongest:
  1. paged kernel (interpret) == jnp ref oracle, over GQA/MQA, sliding
     window, ragged lengths and block-boundary cases;
  2. paged layer decode == dense layer decode on identical histories;
  3. right-padded (bucketed) prefill == exact-length prefill, logits and
     downstream decode;
  4. Engine equivalence: paged backend == static backend == unbatched
     oracle, greedy, on ragged prompts (the PR-1 left-pad leak is the
     regression target), through real smoke models;
  5. scheduler invariants: no block leaked/double-freed under optimistic
     admission + LIFO preemption, retired slots reused, outputs
     independent of admission order and preemption history, bucketed
     prefill compile cap.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.models import attention as attn_lib
from repro.models import paged_kv
from repro.models.model import Model
from repro.models.transformer import RunCtx

CTX = RunCtx(kernel_mode="ref")


def _rand_pool_case(rng, B, hq, hkv, hd, bs, nbmax, lengths):
    nb = B * nbmax + 1
    q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    # distinct physical blocks per sequence, deliberately scrambled
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    return q, kp, vp, bt, jnp.asarray(lengths, jnp.int32)


# -- 1. kernel vs oracle ------------------------------------------------


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("window", [None, 5])
def test_paged_kernel_matches_ref(rng, hq, hkv, window):
    bs, nbmax = 4, 4
    # ragged: mid-block, exact block boundary, single token, full
    lengths = [7, 8, 1, 16]
    q, kp, vp, bt, ln = _rand_pool_case(rng, 4, hq, hkv, 16, bs, nbmax,
                                        lengths)
    got = ops.paged_decode_attention(q, kp, vp, bt, ln, window=window,
                                     mode="interpret")
    want = ref.paged_decode_attention(q, kp, vp, bt, ln, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_bf16(rng):
    q, kp, vp, bt, ln = _rand_pool_case(rng, 2, 4, 2, 32, 8, 2, [5, 11])
    q, kp, vp = (t.astype(jnp.bfloat16) for t in (q, kp, vp))
    got = ops.paged_decode_attention(q, kp, vp, bt, ln, mode="interpret")
    want = ref.paged_decode_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@given(st.integers(1, 31), st.integers(1, 31))
@settings(max_examples=15, deadline=None)
def test_paged_kernel_any_ragged_pair(l0, l1):
    """Property: any pair of lengths within the table range agrees with
    the oracle (block-boundary cases arise from the sweep)."""
    rng = np.random.default_rng(l0 * 100 + l1)
    q, kp, vp, bt, ln = _rand_pool_case(rng, 2, 4, 2, 8, 4, 8, [l0, l1])
    got = ops.paged_decode_attention(q, kp, vp, bt, ln, mode="interpret")
    want = ref.paged_decode_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# -- 2. paged oracle vs dense attention on one history ------------------


def test_paged_ref_matches_dense_gather(rng):
    """Gathering a sequence's blocks and running dense attention over its
    first L positions must equal the paged oracle."""
    B, hq, hkv, hd, bs, nbmax = 3, 4, 2, 16, 4, 4
    lengths = [6, 12, 16]
    q, kp, vp, bt, ln = _rand_pool_case(rng, B, hq, hkv, hd, bs, nbmax,
                                        lengths)
    S = nbmax * bs
    k_seq = kp[bt].reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)
    v_seq = vp[bt].reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)
    paged = ref.paged_decode_attention(q, kp, vp, bt, ln)
    for b, L in enumerate(lengths):
        dense = ref.flash_attention(q[b:b + 1, :, None],
                                    k_seq[b:b + 1, :, :L],
                                    v_seq[b:b + 1, :, :L], causal=False)
        np.testing.assert_allclose(np.asarray(paged[b]),
                                   np.asarray(dense[0, :, 0]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch,window", [("olmo_1b", None),
                                         ("h2o_danube_3_4b", 16)])
def test_layer_decode_paged_matches_dense(rng, arch, window):
    """Replay the same token history through the dense decode_attend and
    the paged/batched path; outputs must agree step by step."""
    cfg = get_config(arch).smoke()
    params = attn_lib.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, n_steps = 2, 9
    layout = paged_kv.PagedLayout(num_slots=B, num_blocks=9, block_size=4,
                                  max_len=16)
    dense = attn_lib.init_kv_cache(cfg, B, 16, jnp.float32, window=window)
    if window is None:
        paged = paged_kv.init_layer_pool(cfg, layout, jnp.float32)
        table = np.zeros((B, layout.max_blocks_per_seq), np.int32)
        alloc = paged_kv.BlockAllocator(layout)
        for b in range(B):
            table[b] = alloc.alloc(layout.max_blocks_per_seq)
        table = jnp.asarray(table)
    else:
        paged = attn_lib.init_kv_cache(cfg, B, 16, jnp.float32,
                                       window=window)
    for t in range(n_steps):
        x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
        out_d, dense = attn_lib.decode_attend(params, cfg, x, dense,
                                              jnp.int32(t), window=window)
        lengths = jnp.full((B,), t, jnp.int32)
        if window is None:
            out_p, paged = attn_lib.decode_attend_paged(
                params, cfg, x, paged, table, lengths, kernel_mode="ref")
        else:
            out_p, paged = attn_lib.decode_attend_batched(
                params, cfg, x, paged, lengths, window=window)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"step {t}")


# -- 3. right-padded (bucketed) prefill == exact-length prefill ---------


@pytest.mark.parametrize("arch", ["olmo_1b", "h2o_danube_3_4b",
                                  "recurrentgemma_2b", "xlstm_1_3b"])
def test_padded_prefill_matches_exact(rng, arch):
    """Masked (right-padded) prefill must reproduce exact-length prefill:
    logits at every real position AND the downstream decode logits (i.e.
    ring/recurrent/conv cache state was extracted at the true length)."""
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S, Sb, ML = 11, 16, 32
    prompt = rng.integers(0, cfg.vocab_size, S)
    exact_t = jnp.asarray([prompt], jnp.int32)
    pad_t = jnp.zeros((1, Sb), jnp.int32).at[0, :S].set(exact_t[0])
    lg_e, cache_e = model.prefill(params, {"tokens": exact_t}, CTX,
                                  max_len=ML)
    lg_p, cache_p = model.prefill(params, {"tokens": pad_t}, CTX,
                                  max_len=ML,
                                  length=jnp.asarray([S], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_p[:, :S]), np.asarray(lg_e),
                               rtol=1e-5, atol=1e-5)
    tok = jnp.argmax(lg_e[:, S - 1:S], -1).astype(jnp.int32)
    for t in range(4):
        de, cache_e = model.decode_step(params, cache_e, tok,
                                        jnp.int32(S + t), CTX)
        dp, cache_p = model.decode_step(params, cache_p, tok,
                                        jnp.int32(S + t), CTX)
        np.testing.assert_allclose(np.asarray(dp), np.asarray(de),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"decode step {t}")
        tok = jnp.argmax(dp, -1)[:, None].astype(jnp.int32)


# -- 4. engine-level: paged == static == unbatched oracle ---------------


def _oracle_greedy(model, params, prompt, n_new, max_len=64):
    """Unbatched reference: exact prefill + scalar decode loop."""
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, CTX,
        max_len=max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    while len(out) < n_new:
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(len(prompt) + len(out) - 1), CTX)
        out.append(int(jnp.argmax(lg[0])))
    return out


def _engine(model, params, backend, **kw):
    base = dict(backend=backend, num_slots=2, block_size=4, num_blocks=17,
                max_len=32)
    base.update(kw)
    return Engine(model, params, EngineConfig(**base))


@pytest.mark.parametrize("arch", ["olmo_1b", "h2o_danube_3_4b",
                                  "recurrentgemma_2b"])
def test_engine_backends_match_oracle_ragged(rng, arch):
    """RAGGED prompts through one Engine API, both backends: greedy
    paged == static == unbatched oracle. Regression for the PR-1 static
    left-pad leak (prefill attended pad keys, shifting short-prompt
    outputs) — right-padded prefill with true-length cache extraction
    must match the per-request reference exactly."""
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_new = 6
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12)]
    want = [_oracle_greedy(model, params, p, n_new) for p in prompts]
    sp = SamplingParams(max_tokens=n_new)
    got_p = _engine(model, params, "paged").generate(prompts, sp)
    got_s = _engine(model, params, "static",
                    num_slots=3, max_len=64).generate(prompts, sp)
    assert got_p == want, f"paged != oracle: {got_p} vs {want}"
    assert got_s == want, f"static != oracle: {got_s} vs {want}"


def test_engine_single_long_prompt_spans_blocks(rng):
    """One prompt spanning several blocks decodes identically to the
    dense path (block-table indirection is invisible)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(rng.integers(0, cfg.vocab_size, 19))  # 5 blocks of 4
    want = _oracle_greedy(model, params, prompt, 8)
    eng = _engine(model, params, "paged", num_slots=1, max_len=40)
    assert eng.generate([prompt], SamplingParams(max_tokens=8)) == [want]


def test_engine_xlstm_ragged_prefill(rng):
    """mlstm/slstm prefill is now exact under right padding (gate
    freezing / carry selection hold the recurrent state at the true
    length), so BOTH backends take the bucketed path for xLSTM and must
    still match the unbatched oracle on prompts that are NOT block
    multiples."""
    cfg = get_config("xlstm_1_3b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 7)]       # 7 % block_size(4) != 0
    want = [_oracle_greedy(model, params, p, 4, max_len=32)
            for p in prompts]
    sp = SamplingParams(max_tokens=4)
    eng = _engine(model, params, "paged")
    assert eng.generate(prompts, sp) == want
    assert eng.stats()["bucketed_prefill"]
    got_s = _engine(model, params, "static", num_slots=3).generate(
        prompts, sp)                     # ragged: one right-padded batch
    assert got_s == want


def test_xlstm_bucketed_prefill_compile_cap(rng):
    """Regression for the exact-length fallback that compiled one prefill
    jit per distinct prompt length: xLSTM must now ride the power-of-two
    buckets (mirror of the paged <= 5 compiles test), outputs unchanged."""
    cfg = get_config("xlstm_1_3b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [int(rng.integers(3, 21)) for _ in range(12)]
    assert len(set(lens)) >= 8, "trace not ragged enough"
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in lens]
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=4, block_size=4,
                              num_blocks=129, max_len=64))
    got = eng.generate(prompts, SamplingParams(max_tokens=3))
    st = eng.stats()
    assert st["bucketed_prefill"]
    assert st["prefill_compiles"] <= 5, st
    for i in (0, 5, 11):
        assert got[i] == _oracle_greedy(model, params, prompts[i], 3)


def test_engine_non_pow2_block_size(rng):
    """Bucketed prefill must round pow-2 buckets up to a block multiple
    when block_size itself is not a power of two."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 13)]
    want = [_oracle_greedy(model, params, p, 4) for p in prompts]
    eng = _engine(model, params, "paged", block_size=6, num_blocks=23)
    assert eng.generate(prompts, SamplingParams(max_tokens=4)) == want
    assert eng.stats()["blocks_used"] == 0


# -- 5. scheduler invariants --------------------------------------------


def _run_trace(model, params, work, *, num_slots, num_blocks=33,
               watermark=0):
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=num_slots,
                              block_size=4, num_blocks=num_blocks,
                              max_len=32, watermark_blocks=watermark))
    handles = [eng.add_request(p, SamplingParams(max_tokens=n))
               for p, n in work]
    eng.drain()
    return eng, handles


def test_engine_no_block_leak_and_slot_reuse(rng):
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    work = [(list(rng.integers(0, cfg.vocab_size,
                               int(rng.integers(2, 12)))),
             int(rng.integers(1, 10))) for _ in range(9)]
    eng, handles = _run_trace(model, params, work, num_slots=3)
    be = eng.backend
    # more requests than slots -> retired slots were reused
    assert len(be.finished) == 9
    # every block returned to the free list; allocator saw no double-free
    # (it raises on double-free) and nothing leaked:
    assert be.alloc.used_count == 0
    assert be.alloc.free_count == be.layout.usable_blocks
    assert np.all(be.table == paged_kv.NULL_BLOCK)
    assert np.all(be.lengths == 0)
    for h, (p, n) in zip(handles, work):
        assert h.finished and len(h.token_ids) == n


def test_engine_outputs_independent_of_admission_order(rng):
    """Greedy outputs are a pure function of (params, prompt): shuffling
    submission order and changing slot count must not change any
    request's tokens (no cross-request contamination through the shared
    pool or the null block)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    work = [(list(rng.integers(0, cfg.vocab_size,
                               int(rng.integers(2, 10)))),
             int(rng.integers(2, 8))) for _ in range(6)]
    _, hs_a = _run_trace(model, params, work, num_slots=2)
    order = [3, 0, 5, 1, 4, 2]
    _, hs_b = _run_trace(model, params, [work[i] for i in order],
                         num_slots=4)
    outs_a = {tuple(work[i][0]): hs_a[i].token_ids for i in range(6)}
    for j, i in enumerate(order):
        assert hs_b[j].token_ids == outs_a[tuple(work[i][0])]


def test_optimistic_admission_with_preemption(rng):
    """Acceptance: a trace whose WORST-CASE footprints can never be
    co-resident under PR-1 full reservation (sum exceeds the pool, so
    admission was serialized) runs fully concurrent under optimistic
    admission, survives pool exhaustion via LIFO preemption + recompute,
    finishes with bit-identical greedy outputs and leaks zero blocks
    (allocator returns to all-free)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(3)]
    n_new, bs, num_blocks = 16, 4, 14        # 13 usable blocks
    worst = paged_kv.blocks_for(8 + n_new, bs)
    assert 3 * worst > num_blocks - 1        # full reservation: never 3-up
    # uncontended reference (big pool, no preemption possible)
    ref_eng = Engine(model, params,
                     EngineConfig(backend="paged", num_slots=3,
                                  block_size=bs, num_blocks=65,
                                  max_len=64))
    want = ref_eng.generate(prompts, SamplingParams(max_tokens=n_new))
    assert ref_eng.stats()["preemptions"] == 0

    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=3, block_size=bs,
                              num_blocks=num_blocks, max_len=64))
    handles = [eng.add_request(p, SamplingParams(max_tokens=n_new))
               for p in prompts]
    max_active = 0
    while eng.has_work:
        eng.step()
        max_active = max(max_active, eng.backend.num_active)
    st = eng.stats()
    assert max_active == 3, "optimistic admission never co-admitted all"
    assert st["preemptions"] >= 1, "pool pressure never triggered"
    assert [h.token_ids for h in handles] == want
    assert st["blocks_used"] == 0
    assert eng.backend.alloc.free_count == eng.backend.layout.usable_blocks
    assert np.all(eng.backend.table == paged_kv.NULL_BLOCK)


def test_bucketed_prefill_compile_cap(rng):
    """Acceptance: 32 requests over >= 12 distinct prompt lengths compile
    at most (length buckets) x (batch buckets) prefill entries — lengths
    3..20 under block 4 span 4 pow-2 buckets {4, 8, 16, 32}; batch widths
    with 4 slots span at most {1, 2, 4} — and every output still matches
    the unbatched oracle. Batched admission must also actually batch:
    fewer prefill calls than requests."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [int(rng.integers(3, 21)) for _ in range(32)]
    assert len(set(lens)) >= 12, "trace not ragged enough"
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in lens]
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=4, block_size=4,
                              num_blocks=129, max_len=64))
    got = eng.generate(prompts, SamplingParams(max_tokens=3))
    st = eng.stats()
    assert st["bucketed_prefill"]
    assert st["prefill_compiles"] <= 4 * 3, st
    assert st["prefill_reqs"] == 32, st
    assert st["prefill_calls"] < 32, "admission never batched a prefill"
    # spot-check correctness across buckets (cheap subset)
    for i in (0, 7, 19, 31):
        assert got[i] == _oracle_greedy(model, params, prompts[i], 3)


def test_batched_prefill_admission_one_call(rng):
    """A same-bucket burst into an idle engine prefills as ONE batched
    call (FCFS prefix drain), and the scattered true-length caches are
    exact: outputs match the unbatched oracle per request."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # lengths 5..8 share the pow-2 bucket 8 under block_size 4
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 8, 6, 7)]
    want = [_oracle_greedy(model, params, p, 4) for p in prompts]
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=4, block_size=4,
                              num_blocks=33, max_len=32))
    got = eng.generate(prompts, SamplingParams(max_tokens=4))
    st = eng.stats()
    assert got == want, (got, want)
    assert st["prefill_calls"] == 1, st
    assert st["prefill_reqs"] == 4, st
    assert st["blocks_used"] == 0, st


def test_batched_prefill_respects_max_prefill_batch(rng):
    """The drain cap: max_prefill_batch=2 splits a 4-request same-bucket
    burst into two batched calls; outputs unchanged."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 8, 6, 7)]
    want = [_oracle_greedy(model, params, p, 4) for p in prompts]
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=4, block_size=4,
                              num_blocks=33, max_len=32,
                              max_prefill_batch=2))
    got = eng.generate(prompts, SamplingParams(max_tokens=4))
    st = eng.stats()
    assert got == want
    assert st["prefill_calls"] == 2, st
    assert st["prefill_reqs"] == 4, st


def test_batched_prefill_stops_at_bucket_boundary(rng):
    """FCFS prefix semantics: a queue [8-bucket, 8-bucket, 16-bucket,
    8-bucket] drains as {two 8s} then {16} then {8} — never skipping
    ahead to glue the fourth request onto the first batch."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plens = (6, 8, 12, 5)                 # buckets 8, 8, 16, 8 (block 4)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in plens]
    want = [_oracle_greedy(model, params, p, 3) for p in prompts]
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=4, block_size=4,
                              num_blocks=65, max_len=32))
    got = eng.generate(prompts, SamplingParams(max_tokens=3))
    st = eng.stats()
    assert got == want
    assert st["prefill_calls"] == 3, st
    assert st["prefill_reqs"] == 4, st


def test_engine_queues_when_pool_tight(rng):
    """Pool too small for all requests at once: the engine must finish
    everything via queueing/preemption without corruption or leaks."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    work = [(list(rng.integers(0, cfg.vocab_size, 8)), 8)
            for _ in range(5)]
    eng, handles = _run_trace(model, params, work, num_slots=4,
                              num_blocks=10)
    assert all(len(h.token_ids) == 8 for h in handles)
    assert eng.backend.alloc.used_count == 0


def test_engine_eos_retirement(rng):
    """EOS is stripped, never emitted — whether it arrives straight out
    of prefill (zero tokens) or mid-decode — and retirement frees the
    slot for queued work."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(rng.integers(0, cfg.vocab_size, 7))
    eos = _oracle_greedy(model, params, prompt, 1)[0]
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=1, block_size=4,
                              num_blocks=17, max_len=32, eos_id=eos))
    r1 = eng.add_request(list(prompt), SamplingParams(max_tokens=20))
    r2 = eng.add_request(list(rng.integers(0, cfg.vocab_size, 5)),
                         SamplingParams(max_tokens=3))
    eng.drain()
    assert r1.finished and r1.token_ids == []        # stripped, not emitted
    assert r1.finish_reason == "stop"
    assert r2.finished and len(r2.token_ids) <= 3 and eos not in r2.token_ids
    assert eng.backend.alloc.used_count == 0


def test_allocator_double_free_detected():
    layout = paged_kv.PagedLayout(num_slots=1, num_blocks=4, block_size=4,
                                  max_len=8)
    alloc = paged_kv.BlockAllocator(layout)
    ids = alloc.alloc(2)
    alloc.free(ids)
    with pytest.raises(ValueError):
        alloc.free([ids[0]])
    with pytest.raises(ValueError):
        alloc.free([paged_kv.NULL_BLOCK])
    with pytest.raises(MemoryError):
        alloc.alloc(4)


def test_admission_counts_first_step_growth(rng):
    """Regression: admission must reserve the candidate's OWN first-step
    growth block (the fed token is cached the same step). Without
    blocks_for(cached + 1) a boundary-length request admits, immediately
    self-preempts in _grow_blocks, and wastes a full prefill per step
    until the older sequence retires (observed: 5 thrash preemptions on
    this exact trace)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=2, block_size=8,
                              num_blocks=7, max_len=48))    # 6 usable
    a = eng.add_request(list(rng.integers(0, cfg.vocab_size, 8)),
                        SamplingParams(max_tokens=40))
    for _ in range(27):                  # drive A deep into the pool
        eng.step()
    b_prompt = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    b = eng.add_request(b_prompt, SamplingParams(max_tokens=4))
    eng.drain()
    st = eng.stats()
    assert st["preemptions"] == 0, f"admission thrash: {st}"
    assert st["blocks_used"] == 0
    assert len(a.token_ids) == 40
    assert b.token_ids == _oracle_greedy(model, params, b_prompt, 4,
                                         max_len=48)


def test_engine_rejects_oversized_request(rng):
    """A request whose worst case could never fit the pool even alone is
    a ValueError at add_request, not a mid-flight failure/livelock."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(backend="paged", num_slots=1, block_size=4,
                              num_blocks=5, max_len=256))   # 16 tokens
    with pytest.raises(ValueError):
        eng.add_request(list(rng.integers(0, cfg.vocab_size, 10)),
                        SamplingParams(max_tokens=20))


def test_allocator_watermark_and_victim_selection():
    layout = paged_kv.PagedLayout(num_slots=2, num_blocks=8, block_size=4,
                                  max_len=16)
    alloc = paged_kv.BlockAllocator(layout, watermark=2)   # 7 usable
    assert alloc.can_admit(5, strict=True)
    assert not alloc.can_admit(6, strict=True)      # watermark headroom
    assert alloc.can_admit(7, strict=False)         # sole request bypass
    # LIFO: the latest admission (highest ticket) is evicted first
    assert paged_kv.BlockAllocator.select_victim(
        [(0, 5), (2, 9), (1, 7)]) == 2
    with pytest.raises(ValueError):
        paged_kv.BlockAllocator.select_victim([])
