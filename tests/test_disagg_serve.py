"""Prefill/decode disaggregation: role replicas + KV-block migration.

Contract:

  * DisaggregatedEngine is token-identical to a single Engine AND a
    symmetric ReplicaSet on ragged prompts, greedy and seeded sampling,
    across olmo (pure-attention pools), recurrentgemma (per-slot ring +
    conv state) and xlstm (mlstm/slstm per-slot state, exact-length
    prefill) — the RNG stream position travels in the packet;
  * a MigrationPacket round-trips through one pool bit-exactly (packet
    unit test) and holds NO blocks: export frees the source chain
    eagerly, so cancelling a migration mid-flight leaks nothing;
  * zero block leaks across BOTH pools under decode-side preemption;
  * work-stealing: an idle decode replica pulls a mid-decode slot from
    the busiest one and outputs stay bit-identical;
  * per-replica EngineConfig overrides carry role configs (prefill
    forces spec_tokens=0); migration geometry may not differ per role;
  * TTFT telemetry: per-request stamps aggregate to p50/p95 in stats().

The sharded (submesh) variant lives in tests/test_sharded_serve.py.
"""

import numpy as np
import pytest

import jax
from repro.configs import get_config
from repro.launch.engine import (DisaggregatedEngine, Engine, EngineConfig,
                                 ReplicaSet, SamplingParams)
from repro.launch.engine import transport
from repro.models.model import Model

ARCHS = ("olmo_1b", "recurrentgemma_2b", "xlstm_1_3b")


@pytest.fixture(scope="module")
def smoke():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).smoke()
        model = Model(cfg)
        out[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return out


def _work(cfg, rng, n=6, max_tokens=6):
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12, 5, 9, 14)[:n]]
    sp = [SamplingParams(max_tokens=max_tokens),
          SamplingParams(max_tokens=max_tokens, temperature=0.9, top_k=12,
                         seed=3),
          SamplingParams(max_tokens=max_tokens, temperature=1.0,
                         top_p=0.85, seed=5),
          SamplingParams(max_tokens=max_tokens),
          SamplingParams(max_tokens=max_tokens, temperature=0.7, seed=11),
          SamplingParams(max_tokens=max_tokens)][:n]
    return prompts, sp


def _assert_no_leaks(engine):
    for eng in engine.replicas:
        be = eng.backend
        assert be.alloc.free_count == be.layout.usable_blocks, \
            (be.alloc.free_count, be.layout.usable_blocks)
        be.alloc.check_invariant()


_BASE = dict(backend="paged", num_slots=3, block_size=4, num_blocks=33,
             max_len=48)


@pytest.mark.parametrize("arch", ARCHS)
def test_disagg_token_identical_to_single_engine(smoke, rng, arch):
    """Migration (pool blocks + per-slot recurrent state + RNG stream
    position) is invisible in the tokens: disagg == one engine, greedy
    and seeded, on all three state families; zero leaks in every pool."""
    cfg, model, params = smoke[arch]
    prompts, sp = _work(cfg, rng)
    want = Engine(model, params, EngineConfig(**_BASE)).generate(
        prompts, sp)
    dis = DisaggregatedEngine(model, params, EngineConfig(**_BASE),
                              dp=2, roles=("prefill", "decode"))
    got = dis.generate(prompts, sp)
    assert got == want, (arch, got, want)
    _assert_no_leaks(dis)
    st = dis.stats()["disagg"]
    assert st["exported"] == st["imported"] == len(prompts)
    assert st["packets_inflight"] == 0
    assert st["bytes_moved"] > 0 and st["fabric_s"] >= 0.0


def test_disagg_matches_symmetric_replicaset(smoke, rng):
    """Same trace through a symmetric dp=2 ReplicaSet and a dp=2
    disaggregated set: bit-identical streams (the acceptance-criteria
    comparison the bench gates on)."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng)
    sym = ReplicaSet(model, params, EngineConfig(**_BASE), dp=2)
    dis = DisaggregatedEngine(model, params, EngineConfig(**_BASE),
                              dp=2, roles="auto")
    assert dis.roles == ("prefill", "decode")
    got_s = sym.generate(prompts, sp)
    got_d = dis.generate(prompts, sp)
    assert got_d == got_s
    _assert_no_leaks(sym)
    _assert_no_leaks(dis)


def test_packet_roundtrip_unit(smoke, rng):
    """Unit: export a live slot to a MigrationPacket (source chain freed
    eagerly), re-import into the SAME pool, and the request finishes
    with exactly the tokens of an unmigrated run."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng, n=2)
    want = Engine(model, params, EngineConfig(**_BASE)).generate(
        prompts, sp)
    eng = Engine(model, params, EngineConfig(**_BASE))
    handles = [eng.add_request(p, s) for p, s in zip(prompts, sp)]
    eng.step()                          # admit + prefill + first decode
    be = eng.backend
    used_before = be.alloc.used_count
    assert used_before > 0
    i = next(j for j, s in enumerate(be.slots)
             if s.req is handles[0])
    pkt = transport.extract_slot(be, i, src=0)
    assert pkt.req is handles[0]
    assert pkt.n_blocks > 0 and pkt.payload_bytes > 0
    # eager free: the packet holds no blocks in the source pool
    assert be.alloc.used_count < used_before
    assert be.slots[i].req is None
    assert transport.can_import(be, pkt)
    j = transport.insert_packet(be, pkt)
    assert be.slots[j].req is handles[0]
    assert int(be.lengths[j]) == pkt.length
    eng.drain()
    assert [h.token_ids for h in handles] == want
    assert be.alloc.free_count == be.layout.usable_blocks


def test_mid_migration_cancel_leaks_nothing(smoke, rng):
    """Packets dropped between export and import (cancellation,
    shutdown) leave BOTH pools fully free — the export already returned
    the source blocks and no destination block was ever allocated."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng, n=3)
    dis = DisaggregatedEngine(model, params, EngineConfig(**_BASE),
                              dp=2, roles=("prefill", "decode"))
    for p, s in zip(prompts, sp):
        dis.add_request(p, s)
    dis._import_packets = lambda: 0     # park every packet in flight
    while dis.queue or any(dis.replicas[r].has_work
                           for r in dis.prefill_ids):
        dis.step()
    assert len(dis.packets) == len(prompts)
    # simulate cancel: drop every in-flight packet on the floor
    for pkt in dis.packets:
        pkt.req.finished = True
        dis._by_uid.pop(pkt.req.uid, None)
    dis.packets.clear()
    assert not dis.has_work
    _assert_no_leaks(dis)


def test_decode_side_preemption_no_leaks(smoke, rng):
    """A decode replica pool too small for its imports preempts LIFO and
    re-prefills locally; outputs stay bit-identical to an uncontended
    single engine and both pools return to all-free."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng, n=4, max_tokens=12)
    big = dict(_BASE, max_len=64, num_blocks=65)
    want = Engine(model, params, EngineConfig(**big)).generate(
        prompts, sp)
    dis = DisaggregatedEngine(
        model, params, EngineConfig(**big), dp=2,
        roles=("prefill", "decode"),
        # starve ONLY the decode pool so imports collide mid-decode
        role_overrides={"decode": {"num_blocks": 12}})
    got = dis.generate(prompts, sp)
    assert got == want
    preempts = sum(e.stats()["preemptions"]
                   for e in [dis.replicas[r] for r in dis.decode_ids])
    assert preempts >= 1
    _assert_no_leaks(dis)


def test_work_stealing_fairness(smoke, rng):
    """Pin imports to ONE decode replica; the idle one must steal the
    donor's newest-ticket slot (donor keeps its oldest admission) and
    every output still matches the single engine bit-exactly."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng, n=6, max_tokens=10)
    big = dict(_BASE, max_len=64, num_blocks=65)
    want = Engine(model, params, EngineConfig(**big)).generate(
        prompts, sp)
    dis = DisaggregatedEngine(
        model, params, EngineConfig(**big), dp=3,
        roles=("prefill", "decode", "decode"),
        policy=lambda rset, cands: cands[0])   # pile onto replica 1
    got = dis.generate(prompts, sp)
    assert got == want
    st = dis.stats()["disagg"]
    assert st["stolen"] >= 1, st
    # a steal re-exports from a decode replica, so it counts as an
    # extra import but not a prefill-side export
    assert st["imported"] == st["exported"] + st["stolen"]
    _assert_no_leaks(dis)


def test_steal_keeps_donor_oldest(smoke, rng):
    """Directly pin the steal victim: with two slots mid-decode on one
    donor, ``_steal`` moves the NEWER ticket to the idle replica — the
    oldest admission never migrates away, preserving no-livelock — and
    the mid-decode migration is token-invisible."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng, n=2, max_tokens=12)
    want = Engine(model, params, EngineConfig(**_BASE)).generate(
        prompts, sp)
    dis = DisaggregatedEngine(model, params, EngineConfig(**_BASE),
                              dp=3, roles=("prefill", "decode", "decode"))
    donor_eng = dis.replicas[dis.decode_ids[0]]
    thief_be = dis.replicas[dis.decode_ids[1]].backend
    handles = [donor_eng.add_request(p, s) for p, s in zip(prompts, sp)]
    donor_eng.step()                    # admit + prefill both mid-decode
    dbe = donor_eng.backend
    assert dbe.num_active == 2
    by_ticket = sorted(((s.ticket, i) for i, s in enumerate(dbe.slots)
                        if s.req is not None))
    oldest_req = dbe.slots[by_ticket[0][1]].req
    newest_req = dbe.slots[by_ticket[-1][1]].req
    assert dis._steal() == 1 and dis.stolen == 1
    assert any(s.req is oldest_req for s in dbe.slots), \
        "steal uprooted the donor's oldest admission"
    assert any(s.req is newest_req for s in thief_be.slots)
    # requests were injected engine-side, so finish them engine-side
    while any(e.has_work for e in dis.replicas):
        for e in dis.replicas:
            if e.has_work:
                e.step()
    assert [h.token_ids for h in handles] == want
    _assert_no_leaks(dis)


def test_prefix_hit_migrates_full_hit_rewind(smoke, rng):
    """A full-prefix hit on a prefill replica has nothing sampled yet
    (lengths = S - 1, stream position 0): migration must carry that
    rewind so the decode replica samples token 0 at position 0 —
    bit-identical to the unmigrated prefix-cache engine."""
    cfg, model, params = smoke["olmo_1b"]
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    sp = [SamplingParams(max_tokens=5, temperature=0.8, seed=7)] * 2
    base = dict(_BASE, prefix_cache=True)
    want = Engine(model, params, EngineConfig(**base)).generate(
        [prompt, prompt], sp)
    dis = DisaggregatedEngine(model, params, EngineConfig(**base),
                              dp=2, roles=("prefill", "decode"))
    h0 = dis.add_request(prompt, sp[0])
    while not h0.finished:
        dis.step()
    h1 = dis.add_request(prompt, sp[1])     # full hit on the prefill pool
    dis.drain()
    assert [h0.token_ids, h1.token_ids] == want
    pre = dis.replicas[dis.prefill_ids[0]].stats()["prefix_cache"]
    assert pre["hits"] >= 1, pre
    _assert_no_leaks(dis)


def test_role_overrides_and_validation(smoke):
    """Per-replica overrides: prefill forces spec_tokens=0 while decode
    keeps its drafter; migration geometry and role names validate."""
    cfg, model, params = smoke["olmo_1b"]
    base = EngineConfig(**dict(_BASE, spec_tokens=2))
    dis = DisaggregatedEngine(model, params, base, dp=2,
                              roles=("prefill", "decode"))
    assert dis.replicas[0].cfg.spec_tokens == 0
    assert dis.replicas[1].cfg.spec_tokens == 2
    assert dis.replicas[0].backend.prefill_only
    assert not dis.replicas[1].backend.prefill_only
    with pytest.raises(ValueError, match="per role"):
        DisaggregatedEngine(model, params, EngineConfig(**_BASE), dp=2,
                            roles=("prefill", "decode"),
                            role_overrides={"decode": {"block_size": 8}})
    with pytest.raises(ValueError, match="unknown role"):
        DisaggregatedEngine(model, params, EngineConfig(**_BASE), dp=2,
                            roles=("prefill", "verify"))
    with pytest.raises(ValueError, match="one replica per role"):
        DisaggregatedEngine(model, params, EngineConfig(**_BASE), dp=2,
                            roles=("decode", "decode"))
    with pytest.raises(ValueError, match="dp >= 2"):
        DisaggregatedEngine(model, params, EngineConfig(**_BASE), dp=1,
                            roles="auto")
    with pytest.raises(ValueError, match="paged"):
        DisaggregatedEngine(model, params,
                            EngineConfig(backend="static"), dp=2)


def test_replicaset_overrides_validation(smoke, rng):
    """The generic ReplicaSet overrides: per-replica fields apply, the
    mesh/eos_id escape hatches are rejected, and validation runs
    against EVERY replica when configs differ."""
    cfg, model, params = smoke["olmo_1b"]
    rs = ReplicaSet(model, params, EngineConfig(**_BASE), dp=2,
                    overrides=[None, {"num_slots": 2}])
    assert rs.replicas[0].cfg.num_slots == 3
    assert rs.replicas[1].cfg.num_slots == 2
    assert rs.total_slots == 5
    with pytest.raises(ValueError, match="cannot change"):
        ReplicaSet(model, params, EngineConfig(**_BASE), dp=2,
                   overrides=[None, {"eos_id": 5}])
    with pytest.raises(ValueError, match="overrides for"):
        ReplicaSet(model, params, EngineConfig(**_BASE), dp=2,
                   overrides=[{}])
    # the smaller replica's max_len bounds every request
    small = ReplicaSet(model, params, EngineConfig(**_BASE), dp=2,
                       overrides=[None, {"max_len": 8}])
    with pytest.raises(ValueError, match="max_len"):
        small.add_request(list(range(1, 7)),
                          SamplingParams(max_tokens=8))


def test_ttft_telemetry(smoke, rng):
    """Every finished request carries submit/first-token stamps and
    stats() aggregates them into a p50 <= p95 distribution, on both
    front-ends."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng)
    for eng in (ReplicaSet(model, params, EngineConfig(**_BASE), dp=2),
                DisaggregatedEngine(model, params, EngineConfig(**_BASE),
                                    dp=2, roles="auto")):
        eng.generate(prompts, sp)
        for h in eng.finished:
            assert h.t_first_token is not None
            assert h.t_first_token >= h.t_submit
        tt = eng.stats()["ttft"]
        assert tt["count"] == len(prompts)
        assert 0.0 <= tt["p50_s"] <= tt["p95_s"]
        eng.reset_telemetry()
        assert eng.stats()["ttft"]["count"] == 0


def test_backpressure_bounds_inflight_packets(smoke, rng):
    """max_inflight=1 pauses fresh dispatch while a packet waits; the
    trace still completes bit-identically (head-blocking import can
    always land on an eventually-idle decode replica)."""
    cfg, model, params = smoke["olmo_1b"]
    prompts, sp = _work(cfg, rng)
    want = Engine(model, params, EngineConfig(**_BASE)).generate(
        prompts, sp)
    dis = DisaggregatedEngine(model, params, EngineConfig(**_BASE),
                              dp=2, roles=("prefill", "decode"),
                              max_inflight=1)
    got = dis.generate(prompts, sp)
    assert got == want
    assert dis._dispatch_candidates() == dis.prefill_ids
    dis.packets.append(object())            # fake backlog
    assert dis._dispatch_candidates() == []
    dis.packets.clear()
    _assert_no_leaks(dis)
