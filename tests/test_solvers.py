"""VRP Krylov solvers: the paper's convergence claims, numerically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solvers, vrp
from repro.core.precision import F64, VP128, VP256


def test_cg_well_conditioned_all_precisions():
    A = solvers.hilbert_like(32, cond=1e3, seed=0)
    x_star = jnp.ones(32)
    b = A @ x_star
    for env in (F64, VP128):
        res = solvers.cg(A, b, env, tol=1e-10, maxiter=200)
        assert bool(res.converged)
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_star),
                                   rtol=1e-6)


def test_cg_extended_precision_converges_faster():
    """Paper claim (§3.3, refs [19][20]): higher precision improves CG
    convergence on ill-conditioned systems."""
    A = solvers.hilbert(12)
    b = A @ jnp.ones(12)
    r64 = solvers.cg(A, b, F64, tol=1e-13, maxiter=400)
    r128 = solvers.cg(A, b, VP128, tol=1e-13, maxiter=400)
    assert bool(r128.converged)
    assert int(r128.iterations) <= int(r64.iterations)


def test_cg_extended_rhs_improves_solution():
    """With the RHS in extended precision, CG converges in fewer
    iterations and to a better solution than f64 (measured effect ~2x on
    x-error at cond 1e6; the paper's "improves convergence" claim).

    Design note (recorded in EXPERIMENTS.md): at cond >= 1e12 ALL
    precisions stall identically — the Chebyshev rate, not rounding,
    limits convergence; precision buys attainable accuracy and iteration
    count at moderate conditioning, which is what this asserts.
    """
    n = 24
    A = solvers.hilbert_like(n, cond=1e6, seed=1)
    env = VP256
    x_star = vrp.from_float(jnp.ones(n), env)
    bE = vrp.tree_sum(vrp.mul(vrp.from_float(A, env),
                              x_star[None], env), env, axis=1)
    r64 = solvers.cg(A, vrp.to_float(bE), F64, tol=1e-24, maxiter=600)
    rvp = solvers.cg(A, bE[:, :2], VP128, tol=1e-24, maxiter=600)
    assert bool(rvp.converged)
    assert int(rvp.iterations) <= int(r64.iterations)
    err64 = float(jnp.max(jnp.abs(r64.x - 1.0)))
    errvp = float(jnp.max(jnp.abs(rvp.x - 1.0)))
    assert errvp <= err64 * 1.2


def test_pcg_jacobi():
    A = solvers.hilbert_like(24, cond=1e6, seed=3)
    b = A @ jnp.ones(24)
    res = solvers.pcg(A, b, VP128, tol=1e-11, maxiter=300)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.ones(24), rtol=1e-6)


def test_bicgstab():
    rng = np.random.default_rng(4)
    n = 24
    A = jnp.asarray(np.eye(n) * 4 + rng.normal(size=(n, n)) * 0.3)
    x_star = jnp.asarray(rng.normal(size=n))
    b = A @ x_star
    res = solvers.bicgstab(A, b, VP128, tol=1e-11, maxiter=200)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_star),
                               rtol=1e-7, atol=1e-8)


def test_runtime_precision_no_recompile_of_user_code():
    """Env-register semantics: same solver call site, K chosen at runtime."""
    A = solvers.hilbert_like(16, cond=1e4, seed=1)
    b = A @ jnp.ones(16)
    iters = {}
    for env in (F64, VP128, VP256):
        res = solvers.cg(A, b, env, tol=1e-10, maxiter=300)
        iters[env.K] = int(res.iterations)
        assert bool(res.converged)
    assert iters[2] <= iters[1] + 5  # more precision never much worse
