"""Multi-device distribution correctness (8 fake CPU devices, subprocess).

The suite's default process must keep 1 device (smoke-test contract), so
these tests re-exec python with XLA_FLAGS set. Inside, they verify:
  * MoE sharded (shard_map EP) == local math,
  * vocab-parallel embedding lookup == plain take,
  * a sharded train step == single-device train step,
  * dry-run cell build lowers+compiles on a (pod, data, model) mesh.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    code = textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_moe_sharded_matches_local():
    _run("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import moe
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import make_shard_ctx
    cfg = dataclasses.replace(get_config("qwen3_moe_30b_a3b").smoke(),
                              moe_capacity_factor=8.0)
    mesh = make_mesh((2, 4), ("data", "model"))
    shard = make_shard_ctx(mesh)
    rng = np.random.default_rng(0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)), jnp.float32)
    local, aux_l = moe.apply_moe(params, cfg, x)
    with mesh:
        sharded, aux_s = jax.jit(
            lambda p, xx: moe.apply_moe_sharded(p, cfg, xx, shard))(params, x)
    np.testing.assert_allclose(np.asarray(local), np.asarray(sharded),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_l), float(aux_s), rtol=1e-4)
    print("moe ok")
    """)


def test_vocab_parallel_lookup_matches_take():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.layers import vocab_parallel_lookup
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import make_shard_ctx
    mesh = make_mesh((2, 4), ("data", "model"))
    shard = make_shard_ctx(mesh)
    rng = np.random.default_rng(1)
    V, d = 64, 16
    table = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, V, (4, 10)), jnp.int32)
    with mesh:
        out = jax.jit(lambda t, i: vocab_parallel_lookup(t, i, shard))(
            table, toks)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.take(table, toks, axis=0)),
                               rtol=1e-6)
    print("lookup ok")
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    _run("""
    import functools, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch import sharding as shlib
    from repro.launch.mesh import make_mesh
    from repro.launch.train import init_state, make_train_step, state_specs
    from repro.models.model import Model
    from repro.models.transformer import RunCtx
    from repro.optim import OptConfig
    from repro.optim.schedule import constant
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    opt_cfg = OptConfig(weight_decay=0.0)
    lr = functools.partial(constant, peak_lr=1e-2)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)))}
    # single device
    step0 = make_train_step(model, opt_cfg, RunCtx(kernel_mode="ref"), lr)
    s0 = init_state(model, opt_cfg)
    n0, m0 = jax.jit(step0)(s0, batch)
    # 8-device (2 dp x 4 tp) mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    shard = shlib.make_shard_ctx(mesh)
    ctx = RunCtx(kernel_mode="ref", shard=shard)
    step1 = make_train_step(model, opt_cfg, ctx, lr)
    s1 = init_state(model, opt_cfg)
    shapes = jax.eval_shape(lambda: init_state(model, opt_cfg))
    sspec = shlib.named(mesh, state_specs(shapes, shard))
    bspec = shlib.named(mesh, shlib.batch_specs(batch, shard))
    with mesh:
        s1 = jax.device_put(s1, sspec)
        n1, m1 = jax.jit(step1, in_shardings=(sspec, bspec))(s1, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(n0["params"]),
                    jax.tree.leaves(n1["params"])):
        # atol covers Adam's rsqrt amplification of cross-device psum
        # reduction-order noise on near-zero gradients (single elements).
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=2e-3)
    print("train step ok")
    """)


def test_dryrun_cell_lowers_on_multipod_mesh():
    _run("""
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.core.compat import cost_analysis
    from repro.launch.dryrun import build_lowerable
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("recurrentgemma_2b").smoke()
    for cell in (ShapeCell("t", "train", 64, 8),
                 ShapeCell("d", "decode", 64, 8)):
        with mesh:
            fn, args = build_lowerable(cfg, cell, mesh)
            compiled = fn.lower(*args).compile()
            assert cost_analysis(compiled)["flops"] > 0
    print("dryrun lowering ok")
    """)


def test_compressed_psum_shard_map():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map
    from repro.launch.mesh import make_mesh
    from repro.optim.grad_compression import compressed_psum
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)

    def local(gl, res):
        total, new_res, n = compressed_psum(gl[0], res[0], "data")
        return (total / n)[None], new_res[None]

    with mesh:
        mean, _ = jax.jit(shard_map(
            local, mesh=mesh, in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None))))(
                g, jnp.zeros_like(g))
    got = np.asarray(mean)[0]
    want = np.asarray(jnp.mean(g, 0))
    np.testing.assert_allclose(got, want, atol=0.05)
    print("compressed psum ok")
    """)


def test_flash_decoding_matches_baseline_decode():
    """The §Perf decode winner (seq-sharded cache + LSE combine) must be
    numerically exact vs the replicated-cache baseline."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import attention as attn_lib
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import make_shard_ctx
    cfg = get_config("yi_6b").smoke()      # GQA kv=2, heads=4
    mesh = make_mesh((2, 4), ("data", "model"))
    shard = make_shard_ctx(mesh, cache_seq_shard=True)
    rng = np.random.default_rng(0)
    params = attn_lib.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 4, 16
    cache = attn_lib.init_kv_cache(cfg, B, S, jnp.float32)
    # pre-populate a few positions
    for t in range(5):
        xt = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
        _, cache = attn_lib.decode_attend(params, cfg, xt, cache,
                                          jnp.int32(t))
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    base_out, base_cache = attn_lib.decode_attend(params, cfg, x, cache,
                                                  jnp.int32(5))
    with mesh:
        fd_out, fd_cache = jax.jit(
            lambda p, xx, c: attn_lib.decode_attend_seqshard(
                p, cfg, xx, c, jnp.int32(5), shard))(params, x, cache)
    np.testing.assert_allclose(np.asarray(fd_out), np.asarray(base_out),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(fd_cache["k"]),
                               np.asarray(base_cache["k"]), rtol=1e-5)
    print("flash decoding ok")
    """)
