"""Pipeline parallelism (GPipe over a pipe axis) + STX cluster executor."""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.core.stx import DEFAULT_CLUSTER, StxCluster
from repro.kernels import ref

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_stx_cluster_paper_model():
    c = StxCluster()
    assert c.peak_gflops == 64.0          # §3.2: 4 x 8 x 2 FLOP @ 1 GHz
    bm, bn, bk = c.matmul_blocks()
    # working set fits 4x the per-cluster TCDM (VMEM is ~16 MB vs 256 kB)
    assert c.working_set_kb(bm, bn, bk) <= c.tcdm_kb * 4


def test_stx_cluster_dispatch(rng):
    x = jnp.asarray(rng.normal(size=(48, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 40)), jnp.float32)
    out = DEFAULT_CLUSTER.matmul(x, w, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(x, w)),
                               rtol=1e-5, atol=1e-4)
    g = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    w5 = ref.five_point_weights()
    out = DEFAULT_CLUSTER.stencil2d(g, w5, mode="ref")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.stencil2d(g, w5)), rtol=1e-5)


def test_pipeline_matches_sequential():
    """4-stage GPipe over 4 fake devices == sequential layer application."""
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.launch.pipeline import make_stage_fn, pipeline_apply, stack_stages

    mesh = make_mesh((4,), ("pipe",))
    rng = np.random.default_rng(0)
    L, d = 8, 16
    layers = [{"w": jnp.asarray(rng.normal(size=(d, d)) * 0.2, jnp.float32),
               "b": jnp.asarray(rng.normal(size=(d,)) * 0.1, jnp.float32)}
              for _ in range(L)]

    def layer_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    M, B = 6, 4
    x = jnp.asarray(rng.normal(size=(M, B, d)), jnp.float32)
    # sequential reference
    ref_out = x
    for p in layers:
        ref_out = layer_fn(p, ref_out)
    # pipelined
    stages = stack_stages(layers, 4)
    with mesh:
        out = jax.jit(lambda sp, xx: pipeline_apply(
            make_stage_fn(layer_fn), sp, xx, mesh))(stages, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-6)
    print("pipeline ok")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(_ROOT, "src"), JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
