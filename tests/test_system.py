"""End-to-end behaviour tests for the EPAC-JAX system.

The paper's bring-up validation sequence, translated: register access ->
(config registry), SRAM patterns -> (checkpoint roundtrip elsewhere),
inter-tile connectivity -> (tile dispatch agreement), vectorized DGEMM /
Stream -> (kernels vs oracles), booting workloads -> (LM train loop
learns; serve generates)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.core import solvers
from repro.core.precision import F64, VP128
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.launch.train import TrainLoopConfig, train_loop
from repro.models.model import Model, input_specs
from repro.models.transformer import RunCtx
from repro.optim import OptConfig


def test_all_archs_registered():
    cfgs = all_configs()
    assert len(cfgs) == 10
    for cfg in cfgs.values():
        assert cfg.n_layers > 0 and cfg.vocab_size > 1000


def test_input_specs_cover_all_cells():
    """Every non-skipped (arch x cell) has well-formed input specs."""
    from repro.configs import LM_SHAPES

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in LM_SHAPES:
            if cell.name == "long_500k" and not cfg.sub_quadratic:
                continue
            specs = input_specs(cfg, cell)
            assert "tokens" in specs
            if cell.kind == "decode":
                assert "cache" in specs and "pos" in specs
                assert specs["tokens"].shape == (cell.global_batch, 1)
            else:
                assert specs["tokens"].shape == (cell.global_batch,
                                                 cell.seq_len)


@pytest.mark.slow
def test_lm_learns_synthetic_structure(tmp_path):
    """The system trains: loss on learnable synthetic data drops."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    loop_cfg = TrainLoopConfig(steps=40, ckpt_every=100,
                               ckpt_dir=str(tmp_path), log_every=1000)
    _, hist = train_loop(model, OptConfig(weight_decay=0.0),
                         RunCtx(kernel_mode="ref"),
                         DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4),
                         loop_cfg)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_server_generates(rng):
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(backend="static", num_slots=2, max_len=64))
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8]]
    outs = eng.generate(prompts, SamplingParams(max_tokens=8))
    assert len(outs) == 2 and all(len(o) == 8 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_precision_rescues_ill_conditioned_solve():
    """The paper's VRP story end-to-end inside the same process."""
    A = solvers.hilbert(12)
    b = A @ jnp.ones(12)
    r64 = solvers.cg(A, b, F64, tol=1e-13, maxiter=400)
    r128 = solvers.cg(A, b, VP128, tol=1e-13, maxiter=400)
    assert bool(r128.converged)
    assert int(r128.iterations) <= int(r64.iterations)


def test_roofline_collective_parser_on_synthetic_hlo():
    from repro.roofline.analysis import parse_collectives

    hlo = """
  %ar = f32[1024,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], channel_id=1
  %ag = bf16[64,4096]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    st = parse_collectives(hlo, pod_size=None, n_devices=256)
    assert st.ops == {"all-reduce": 1, "all-gather": 1,
                      "collective-permute": 1}
    ar_bytes = 1024 * 256 * 4
    assert abs(st.wire_bytes["all-reduce"] - 2 * 15 / 16 * ar_bytes) < 1
    ag_res = 64 * 4096 * 2
    assert abs(st.wire_bytes["all-gather"] - 3 / 4 * ag_res) < 1
    assert st.wire_bytes["collective-permute"] == 128 * 4


def test_roofline_pod_attribution_iota_groups():
    from repro.roofline.analysis import parse_collectives

    # group spans the pod boundary (ids 0 and 256 with pod_size=256)
    hlo = "%ar = f32[256]{0} all-reduce(%x), replica_groups=[256,2]<=[2,256]T(1,0)"
    st = parse_collectives(hlo, pod_size=256, n_devices=512)
    assert st.pod_wire_bytes > 0
    # intra-pod groups -> no pod traffic
    hlo2 = "%ar = f32[256]{0} all-reduce(%x), replica_groups=[32,16]<=[512]"
    st2 = parse_collectives(hlo2, pod_size=256, n_devices=512)
    assert st2.pod_wire_bytes == 0


def test_roofline_terms_shape():
    from repro.roofline.analysis import CollectiveStats, roofline_terms

    coll = CollectiveStats(ops={}, operand_bytes={}, wire_bytes={},
                           pod_wire_bytes=0.0, total_operand_bytes=0.0,
                           total_wire_bytes=5e9)
    t = roofline_terms(1e12, 1e10, coll)
    assert t["dominant"] == "collective_s"
    assert 0 < t["roofline_fraction"] <= 1.0
