"""Optimizers: convergence, Kahan-compensated bf16 (the VRP training
claim), adafactor memory shapes, clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptConfig, apply_updates, clip_by_global_norm,
                         global_norm, init_opt_state)
from repro.optim.schedule import warmup_cosine


def _quadratic_run(opt_cfg, steps=60, dtype=jnp.float32, lr=0.1, dim=16):
    """Minimize ||x - t||^2; returns final params."""
    t = jnp.arange(dim, dtype=jnp.float32) / dim
    params = {"x": jnp.zeros((dim,), dtype)}
    state = init_opt_state(params, opt_cfg)
    for _ in range(steps):
        grads = {"x": (params["x"].astype(jnp.float32) - t).astype(dtype)}
        params, state, _ = apply_updates(params, grads, state, opt_cfg, lr)
    return params["x"].astype(jnp.float32), t


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_converges(kind):
    cfg = OptConfig(kind=kind, weight_decay=0.0)
    x, t = _quadratic_run(cfg)
    assert float(jnp.mean(jnp.abs(x - t))) < 0.05


def test_kahan_bf16_tracks_f32_master():
    """VRP claim for training: compensated bf16 accumulation recovers the
    f32-master trajectory where plain bf16 stalls on tiny updates."""
    cfg_f32 = OptConfig(weight_decay=0.0)
    cfg_bf16 = OptConfig(weight_decay=0.0, kahan=False)
    cfg_kahan = OptConfig(weight_decay=0.0, kahan=True)
    # small lr -> updates below bf16 ulp of the params
    xf, t = _quadratic_run(cfg_f32, steps=400, lr=3e-3)
    xb, _ = _quadratic_run(cfg_bf16, steps=400, lr=3e-3, dtype=jnp.bfloat16)
    xk, _ = _quadratic_run(cfg_kahan, steps=400, lr=3e-3, dtype=jnp.bfloat16)
    err_b = float(jnp.mean(jnp.abs(xb - xf)))
    err_k = float(jnp.mean(jnp.abs(xk - xf)))
    assert err_k < err_b / 2, (err_k, err_b)


def test_global_norm_vrp_tile_matches_vec():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=128), jnp.float32)}
    nv = float(global_norm(tree, "vec"))
    nr = float(global_norm(tree, "vrp"))
    assert abs(nv - nr) / nv < 1e-5


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 100
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-3


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((128, 256)), "b": jnp.zeros((256,))}
    st = init_opt_state(params, OptConfig(kind="adafactor"))
    assert st["fac"]["w"]["row"].shape == (128,)
    assert st["fac"]["w"]["col"].shape == (256,)
    assert st["fac"]["b"]["v"].shape == (256,)


def test_warmup_cosine_schedule():
    import numpy as np
    s = warmup_cosine(jnp.arange(100), peak_lr=1.0, warmup_steps=10,
                      total_steps=100)
    s = np.asarray(s)
    assert s[0] == 0.0 and abs(s[10] - 1.0) < 0.11
    assert s[99] < 0.2 and (np.diff(s[:10]) > 0).all()
