"""int8 gradient compression with error feedback (pod-axis traffic)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.grad_compression import (compress_residual, dequantize_int8,
                                          quantize_int8)


def test_quantize_bounds(rng):
    x = jnp.asarray(rng.normal(size=512) * 7, jnp.float32)
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_unbiased_over_time(rng):
    """With error feedback the long-run average of compressed grads
    converges to the true gradient (compression error doesn't bias)."""
    g = jnp.asarray(rng.normal(size=256), jnp.float32)
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    T = 200
    for _ in range(T):
        q, scale, residual = compress_residual(g, residual)
        acc = acc + dequantize_int8(q, scale)
    mean = np.asarray(acc / T)
    np.testing.assert_allclose(mean, np.asarray(g), atol=5e-3)


def test_error_feedback_sgd_converges(rng):
    """SGD with int8-compressed grads + error feedback still converges."""
    t = jnp.asarray(rng.normal(size=64), jnp.float32)
    x = jnp.zeros(64)
    residual = jnp.zeros(64)
    for _ in range(300):
        g = x - t
        q, scale, residual = compress_residual(g, residual)
        x = x - 0.1 * dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(x - t))) < 1e-2
