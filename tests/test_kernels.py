"""Per-kernel interpret-mode validation vs the pure-jnp oracles,
sweeping shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


def _randn(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("m,k,n", [(32, 16, 32), (70, 50, 130), (128, 128, 128),
                                   (1, 7, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stx_matmul(rng, m, k, n, dtype):
    x = _randn(rng, (m, k), dtype)
    w = _randn(rng, (k, n), dtype)
    out = ops.stx_matmul(x, w, block_m=32, block_n=64, block_k=16,
                         mode="interpret")
    want = ref.matmul(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_stx_matmul_batched_lead(rng):
    x = _randn(rng, (3, 5, 40), jnp.float32)
    w = _randn(rng, (40, 24), jnp.float32)
    out = ops.stx_matmul(x, w, block_m=16, block_n=16, block_k=16,
                         mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(x, w)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape", [(64, 64), (65, 70), (128, 33)])
@pytest.mark.parametrize("weights_fn", [ref.five_point_weights,
                                        lambda: jnp.ones((3, 3), jnp.float32)])
def test_stencil2d(rng, shape, weights_fn):
    x = _randn(rng, shape, jnp.float32)
    w = weights_fn()
    out = ops.stencil2d(x, w, block_m=32, block_n=32, mode="interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.stencil2d(x, w)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 16, 32), (9, 20, 33)])
def test_stencil3d_seven_point(rng, shape):
    x = _randn(rng, shape, jnp.float32)
    w = ref.seven_point_weights()
    out = ops.stencil3d(x, w, block_d=4, block_m=8, block_n=16,
                        mode="interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.stencil3d(x, w)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
def test_flash_attention(rng, hq, hkv, causal, window):
    B, S, D = 2, 80, 32
    q = _randn(rng, (B, hq, S, D), jnp.float32)
    k = _randn(rng, (B, hkv, S, D), jnp.float32)
    v = _randn(rng, (B, hkv, S, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_k=32, mode="interpret")
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_bf16(rng):
    B, H, S, D = 1, 2, 64, 64
    q = _randn(rng, (B, H, S, D), jnp.bfloat16)
    k = _randn(rng, (B, H, S, D), jnp.bfloat16)
    v = _randn(rng, (B, H, S, D), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32,
                              mode="interpret")
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_vrp_dot_beats_naive(rng):
    n = 3000
    x = jnp.asarray(rng.normal(size=n) * 1e4, jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    exact = float(np.dot(np.asarray(x, np.float64), np.asarray(y, np.float64)))
    naive_err = abs(float(jnp.dot(x, y)) - exact)
    d = ops.vrp_dot(x, y, mode="interpret")
    got = float(d[0]) + float(d[1])
    assert abs(got - exact) < max(naive_err / 100, 1e-8)


def test_vrp_sum_matches_ref(rng):
    x = jnp.asarray(rng.normal(size=2048) * 1e6, jnp.float32)
    kern = ops.vrp_sum(x, mode="interpret")
    oracle = ops.vrp_sum(x, mode="ref")
    exact = float(np.sum(np.asarray(x, np.float64)))
    assert abs(float(kern[0]) + float(kern[1]) - exact) <= \
        abs(float(oracle[0]) + float(oracle[1]) - exact) * 10 + 1e-6


@pytest.mark.parametrize("B,T,D", [(3, 100, 40), (2, 64, 128), (1, 17, 5)])
def test_rglru_scan(rng, B, T, D):
    a = jnp.asarray(0.8 + 0.2 * rng.random((B, T, D)), jnp.float32)
    x = _randn(rng, (B, T, D), jnp.float32)
    out = ops.rglru_scan(a, x, block_b=2, block_t=16, block_d=16,
                         mode="interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.linear_scan(a, x)),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_with_state(rng):
    B, T, D = 2, 32, 16
    a = jnp.asarray(0.9 * rng.random((B, T, D)), jnp.float32)
    x = _randn(rng, (B, T, D), jnp.float32)
    h0 = _randn(rng, (B, D), jnp.float32)
    out = ops.rglru_scan(a, x, h0, block_b=2, block_t=8, block_d=8,
                         mode="interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.linear_scan(a, x, h0)),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 200), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_property_matmul_any_shape(m, n):
    """VLA property: any (m, k) x (k, n) works via masked padding."""
    rng = np.random.default_rng(m * 1000 + n)
    x = jnp.asarray(rng.normal(size=(m, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, n)), jnp.float32)
    out = ops.stx_matmul(x, w, block_m=32, block_n=32, block_k=8,
                         mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(x, w)),
                               rtol=1e-4, atol=1e-4)
