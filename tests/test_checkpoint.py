"""Checkpointing: atomicity, async, keep-k, restore/reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                       "blocks": {"b0": jnp.arange(6).reshape(2, 3)}},
            "opt": {"step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = _tree()
    mgr.save(10, tree, metadata={"step": 10})
    got, meta = mgr.restore(template=jax.eval_shape(lambda: tree))
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    mgr.wait()
    assert mgr.latest_step() == 2


def test_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(5, _tree())
    entries = os.listdir(tmp_path)
    assert entries == ["step_5"]
    assert not any(e.startswith("tmp.") for e in entries)


def test_restore_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        mgr.restore(template={"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_restore_onto_shardings_single_device(tmp_path):
    """Elastic contract: restore() accepts shardings (trivial 1-device)."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = _tree()
    mgr.save(1, tree)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree)
    got, _ = mgr.restore(template=jax.eval_shape(lambda: tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
