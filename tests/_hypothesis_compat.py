"""Hypothesis-optional shim: property tests degrade to fixed-seed examples.

``hypothesis`` is a dev-only extra (requirements-dev.txt). When it is
installed, this module re-exports the real ``given``/``settings``/
``strategies`` untouched. When it is absent, a minimal deterministic
stand-in draws a fixed, seeded set of examples per test — weaker than
real property testing (no shrinking, no coverage-guided search) but
enough to keep the invariants exercised and, crucially, to keep tier-1
collection from dying at import.

Usage in test modules:

    from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

import math
import random

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_MAX_EXAMPLES = 25  # cap: examples are fixed-seed, not searched

    class _Strategy:
        def __init__(self, draw, predicate=None):
            self._draw = draw
            self._predicate = predicate

        def filter(self, predicate):
            old = self._predicate

            def both(v):
                return (old is None or old(v)) and predicate(v)

            return _Strategy(self._draw, both)

        def example(self, rng):
            for _ in range(1000):
                v = self._draw(rng)
                if self._predicate is None or self._predicate(v):
                    return v
            raise ValueError("filter predicate rejected 1000 draws")

    class strategies:  # noqa: N801 - mimics `hypothesis.strategies` module
        @staticmethod
        def integers(min_value, max_value):
            def draw(rng):
                # Mix uniform draws with the boundaries so edge cases
                # (1, max) always appear in the fixed example set.
                r = rng.random()
                if r < 0.15:
                    return min_value
                if r < 0.3:
                    return max_value
                return rng.randint(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def floats(min_value=None, max_value=None, allow_nan=True,
                   allow_infinity=True, allow_subnormal=True):
            lo = -1e300 if min_value is None else min_value
            hi = 1e300 if max_value is None else max_value

            def draw(rng):
                r = rng.random()
                if r < 0.1:
                    return 0.0
                if r < 0.2:  # near-boundary magnitudes
                    v = rng.choice([lo, hi])
                    return float(v)
                # log-uniform magnitude sweep, signed
                mag_hi = max(abs(lo), abs(hi), 1.0)
                exp = rng.uniform(-12, math.log10(mag_hi) if mag_hi > 1
                                  else 0.0)
                v = (10.0 ** exp) * (1.0 + rng.random())
                if rng.random() < 0.5 and lo < 0:
                    v = -v
                return float(min(max(v, lo), hi))

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    def settings(**kwargs):
        """Accepts and records hypothesis settings; only max_examples is
        honored by the fallback runner (deadline etc. are no-ops)."""

        def deco(f):
            f._hc_max_examples = kwargs.get("max_examples", 20)
            return f

        return deco

    def given(*strats):
        def deco(f):
            def wrapper():
                n = getattr(wrapper, "_hc_max_examples",
                            getattr(f, "_hc_max_examples", 20))
                n = min(n, _FALLBACK_MAX_EXAMPLES)
                rng = random.Random(f.__qualname__)
                for _ in range(n):
                    f(*[s.example(rng) for s in strats])

            # No functools.wraps: pytest must see a zero-arg signature,
            # not the original one (it would treat drawn args as fixtures).
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper

        return deco
