"""Per-architecture smoke tests (required deliverable f):

Each of the 10 assigned archs instantiates a REDUCED same-family config
and runs one forward + one train step on CPU, asserting output shapes and
no NaNs. Full configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model
from repro.models.transformer import RunCtx
from repro.optim import OptConfig, apply_updates, init_opt_state

CTX = RunCtx(kernel_mode="ref")


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_len, cfg.d_model)), jnp.float32)
    if cfg.visual_prefix:
        batch["visual_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.visual_prefix, cfg.d_model)), jnp.float32)
    if cfg.rope_style == "mrope":
        batch["mrope_positions"] = jnp.asarray(
            np.tile(np.arange(S), (3, B, 1)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    loss, metrics = jax.jit(
        lambda p, b: model.loss_fn(p, b, CTX))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    opt_cfg = OptConfig(grad_clip=1.0)
    opt = init_opt_state(params, opt_cfg)
    grads = jax.grad(lambda p: model.loss_fn(p, batch, CTX)[0])(params)
    new_params, new_opt, om = apply_updates(params, grads, opt, opt_cfg,
                                            1e-3)
    assert bool(jnp.isfinite(om["grad_norm"])), f"{arch}: non-finite gnorm"
    # params actually moved
    delta = sum(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["yi_6b", "recurrentgemma_2b", "xlstm_1_3b",
                                  "whisper_base", "qwen2_vl_2b"])
def test_smoke_prefill_decode_shapes(arch, rng):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = {k: v for k, v in _batch(cfg, rng, B, S).items()
             if k != "targets"}
    logits, cache = model.prefill(params, batch, CTX, max_len=S + 4)
    assert logits.shape == (B, S, cfg.vocab_size)
    mrope = (jnp.full((3, B, 1), S, jnp.int32)
             if cfg.rope_style == "mrope" else None)
    step_logits, cache2 = model.decode_step(
        params, cache, batch["tokens"][:, :1], jnp.int32(S), CTX,
        mrope_positions=mrope)
    assert step_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(step_logits)))


def test_long_500k_skip_list_matches_design():
    """Sub-quadratic flags drive long_500k participation (DESIGN.md §4)."""
    runs = {a for a in ARCH_IDS if get_config(a).sub_quadratic}
    assert runs == {"xlstm_1_3b", "recurrentgemma_2b", "h2o_danube_3_4b"}


def test_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    k = get_config("kimi_k2_1t_a32b")
    assert (k.n_layers, k.d_model, k.n_heads, k.n_kv_heads) == (61, 7168, 64, 8)
    assert (k.n_experts, k.moe_top_k, k.vocab_size) == (384, 8, 163840)
    g = get_config("gemma_7b")
    assert (g.head_dim, g.d_ff, g.vocab_size) == (256, 24576, 256000)
    r = get_config("recurrentgemma_2b")
    assert r.block_pattern == ("rglru", "rglru", "local")
    assert r.n_layers == 26 and r.n_kv_heads == 1
    x = get_config("xlstm_1_3b")
    assert x.layer_kinds.count("slstm") == 6 and x.d_ff == 0
    w = get_config("whisper_base")
    assert w.enc_dec and w.n_encoder_layers == 6 and w.vocab_size == 51865
