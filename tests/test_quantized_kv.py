"""Quantized paged KV cache (int8/fp8) + the unified kernel dispatcher.

Contract chain, weakest to strongest:
  1. quantize/dequantize round-trip error is bounded; fp8 saturates
     (never NaN) on overflow-scale rows; PoolSpec validates and stays
     hashable (it rides in the jit-static RunCtx);
  2. fused-dequant kernels: interpret-mode Pallas == quantized jnp
     oracle (decode AND verify), quantized oracle ~= fp oracle within
     quantization tolerance; lane-padded pools (padded_head_dim) are
     BIT-equal to unpadded — padding is exact, not approximate;
  3. the one ``ops.paged_attention`` dispatcher: bf16 pools are
     bit-identical through it vs the deprecated aliases, bad modes
     raise;
  4. engine level: int8/fp8 engines emit greedy tokens matching the
     bf16 engine at a high rate on real smoke models (olmo dense,
     recurrentgemma windowed-hybrid — its rings stay full-precision),
     with zero block leaks; the bf16 pool tree gains NO scale leaves
     (structure regression for donation/sharding);
  5. subsystems compose: COW prefix caching shares quantized blocks
     unchanged (cache on == cache off, bit-identical), migration
     packets carry scales and land bit-exact (round-trip finishes with
     the unmigrated tokens), and a kv-format mismatch at import is
     rejected naming the gate;
  6. the gates themselves: static backend + quantized, encoder-decoder
     + quantized (ServingCaps.quantized_kv), unknown kv_dtype, and the
     serve CLI rejecting an unknown --kv-dtype.

Head-sharded (mesh) quantized coverage re-execs under 8 fake CPU
devices like tests/test_sharded_serve.py.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.launch.engine import transport
from repro.models import paged_kv
from repro.models.model import Model

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BASE = dict(backend="paged", num_slots=3, block_size=4, num_blocks=33,
             max_len=48)


def _spec(kv_dtype="int8", bs=4, hkv=2, hd=16, padded=0):
    return paged_kv.PoolSpec(kv_dtype=kv_dtype, block_size=bs,
                             n_kv_heads=hkv, head_dim=hd,
                             padded_head_dim=padded)


def _quant_pool_case(rng, B, hq, hkv, hd, bs, nbmax, lengths, kv_dtype):
    """An fp pool plus its quantized counterpart over a scrambled block
    table (same construction as test_paged_serve)."""
    nb = B * nbmax + 1
    q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    spec = _spec(kv_dtype, bs=bs, hkv=hkv, hd=hd)
    kq, ks = paged_kv.quantize_kv(kp, spec)
    vq, vs = paged_kv.quantize_kv(vp, spec)
    qpool = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return q, {"k": kp, "v": vp}, qpool, bt, \
        jnp.asarray(lengths, jnp.int32), spec


# -- 1. quantization math ----------------------------------------------


def test_quantize_roundtrip_bounded(rng):
    spec = _spec("int8")
    x = jnp.asarray(rng.normal(size=(9, 4, 2, 16)), jnp.float32)
    payload, scale = paged_kv.quantize_kv(x, spec)
    assert payload.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert scale.shape == x.shape[:-1]
    back = paged_kv.dequantize_kv(payload, scale)
    err = float(jnp.max(jnp.abs(back - x)))
    # per-(row, head) amax / 127 bounds the grid step
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_quantize_zero_rows_and_fp8_overflow(rng):
    spec8 = _spec("int8")
    z = jnp.zeros((2, 4, 2, 16), jnp.float32)
    payload, scale = paged_kv.quantize_kv(z, spec8)
    assert float(jnp.max(jnp.abs(paged_kv.dequantize_kv(payload,
                                                        scale)))) == 0.0
    if not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no fp8 dtype in this jax build")
    spec = _spec("fp8")
    big = jnp.asarray(rng.normal(size=(2, 4, 2, 16)) * 1e6, jnp.float32)
    payload, scale = paged_kv.quantize_kv(big, spec)
    back = paged_kv.dequantize_kv(payload, scale)
    assert bool(jnp.all(jnp.isfinite(back)))  # clipped, never NaN


def test_pool_spec_validates_and_hashes():
    with pytest.raises(ValueError, match="kv_dtype"):
        paged_kv.PoolSpec(kv_dtype="int4")
    with pytest.raises(ValueError, match="padded_head_dim"):
        paged_kv.PoolSpec(kv_dtype="int8", head_dim=64,
                          padded_head_dim=32)
    a = _spec("int8")
    assert hash(a) == hash(_spec("int8"))  # jit-static in RunCtx
    assert a.quantized and not _spec("bf16").quantized
    assert _spec("bf16", padded=128).pool_head_dim == 128


# -- 2. fused-dequant kernels vs oracles --------------------------------


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("window", [None, 5])
def test_quantized_decode_kernel_matches_oracle(rng, kv_dtype, window):
    if kv_dtype == "fp8" and not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no fp8 dtype in this jax build")
    q, _, qpool, bt, ln, spec = _quant_pool_case(
        rng, 4, 4, 2, 16, 4, 4, [7, 8, 1, 16], kv_dtype)
    got = ops.paged_attention(q, qpool, bt, ln, mode="decode",
                              window=window, kernel_mode="interpret",
                              kv_format=spec)
    want = ref.paged_decode_attention(
        q, qpool["k"], qpool["v"], bt, ln, window=window,
        k_scale=qpool["k_scale"], v_scale=qpool["v_scale"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [None, 6])
def test_quantized_verify_kernel_matches_oracle(rng, window):
    B, K1, hq, hkv, hd, bs, nbmax = 4, 3, 4, 2, 16, 4, 4
    _, _, qpool, bt, ln, spec = _quant_pool_case(
        rng, B, hq, hkv, hd, bs, nbmax, [2, 7, 0, 12], "int8")
    q = jnp.asarray(rng.normal(size=(B, K1, hq, hd)), jnp.float32)
    got = ops.paged_attention(q, qpool, bt, ln, mode="verify",
                              window=window, kernel_mode="interpret",
                              kv_format=spec)
    want = ref.paged_verify_attention(
        q, qpool["k"], qpool["v"], bt, ln, window=window,
        k_scale=qpool["k_scale"], v_scale=qpool["v_scale"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quantized_oracle_close_to_fp_oracle(rng):
    """The quantized pool approximates the fp attention output within
    quantization tolerance — the kernel-level half of the quality gate
    (the engine-level half is the greedy match rate below)."""
    q, pool, qpool, bt, ln, _ = _quant_pool_case(
        rng, 4, 4, 2, 16, 4, 4, [7, 8, 1, 16], "int8")
    fp = ref.paged_decode_attention(q, pool["k"], pool["v"], bt, ln)
    qt = ref.paged_decode_attention(
        q, qpool["k"], qpool["v"], bt, ln,
        k_scale=qpool["k_scale"], v_scale=qpool["v_scale"])
    np.testing.assert_allclose(np.asarray(qt), np.asarray(fp), atol=0.05)


def test_padded_head_dim_is_exact(rng):
    """Lane-width tiling: a pool whose blocks are physically padded to
    head dim 128 produces BIT-equal output to the unpadded pool — the
    zero k-tail contributes nothing to logits (q is zero-padded too),
    v-tail columns are sliced off, and the per-row amax (hence every
    scale and payload value) is unchanged by zero padding."""
    B, hq, hkv, hd, bs, nbmax = 4, 4, 2, 16, 4, 4
    q, _, qpool, bt, ln, _ = _quant_pool_case(
        rng, B, hq, hkv, hd, bs, nbmax, [7, 8, 1, 16], "int8")
    spec_u = _spec("int8", bs=bs, hkv=hkv, hd=hd)
    spec_p = _spec("int8", bs=bs, hkv=hkv, hd=hd, padded=128)
    pad = [(0, 0)] * 3 + [(0, 128 - hd)]
    kq, ks = paged_kv.quantize_kv(
        jnp.pad(paged_kv.dequantize_kv(qpool["k"], qpool["k_scale"]),
                pad), spec_p)
    vq, vs = paged_kv.quantize_kv(
        jnp.pad(paged_kv.dequantize_kv(qpool["v"], qpool["v_scale"]),
                pad), spec_p)
    ppool = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    out_u = ops.paged_attention(q, qpool, bt, ln, mode="decode",
                                kernel_mode="ref", kv_format=spec_u)
    out_p = ops.paged_attention(q, ppool, bt, ln, mode="decode",
                                kernel_mode="ref", kv_format=spec_p)
    assert out_p.shape == out_u.shape  # sliced back to logical D
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_u))
    # scales are invariant under zero padding of the head dim
    np.testing.assert_array_equal(np.asarray(ks),
                                  np.asarray(qpool["k_scale"]))


# -- 3. the unified dispatcher -----------------------------------------


def test_dispatcher_bf16_bit_identical_to_aliases(rng):
    q, pool, _, bt, ln, _ = _quant_pool_case(
        rng, 4, 4, 2, 16, 4, 4, [7, 8, 1, 16], "int8")
    new = ops.paged_attention(q, pool, bt, ln, mode="decode",
                              kernel_mode="ref")
    old = ops.paged_decode_attention(q, pool["k"], pool["v"], bt, ln,
                                     mode="ref")
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))
    K1 = 3
    qv = jnp.asarray(rng.normal(size=(4, K1, 4, 16)), jnp.float32)
    newv = ops.paged_attention(qv, pool, bt, ln, mode="verify",
                               kernel_mode="ref")
    oldv = ops.paged_verify_attention(qv, pool["k"], pool["v"], bt, ln,
                                      mode="ref")
    np.testing.assert_array_equal(np.asarray(newv), np.asarray(oldv))


def test_dispatcher_rejects_unknown_mode(rng):
    q, pool, _, bt, ln, _ = _quant_pool_case(
        rng, 2, 4, 2, 16, 4, 2, [3, 5], "int8")
    with pytest.raises(ValueError, match="decode"):
        ops.paged_attention(q, pool, bt, ln, mode="prefill")


# -- 4. engine-level greedy match + structure regression ----------------


def _greedy_outputs(model, params, prompts, kv_dtype, n_new=8, **over):
    cfg = EngineConfig(**dict(_BASE, kv_dtype=kv_dtype, **over))
    eng = Engine(model, params, cfg)
    sp = SamplingParams(max_tokens=n_new)
    out = eng.generate(prompts, sp)
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks  # zero leaks
    return out


def _match_rate(a, b):
    tot = sum(max(len(x), len(y)) for x, y in zip(a, b))
    hit = sum(sum(u == v for u, v in zip(x, y)) for x, y in zip(a, b))
    return hit / max(tot, 1)


@pytest.mark.parametrize("arch", ["olmo_1b", "recurrentgemma_2b"])
@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_engine_greedy_match_vs_bf16(rng, arch, kv_dtype):
    """The acceptance gate at engine level: a quantized engine serves
    real smoke models with greedy outputs matching the bf16 engine at a
    high token rate, leak-free. recurrentgemma mixes windowed rings
    (full-precision, untouched) with quantized full-attention pools."""
    if kv_dtype == "fp8" and not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no fp8 dtype in this jax build")
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 9, 14)]
    want = _greedy_outputs(model, params, prompts, "bf16")
    got = _greedy_outputs(model, params, prompts, kv_dtype)
    rate = _match_rate(want, got)
    assert rate >= 0.9, (arch, kv_dtype, rate, want, got)


def test_bf16_pool_tree_unchanged():
    """Structure regression: kv_dtype='bf16' must build EXACTLY the
    historical pool tree — no scale leaves — so donation, sharding
    specs and migration traces stay bit-for-bit what they were."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(**_BASE))
    leaves = jax.tree_util.tree_flatten_with_path(eng.backend.pools)[0]
    keys = {str(k[-1]) for k, _ in leaves}
    assert not any("scale" in k for k in keys), keys
    assert eng.backend.kv_spec is None
    q = Engine(model, params, EngineConfig(**_BASE, kv_dtype="int8"))
    qkeys = {str(k[-1]) for k, _ in
             jax.tree_util.tree_flatten_with_path(q.backend.pools)[0]}
    assert any("k_scale" in k for k in qkeys), qkeys
    # the payload leaves themselves store int8
    kinds = {str(l.dtype) for l in jax.tree.leaves(q.backend.pools)}
    assert "int8" in kinds, kinds


def test_speculative_quantized_matches_nonspec(rng):
    """Verify-path quantization: the speculative engine over an int8
    pool emits exactly the non-speculative int8 engine's tokens (the
    accept rule compares target vs target — quantization shifts both
    sides identically)."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [(list(map(int, rng.integers(0, cfg.vocab_size, 4))) * 4)
               [:9 + i] for i in range(3)]
    want = _greedy_outputs(model, params, prompts, "int8", n_new=10)
    got = _greedy_outputs(model, params, prompts, "int8", n_new=10,
                          spec_tokens=3)
    assert got == want


# -- 5. subsystem composition: COW, migration ---------------------------


def test_prefix_cache_shares_quantized_blocks(rng):
    """COW prefix caching over an int8 pool: cached == uncached,
    bit-identical — shared quantized blocks (payload + scales) are
    reused as stored, and the COW block copy duplicates scale leaves
    through the same block-axis treemap."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 12)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, t)))
               for t in (2, 3, 5)]
    off = _greedy_outputs(model, params, prompts, "int8",
                          prefix_cache=False)
    on_eng = Engine(model, params, EngineConfig(
        **_BASE, kv_dtype="int8", prefix_cache=True))
    on = on_eng.generate(prompts, SamplingParams(max_tokens=8))
    assert on == off
    st = on_eng.stats()["prefix_cache"]
    assert st["hits"] > 0  # sharing actually happened


def test_migration_roundtrip_quantized(rng):
    """Extract/insert with an int8 pool: the packet carries the scale
    leaves inside ``state`` and stamps ``kv_format``; a round-trip
    through the SAME backend finishes with the unmigrated tokens."""
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (5, 9)]
    sp = [SamplingParams(max_tokens=8)] * 2
    ecfg = EngineConfig(**_BASE, kv_dtype="int8")
    want = Engine(model, params, ecfg).generate(prompts, sp)
    eng = Engine(model, params, ecfg)
    handles = [eng.add_request(p, s) for p, s in zip(prompts, sp)]
    eng.step()
    be = eng.backend
    i = next(j for j, s in enumerate(be.slots) if s.req is handles[0])
    pkt = transport.extract_slot(be, i, src=0)
    assert pkt.kv_format == be.kv_spec and pkt.kv_format.quantized
    # scale leaves travel in the packet state
    skeys = {str(k[-1]) for k, _ in
             jax.tree_util.tree_flatten_with_path(pkt.state)[0]}
    assert any("k_scale" in k for k in skeys), skeys
    assert transport.can_import(be, pkt)
    transport.insert_packet(be, pkt)
    eng.drain()
    assert [h.token_ids for h in handles] == want
    assert be.alloc.free_count == be.layout.usable_blocks


def test_migration_kv_format_mismatch_rejected(rng):
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 6)))]
    src = Engine(model, params, EngineConfig(**_BASE, kv_dtype="int8"))
    src.add_request(prompts[0], SamplingParams(max_tokens=8))
    src.step()
    be = src.backend
    i = next(j for j, s in enumerate(be.slots) if s.req is not None)
    pkt = transport.extract_slot(be, i)
    dst = Engine(model, params, EngineConfig(**_BASE))  # bf16 pool
    with pytest.raises(ValueError, match="kv_format"):
        transport.insert_packet(dst.backend, pkt)


# -- 6. the gates -------------------------------------------------------


def test_static_backend_rejects_quantized():
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged backend"):
        Engine(model, params,
               EngineConfig(backend="static", kv_dtype="int8"))


def test_encdec_rejects_quantized_naming_cap():
    cfg = get_config("whisper_base").smoke()
    model = Model(cfg)
    assert not model.serving_caps().quantized_kv
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="quantized_kv"):
        Engine(model, params, EngineConfig(**dict(_BASE,
                                                  kv_dtype="int8")))


def test_unknown_kv_dtype_rejected():
    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_dtype"):
        Engine(model, params, EngineConfig(**dict(_BASE,
                                                  kv_dtype="int4")))


def test_serve_cli_rejects_unknown_kv_dtype():
    """Both CLIs advertise --kv-dtype with a closed choice set; an
    unknown value dies in argparse with the standard rejection message
    (before any device work)."""
    proc = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", "serve_lm.py"),
         "--smoke", "--kv-dtype", "int4"],
        env=dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src")),
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 2
    assert "invalid choice: 'int4'" in proc.stderr


# -- 7. head-sharded quantized (8 fake devices, subprocess) -------------


def test_headshard_quantized_matches_oracle_and_engine():
    """Mesh coverage: (a) the head-sharded quantized kernel (scale
    leaves sharded over Hkv with the payload) equals the single-device
    quantized oracle; (b) a mesh-sharded int8 engine emits tokens
    identical to the single-device int8 engine."""
    code = textwrap.dedent("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.kernels import ops, ref
    from repro.launch.engine import Engine, EngineConfig, SamplingParams
    from repro.launch.mesh import make_mesh
    from repro.models import paged_kv
    from repro.models.model import Model

    assert len(jax.devices()) == 8
    MESH = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(7)
    B, hq, hkv, hd, bs, nbmax = 4, 8, 2, 16, 4, 4
    nb = B * nbmax + 1
    spec = paged_kv.PoolSpec(kv_dtype="int8", block_size=bs,
                             n_kv_heads=hkv, head_dim=hd)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    kq, ks = paged_kv.quantize_kv(kp, spec)
    vq, vs = paged_kv.quantize_kv(vp, spec)
    pool = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.float32)
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    ln = jnp.asarray([7, 8, 1, 16], jnp.int32)

    class Sh:
        mesh, tp_axis = MESH, "model"

    got = ops.paged_attention(q, pool, bt, ln, mode="decode",
                              kernel_mode="ref", sharding=Sh,
                              kv_format=spec)
    want = ref.paged_decode_attention(q, kq, vq, bt, ln, k_scale=ks,
                                      v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    qv = jnp.asarray(rng.normal(size=(B, 3, hq, hd)), jnp.float32)
    gotv = ops.paged_attention(qv, pool, bt, ln, mode="verify",
                               kernel_mode="ref", sharding=Sh,
                               kv_format=spec)
    wantv = ref.paged_verify_attention(qv, kq, vq, bt, ln, k_scale=ks,
                                       v_scale=vs)
    np.testing.assert_allclose(np.asarray(gotv), np.asarray(wantv),
                               rtol=1e-5, atol=1e-5)

    cfg = get_config("olmo_1b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 9, 14)]
    sp = SamplingParams(max_tokens=6)
    base = dict(backend="paged", num_slots=3, block_size=4,
                num_blocks=33, max_len=48, kv_dtype="int8")
    want = Engine(model, params, EngineConfig(**base)).generate(
        prompts, sp)
    sharded = Engine(model, params, EngineConfig(mesh=MESH, **base))
    got = sharded.generate(prompts, sp)
    assert got == want, (got, want)
    be = sharded.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    print("body ran")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "body ran" in proc.stdout
