"""Mesh-sharded serving Engine backends (8 fake CPU devices, subprocess).

The tentpole contract: putting a mesh under a backend changes WHERE
tensors live, never WHAT tokens come out. On a (4 data x 2 model) mesh:

  * sharded paged == single-device paged == unbatched oracle on ragged
    prompts (greedy AND seeded stochastic sampling), across a plain-MHA
    arch and a GQA arch with the head-sharded pool shard_map active,
    plus an arch whose kv heads do NOT divide |tp| (honest GSPMD-only
    fallback);
  * zero block leaks after LIFO preemption on the sharded pool;
  * the static backend matches under the same mesh;
  * the head-sharded paged attention op matches the single-device oracle
    at the kernel level.

(Data-parallel replica serving over submeshes lives in
``tests/test_replica_serve.py``.)

The suite's default process must keep 1 device (smoke-test contract), so
these tests re-exec python with XLA_FLAGS set, like test_distribution.py.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """
import jax, numpy as np
from repro.configs import get_config
from repro.launch.engine import Engine, EngineConfig, SamplingParams
from repro.launch.mesh import make_mesh
from repro.models.model import Model

assert len(jax.devices()) == 8
MESH = make_mesh((4, 2), ("data", "model"))

def setup(arch):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))
"""


def _run(body: str):
    # Dedent the body BEFORE prepending the (unindented) prelude:
    # dedenting the concatenation would leave the body indented, quietly
    # parsing it into the prelude's trailing function and running
    # nothing. The "body ran" marker guards against that class of bug.
    code = _PRELUDE + textwrap.dedent(body)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "body ran" in proc.stdout, f"test body never executed:\n{code}"
    return proc.stdout


def test_sharded_paged_token_identical_two_archs():
    """Acceptance: on an 8-device mesh the sharded PagedBackend emits
    token-identical outputs to the single-device engine — greedy and
    seeded sampling — on ragged prompts, across >= 2 architectures.
    olmo exercises the head-sharded pool path (heads divide |tp|);
    recurrentgemma (MQA kv=1) exercises the GSPMD-only fallback."""
    _run("""
    rng = np.random.default_rng(0)
    for arch in ("olmo_1b", "recurrentgemma_2b"):
        cfg, model, params = setup(arch)
        prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
                   for L in (3, 7, 12)]
        sp = [SamplingParams(max_tokens=5),
              SamplingParams(max_tokens=5, temperature=0.9, top_k=12,
                             seed=3),
              SamplingParams(max_tokens=5, temperature=1.0, top_p=0.85,
                             seed=5)]
        base = dict(num_slots=3, block_size=4, num_blocks=33, max_len=32)
        want = Engine(model, params, EngineConfig(
            backend="paged", **base)).generate(prompts, sp)
        eng = Engine(model, params, EngineConfig(
            backend="paged", mesh=MESH, **base))
        assert eng.backend.ctx.decode_head_shard == (arch == "olmo_1b")
        got = eng.generate(prompts, sp)
        assert got == want, (arch, got, want)
        assert eng.stats()["blocks_used"] == 0
        print(arch, "ok")
    print("body ran")
    """)


def test_sharded_static_matches_and_mesh_threads_through():
    _run("""
    rng = np.random.default_rng(1)
    cfg, model, params = setup("olmo_1b")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (4, 9, 14, 6)]
    sp = SamplingParams(max_tokens=6)
    want = Engine(model, params, EngineConfig(
        backend="static", num_slots=4, max_len=64)).generate(prompts, sp)
    got = Engine(model, params, EngineConfig(
        backend="static", num_slots=4, max_len=64,
        mesh=MESH)).generate(prompts, sp)
    assert got == want, (got, want)
    print("body ran")
    """)


def test_sharded_pool_preemption_no_leaks():
    """LIFO preemption + recompute on the HEAD-SHARDED pool: a pool too
    small for three worst-case footprints forces eviction; outputs stay
    bit-identical to an uncontended run and the allocator returns to
    all-free (zero leaks) with the table fully nulled."""
    _run("""
    from repro.models import paged_kv
    rng = np.random.default_rng(2)
    cfg, model, params = setup("olmo_1b")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(3)]
    want = Engine(model, params, EngineConfig(
        backend="paged", num_slots=3, block_size=4, num_blocks=65,
        max_len=64, mesh=MESH)).generate(
            prompts, SamplingParams(max_tokens=16))
    eng = Engine(model, params, EngineConfig(
        backend="paged", num_slots=3, block_size=4, num_blocks=14,
        max_len=64, mesh=MESH))
    handles = [eng.add_request(p, SamplingParams(max_tokens=16))
               for p in prompts]
    eng.drain()
    st = eng.stats()
    assert st["preemptions"] >= 1, st
    assert [h.token_ids for h in handles] == want
    assert st["blocks_used"] == 0
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    assert np.all(be.table == paged_kv.NULL_BLOCK)
    print("body ran")
    """)


def test_headshard_op_matches_oracle():
    """Kernel-level: the head-sharded paged attention (each device owns
    its kv-head shard of every block) equals the single-device oracle on
    a scrambled block table with ragged lengths, MHA and GQA."""
    _run("""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(3)
    for hq, hkv in ((4, 4), (8, 2)):
        B, hd, bs, nbmax = 4, 16, 4, 4
        nb = B * nbmax + 1
        q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
        perm = rng.permutation(nb - 1) + 1
        bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
        ln = jnp.asarray([7, 8, 1, 16], jnp.int32)
        got = ops.paged_decode_attention_headshard(
            q, kp, vp, bt, ln, mesh=MESH, mode="ref")
        want = ref.paged_decode_attention(q, kp, vp, bt, ln)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("hq", hq, "hkv", hkv, "ok")
    print("body ran")
    """)


@pytest.mark.slow
def test_sharded_paged_third_arch_xlstm():
    """xLSTM's mlstm/slstm per-slot states shard over (data, model) while
    its pools stay head-sharded — outputs must still be token-identical
    (also covers the new ragged recurrent prefill under a mesh)."""
    _run("""
    rng = np.random.default_rng(4)
    cfg, model, params = setup("xlstm_1_3b")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12)]
    sp = [SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=5, temperature=0.9, top_k=12, seed=3),
          SamplingParams(max_tokens=5, temperature=1.0, top_p=0.85,
                         seed=5)]
    base = dict(num_slots=3, block_size=4, num_blocks=33, max_len=32)
    want = Engine(model, params, EngineConfig(
        backend="paged", **base)).generate(prompts, sp)
    got = Engine(model, params, EngineConfig(
        backend="paged", mesh=MESH, **base)).generate(prompts, sp)
    assert got == want, (got, want)
    print("body ran")
    """)


def test_sharded_speculative_token_identical():
    """Speculative decoding under a (4 data x 2 model) mesh with the
    head-sharded pool active: spec == unsharded non-spec baseline,
    greedy and seeded, zero leaks — and the multi-query verify headshard
    op equals the multi-query oracle at the kernel level."""
    _run("""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(6)
    # kernel level: multi-query headshard == oracle
    B, K1, hq, hkv, hd, bs, nbmax = 4, 3, 4, 2, 16, 4, 4
    nb = B * nbmax + 1
    q = jnp.asarray(rng.normal(size=(B, K1, hq, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, hd)), jnp.float32)
    perm = rng.permutation(nb - 1) + 1
    bt = jnp.asarray(perm[:B * nbmax].reshape(B, nbmax), jnp.int32)
    ln = jnp.asarray([2, 7, 0, 12], jnp.int32)
    got = ops.paged_verify_attention_headshard(
        q, kp, vp, bt, ln, mesh=MESH, mode="ref")
    want = ref.paged_verify_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # engine level: sharded spec == unsharded baseline
    cfg, model, params = setup("olmo_1b")
    prompts = [(list(map(int, rng.integers(0, cfg.vocab_size, 3))) * 5)
               [:9 + i] for i in range(4)]
    base = dict(num_slots=4, block_size=4, num_blocks=33, max_len=48)
    for sp in (SamplingParams(max_tokens=10),
               SamplingParams(max_tokens=10, temperature=0.9, seed=4)):
        want = Engine(model, params, EngineConfig(
            backend="paged", **base)).generate(prompts, sp)
        spec = Engine(model, params, EngineConfig(
            backend="paged", mesh=MESH, spec_tokens=3, **base))
        assert spec.backend.ctx.decode_head_shard
        got = spec.generate(prompts, sp)
        assert got == want, (got, want)
        assert spec.stats()["blocks_used"] == 0
    print("body ran")
    """)


def test_sharded_disaggregation_token_identical():
    """Prefill/decode disaggregation over submeshes: 4 data-parallel
    replicas on the (4 x 2) mesh, two per role — migration packets cross
    TP subgrids via device_put resharding — and outputs stay
    token-identical to the unsharded single engine with zero leaks in
    every per-replica pool."""
    _run("""
    from repro.launch.engine import DisaggregatedEngine
    rng = np.random.default_rng(8)
    cfg, model, params = setup("olmo_1b")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12, 5, 9, 14)]
    sp = [SamplingParams(max_tokens=6),
          SamplingParams(max_tokens=6, temperature=0.9, top_k=12, seed=3),
          SamplingParams(max_tokens=6, temperature=1.0, top_p=0.85,
                         seed=5),
          SamplingParams(max_tokens=6),
          SamplingParams(max_tokens=6, temperature=0.7, seed=11),
          SamplingParams(max_tokens=6)]
    base = dict(num_slots=3, block_size=4, num_blocks=33, max_len=48)
    want = Engine(model, params, EngineConfig(
        backend="paged", **base)).generate(prompts, sp)
    dis = DisaggregatedEngine(model, params, EngineConfig(
        backend="paged", **base), mesh=MESH, roles="auto")
    assert dis.roles == ("prefill", "prefill", "decode", "decode")
    got = dis.generate(prompts, sp)
    assert got == want, (got, want)
    st = dis.stats()["disagg"]
    assert st["exported"] >= len(prompts) and st["bytes_moved"] > 0, st
    for eng in dis.replicas:
        be = eng.backend
        assert be.alloc.free_count == be.layout.usable_blocks
        be.alloc.check_invariant()
    print("body ran")
    """)


def test_sharded_prefix_cache_token_identical():
    """COW prefix caching on the head-sharded pool: the trie index and
    refcounts are per-replica HOST state, the COW block copy runs under
    the pool's NamedSharding, and outputs stay token-identical to the
    cache-off sharded engine with real hits and zero leaks."""
    _run("""
    rng = np.random.default_rng(7)
    cfg, model, params = setup("olmo_1b")
    common = list(map(int, rng.integers(0, cfg.vocab_size, 12)))
    prompts = [common + list(map(int, rng.integers(0, cfg.vocab_size, 3)))
               for _ in range(5)] + [common]      # last: full-prefix hit
    sp = [SamplingParams(max_tokens=5, temperature=t, seed=i)
          for i, t in enumerate((0.0, 0.9, 0.0, 1.0, 0.0, 0.9))]
    base = dict(num_slots=2, block_size=4, num_blocks=33, max_len=32,
                mesh=MESH)
    want = Engine(model, params, EngineConfig(
        backend="paged", prefix_cache=False, **base)).generate(prompts, sp)
    eng = Engine(model, params, EngineConfig(
        backend="paged", prefix_cache=True, **base))
    assert eng.backend.ctx.decode_head_shard
    got = eng.generate(prompts, sp)
    assert got == want, (got, want)
    st = eng.stats()
    pc = st["prefix_cache"]
    assert pc["enabled"] and pc["hits"] >= 4 and pc["cow_copies"] >= 1, pc
    assert st["blocks_used"] == 0
    be = eng.backend
    assert be.alloc.free_count == be.layout.usable_blocks
    be.alloc.check_invariant()
    print("body ran")
    """)


def test_sharded_moe_expert_parallel_token_identical():
    """MoE serving under the mesh: qwen3-moe's 8 experts divide |tp|=2
    and num_slots=4 divides |dp|=4, so the Engine flips
    ``ctx.moe_sharded`` and decode/verify run the expert-sharded
    shard_map FFN (prefill drops back to GSPMD — pow-2 buckets need not
    divide dp). Same tokens as the single-device engine, greedy and
    seeded, with dropless routing keeping expert outputs per-token on
    both sides."""
    _run("""
    rng = np.random.default_rng(11)
    cfg, model, params = setup("qwen3_moe_30b_a3b")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 12, 5)]
    sp = [SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=5, temperature=0.9, top_k=12, seed=3),
          SamplingParams(max_tokens=5, temperature=1.0, top_p=0.85,
                         seed=5),
          SamplingParams(max_tokens=4)]
    base = dict(num_slots=4, block_size=4, num_blocks=33, max_len=32)
    want = Engine(model, params, EngineConfig(
        backend="paged", **base)).generate(prompts, sp)
    eng = Engine(model, params, EngineConfig(
        backend="paged", mesh=MESH, **base))
    assert eng.backend.ctx.moe_sharded
    assert not eng.backend.prefill_ctx.moe_sharded
    got = eng.generate(prompts, sp)
    assert got == want, (got, want)
    assert eng.stats()["blocks_used"] == 0
    print("body ran")
    """)


def test_sharded_encdec_token_identical():
    """Encoder-decoder serving under the mesh: the cross-KV arena is a
    pool leaf like any other, so the whisper smoke serves token-identical
    to the single-device engine, with the arena drained at exit."""
    _run("""
    rng = np.random.default_rng(12)
    cfg, model, params = setup("whisper_base")
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, L)))
               for L in (3, 7, 5)]
    feats = [np.asarray(rng.normal(size=(F, cfg.d_model)), np.float32)
             for F in (5, 16, 9)]
    sp = [SamplingParams(max_tokens=5),
          SamplingParams(max_tokens=5, temperature=8.0, seed=3),
          SamplingParams(max_tokens=4, temperature=9.0, seed=5)]
    base = dict(num_slots=3, block_size=4, num_blocks=33, max_len=32)
    want = Engine(model, params, EngineConfig(
        backend="paged", **base)).generate(prompts, sp,
                                           encoder_features=feats)
    eng = Engine(model, params, EngineConfig(
        backend="paged", mesh=MESH, **base))
    got = eng.generate(prompts, sp, encoder_features=feats)
    assert got == want, (got, want)
    assert eng.stats()["blocks_used"] == 0
    assert eng.backend.arena.used_count == 0
    print("body ran")
    """)
